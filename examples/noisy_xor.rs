//! Noisy XOR — the canonical TM benchmark (Granmo 2018), plus the
//! interpretability payoff: print the learned clauses and check they
//! are exactly the XOR minterms.
//!
//! y = x0 XOR x1, with 10 distractor features and flipped labels on a
//! noise fraction of training samples. A plain TM must learn the four
//! minterm clauses x0∧¬x1, ¬x0∧x1 (positive) / x0∧x1, ¬x0∧¬x1
//! (negative) despite the noise — non-linearly separable, the case
//! §1/Fig. 1 calls out.
//!
//! ```bash
//! cargo run --release --example noisy_xor
//! ```

use tsetlin_index::data::synth::noisy_xor;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::interpret;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

const FEATURES: usize = 12; // x0, x1 + 10 distractors
const NOISE: f64 = 0.15;

fn main() {
    let train = noisy_xor(FEATURES, 5000, NOISE, 1);
    let test = noisy_xor(FEATURES, 2000, 0.0, 2);

    let params = TMParams::new(2, 20, FEATURES)
        .with_threshold(15)
        .with_s(3.9)
        .with_seed(4);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(3);
    for epoch in 1..=30 {
        let order = train.epoch_order(&mut order_rng);
        tr.train_epoch(train.iter_order(&order));
        if epoch % 10 == 0 {
            println!(
                "epoch {epoch:>2}: noise-free test accuracy {:.3}",
                tr.accuracy(test.iter())
            );
        }
    }
    let acc = tr.accuracy(test.iter());
    println!("\nfinal accuracy on noise-free XOR: {acc:.3} (label noise was {NOISE})");
    assert!(acc > 0.95, "TM should see through the label noise");

    println!("\nlearned clauses (class 1 = XOR true), top 6 by specificity:");
    for line in interpret::top_clauses(&tr.tm, 1, 6, None) {
        println!("  {line}");
    }
    println!("\nexpected minterms: x0 ∧ ¬x1 and ¬x0 ∧ x1 dominate the positive polarity.");
}
