//! End-to-end driver: every layer of the stack on one real workload.
//!
//! 1. **Train** (Layer 3): a 10-class TM (1280 clauses) on a synthetic
//!    MNIST-like dataset, logging the accuracy curve and epoch times for
//!    the indexed vs naive evaluators.
//! 2. **Serve** (Layers 1–3): register the trained machine with the
//!    coordinator twice — `cpu` (clause-indexed Rust hot path) and
//!    `xla` (the AOT-compiled JAX/Pallas artifact through PJRT) — then
//!    drive concurrent batched clients against both, reporting
//!    throughput, latency quantiles, and cross-backend agreement.
//!
//! The model shape (784 features, 1280 clauses, 10 classes) matches the
//! `tm_b32_f784_c1280_m10` artifact emitted by `make artifacts`; without
//! artifacts the XLA route is skipped with a notice.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_serve
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use tsetlin_index::coordinator::{
    BatchPolicy, Coordinator, CpuBackend, ServeBackend as _, XlaBackend,
};
use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::runtime::{Manifest, Runtime};
use tsetlin_index::tm::io::DenseModel;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

const FEATURES: usize = 784;
const CLAUSES_TOTAL: usize = 1280;
const CLASSES: usize = 10;

fn train_phase(train: &Dataset, test: &Dataset) -> Trainer {
    println!("== phase 1: training ({} train / {} test samples) ==", train.len(), test.len());
    let params = TMParams::from_total_clauses(CLASSES, CLAUSES_TOTAL, FEATURES)
        .with_threshold(25)
        .with_s(5.0)
        .with_seed(42);

    // A/B the two evaluators on identical trajectories.
    let mut indexed = Trainer::new(params.clone(), Backend::Indexed);
    let mut naive = Trainer::new(params, Backend::Naive);
    for epoch in 1..=6 {
        let mut order_rng = Rng::new(1000 + epoch);
        let order = train.epoch_order(&mut order_rng);
        let t0 = Instant::now();
        indexed.train_epoch(train.iter_order(&order));
        let t_idx = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        naive.train_epoch(train.iter_order(&order));
        let t_nv = t0.elapsed().as_secs_f64();
        let acc = indexed.accuracy(test.iter());
        println!(
            "epoch {epoch}: accuracy {acc:.3}  epoch-time indexed {t_idx:.2}s / naive {t_nv:.2}s (speedup {:.2}x)  clause-len {:.1}",
            t_nv / t_idx,
            indexed.tm.mean_clause_length()
        );
    }
    assert_eq!(
        indexed.tm.bank(0).states(),
        naive.tm.bank(0).states(),
        "backends must train identical machines"
    );
    indexed
}

fn serve_phase(trainer: Trainer, test: &Dataset) {
    println!("\n== phase 2: serving ==");
    let tm = trainer.tm;
    let dense = DenseModel::from_tm(&tm);
    let mut coord = Coordinator::new();
    coord.register(
        "cpu",
        Box::new(CpuBackend::new(tm.clone(), Backend::Indexed)),
        BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_micros(200),
        },
    );

    let artifacts = std::path::Path::new("artifacts");
    let mut have_xla = false;
    if artifacts.join("manifest.json").exists() {
        let dense_for_worker = dense.clone();
        let res = coord.register_with(
            "xla",
            move || {
                let manifest = Manifest::load("artifacts")?;
                let meta = manifest
                    .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
                    .ok_or_else(|| anyhow::anyhow!("no matching artifact variant"))?
                    .clone();
                let rt = Runtime::cpu()?;
                let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta)?;
                let mut be = XlaBackend::new(rt, exe, &dense_for_worker)?;
                // warm the executable (first run includes PJRT setup)
                let warm = vec![tsetlin_index::util::BitVec::ones(2 * FEATURES)];
                let _ = be.infer_batch(&warm)?;
                Ok(Box::new(be) as _)
            },
            BatchPolicy {
                max_batch: 32,
                max_wait: std::time::Duration::from_millis(1),
            },
        );
        match res {
            Ok(()) => have_xla = true,
            Err(e) => println!("xla route unavailable: {e:#}"),
        }
    } else {
        println!("artifacts/ missing — run `make artifacts` for the XLA route");
    }

    let handle = coord.handle();
    let routes: Vec<&str> = if have_xla { vec!["cpu", "xla"] } else { vec!["cpu"] };
    for route in &routes {
        let requests = 2000usize.min(test.len() * 10);
        let clients = 8;
        let counter = Arc::new(AtomicUsize::new(0));
        let correct = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..clients {
                let handle = handle.clone();
                let counter = Arc::clone(&counter);
                let correct = Arc::clone(&correct);
                let test = &test;
                let route: String = route.to_string();
                scope.spawn(move || loop {
                    let i = counter.fetch_add(1, Ordering::Relaxed);
                    if i >= requests {
                        break;
                    }
                    let idx = i % test.len();
                    let p = handle.infer(&route, test.literals(idx).clone()).unwrap();
                    if p.class == test.label(idx) {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        let secs = t0.elapsed().as_secs_f64();
        let m = coord.metrics(route).unwrap();
        println!(
            "route {route:<4}: {requests} reqs in {secs:.2}s = {:.0} req/s | p50 {}us p99 {}us | mean batch {:.1} | accuracy {:.3}",
            requests as f64 / secs,
            m.latency_quantile_us(0.5).unwrap_or(0),
            m.latency_quantile_us(0.99).unwrap_or(0),
            m.mean_batch_size(),
            correct.load(Ordering::Relaxed) as f64 / requests as f64,
        );
    }

    // cross-backend agreement on a sample of requests
    if have_xla {
        let agree = (0..200)
            .filter(|&i| {
                let lits = test.literals(i % test.len()).clone();
                let a = handle.infer("cpu", lits.clone()).unwrap();
                let b = handle.infer("xla", lits).unwrap();
                a.class == b.class && a.scores == b.scores
            })
            .count();
        println!("cpu/xla agreement: {agree}/200 (scores bit-identical)");
        assert_eq!(agree, 200, "backends disagree!");
    }
    coord.shutdown();
}

fn main() {
    let all = image_dataset(ImageStyle::Digits, CLASSES, 2600, 1, 42);
    let train = all.slice(0, 2000);
    let test = all.slice(2000, 2600);
    let trainer = train_phase(&train, &test);
    serve_phase(trainer, &test);
    println!("\ne2e OK");
}
