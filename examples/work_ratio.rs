//! §3 "Remarks" reproduction: the work-ratio analysis.
//!
//! The paper estimates indexed evaluation at ~0.02 of the unindexed
//! work on MNIST (mean clause length ≈58, lists ≈740 long at 20k
//! clauses) and ~0.006 on IMDb (length ≈116). This example trains on
//! both synthetic workloads, prints the measured statistics, and
//! compares the model-predicted ratio with a measured wall-clock ratio.
//!
//! ```bash
//! cargo run --release --example work_ratio
//! ```

use tsetlin_index::data::synth::{bow, image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::timer::time_it;
use tsetlin_index::util::Rng;

fn analyze(name: &str, train: &Dataset, test: &Dataset, total_clauses: usize, epochs: usize) {
    let params = TMParams::from_total_clauses(train.classes, total_clauses, train.features)
        .with_threshold(25)
        .with_s(8.0);
    let mut indexed = Trainer::new(params.clone(), Backend::Indexed);
    let mut order_rng = Rng::new(0xABCD);
    for _ in 0..epochs {
        let order = train.epoch_order(&mut order_rng);
        indexed.train_epoch(train.iter_order(&order));
    }
    let stats = indexed.index_stats().unwrap();
    let mean_len = indexed.tm.mean_clause_length();
    let mean_list: f64 =
        stats.iter().map(|s| s.mean_list_length).sum::<f64>() / stats.len() as f64;
    let predicted_ratio: f64 =
        stats.iter().map(|s| s.work_ratio).sum::<f64>() / stats.len() as f64;

    // measured wall-clock ratio on the same trained machine; warm each
    // trainer with one untimed predict so the indexed side's one-off
    // fused-engine snapshot build stays out of the timed region
    let mut naive = Trainer::from_machine(indexed.tm.clone(), Backend::Naive);
    if let Some((lits, _)) = test.iter().next() {
        let _ = naive.predict(lits);
        let _ = indexed.predict(lits);
    }
    let (_, t_naive) = time_it(|| naive.accuracy(test.iter()));
    let (_, t_indexed) = time_it(|| indexed.accuracy(test.iter()));

    println!("== {name} ==");
    println!("  features (o):              {}", train.features);
    println!("  total clauses (m*n):       {total_clauses}");
    println!("  mean clause length:        {mean_len:.1}");
    println!("  mean inclusion-list len:   {mean_list:.1}");
    println!("  predicted work ratio:      {predicted_ratio:.4}");
    println!(
        "  measured time ratio:       {:.4}  (indexed {:.3}s vs naive {:.3}s)",
        t_indexed / t_naive,
        t_indexed,
        t_naive
    );
    println!(
        "  inference speedup:         {:.1}x\n",
        t_naive / t_indexed
    );
}

fn main() {
    // MNIST-shaped: paper predicts ratio ~0.02 at scale.
    let all = image_dataset(ImageStyle::Digits, 10, 1300, 1, 11);
    analyze(
        "MNIST-like (784 features)",
        &all.slice(0, 1000),
        &all.slice(1000, 1300),
        2000,
        2,
    );

    // IMDb-shaped: sparser literals, longer clauses, ratio ~0.006.
    let train = bow(5000, 400, 12);
    let test = bow(5000, 200, 13);
    analyze("IMDb-like (5000 features)", &train, &test, 1000, 2);
}
