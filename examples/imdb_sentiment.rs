//! IMDb-style sentiment analysis — the paper's sparsest, highest-payoff
//! workload (up to 15x inference speedup at 20k clauses).
//!
//! Trains a two-class TM on a Zipf bag-of-words (or a real exported BoW
//! file via `--bow-file` semantics of the `tmi` CLI), then compares
//! inference cost across all three CPU backends at growing clause
//! counts — a miniature of the paper's Fig. 6.
//!
//! ```bash
//! cargo run --release --example imdb_sentiment
//! ```

use tsetlin_index::data::synth::bow;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::timer::time_it;
use tsetlin_index::util::Rng;

fn main() {
    let features = 5000;
    let train = bow(features, 600, 21);
    let test = bow(features, 300, 22);
    println!(
        "IMDb-like BoW: {} features, density {:.1}%, {} train / {} test docs\n",
        features,
        train.mean_feature_density() * 100.0,
        train.len(),
        test.len()
    );

    for total_clauses in [200usize, 500, 1000, 2000] {
        let params = TMParams::from_total_clauses(2, total_clauses, features)
            .with_threshold(20)
            .with_s(8.0);
        let mut trainer = Trainer::new(params, Backend::Indexed);
        let mut order_rng = Rng::new(5);
        let mut train_s = 0.0;
        for _ in 0..2 {
            let order = train.epoch_order(&mut order_rng);
            let (_, s) = time_it(|| trainer.train_epoch(train.iter_order(&order)));
            train_s = s; // keep last epoch (clause lengths in regime)
        }
        let acc = trainer.accuracy(test.iter());

        let mut line = format!(
            "clauses {total_clauses:>5}  acc {acc:.3}  train/epoch {train_s:>7.2}s  inference: "
        );
        let mut naive_time = 0.0;
        for backend in [Backend::Naive, Backend::BitPacked, Backend::Indexed] {
            let mut clf = Trainer::from_machine(trainer.tm.clone(), backend);
            // untimed warm-up: keeps the indexed backend's one-off
            // fused-engine build out of the measured inference pass
            if let Some((lits, _)) = test.iter().next() {
                let _ = clf.predict(lits);
            }
            let (_, secs) = time_it(|| clf.accuracy(test.iter()));
            if backend == Backend::Naive {
                naive_time = secs;
            }
            line += &format!(
                "{} {:.3}s ({:.1}x)  ",
                backend.name(),
                secs,
                naive_time / secs
            );
        }
        println!("{line}");
    }
    println!("\n(speedup = naive time / backend time; the paper's Table 2 pattern —");
    println!(" indexed inference pulls away as clauses grow — should be visible.)");
}
