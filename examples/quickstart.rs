//! Quickstart: train a clause-indexed Tsetlin Machine on a synthetic
//! MNIST-like dataset, evaluate it, save it, reload it, and classify a
//! sample — the whole public API in ~60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

fn main() -> anyhow::Result<()> {
    // 1. Data: 10-class 28x28 synthetic digits, 1-bit binarized (784
    //    features). Swap in `data::mnist::load_idx` for real MNIST.
    let all = image_dataset(ImageStyle::Digits, 10, 2400, 1, 42);
    let train = all.slice(0, 2000);
    let test = all.slice(2000, 2400);

    // 2. Machine: 100 clauses/class, the paper's indexed evaluator.
    let params = TMParams::new(10, 100, train.features)
        .with_threshold(20)
        .with_s(5.0);
    let mut trainer = Trainer::new(params, Backend::Indexed);

    // 3. Train a few epochs.
    let mut order_rng = Rng::new(7);
    for epoch in 1..=5 {
        let order = train.epoch_order(&mut order_rng);
        let t0 = std::time::Instant::now();
        trainer.train_epoch(train.iter_order(&order));
        println!(
            "epoch {epoch}: {:.2}s, accuracy {:.3}, mean clause length {:.1}",
            t0.elapsed().as_secs_f64(),
            trainer.accuracy(test.iter()),
            trainer.tm.mean_clause_length(),
        );
    }

    // 4. Persist and reload.
    let path = std::env::temp_dir().join("quickstart.tm");
    io::save(&trainer.tm, &path)?;
    let reloaded = io::load(&path)?;
    println!("saved + reloaded model: {} bytes", std::fs::metadata(&path)?.len());

    // 5. Classify one sample with a fresh evaluator (any backend reads
    //    the same machine).
    let mut clf = Trainer::from_machine(reloaded, Backend::Indexed);
    let predicted = clf.predict(test.literals(0));
    println!(
        "sample 0: predicted class {predicted}, true class {}",
        test.label(0)
    );
    Ok(())
}
