//! The paper's §5 further work, demonstrated: clause indexing speeds up
//! tree search by exploiting incremental board changes.
//!
//! A TM is trained to score 4x4 board positions ("does X have a
//! 3-in-a-row?") from two occupancy planes (32 features). A search then
//! expands random game continuations and scores every visited node:
//!
//! * **full**: standard indexed evaluation from scratch per node;
//! * **incremental**: [`IncrementalEval`] — each move flips 1 feature
//!   (2 literals), so a child's score costs `O(|L_k|)` for those
//!   literals only (paper: "exploiting the incremental changes of the
//!   board position from parent to child node").
//!
//! Both must produce identical scores; the incremental path should
//! evaluate nodes several-fold faster.
//!
//! ```bash
//! cargo run --release --example mcts_search
//! ```

use std::time::Instant;

use tsetlin_index::data::Dataset;
use tsetlin_index::eval::{Backend, Evaluator};
use tsetlin_index::index::{IncrementalEval, IndexedEval};
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

const SIDE: usize = 4;
const CELLS: usize = SIDE * SIDE;
const FEATURES: usize = 2 * CELLS; // X plane + O plane

/// Does `plane` contain 3 aligned stones?
fn has_three(plane: &[bool]) -> bool {
    let at = |r: isize, c: isize| -> bool {
        (0..SIDE as isize).contains(&r)
            && (0..SIDE as isize).contains(&c)
            && plane[r as usize * SIDE + c as usize]
    };
    for r in 0..SIDE as isize {
        for c in 0..SIDE as isize {
            for (dr, dc) in [(0, 1), (1, 0), (1, 1), (1, -1)] {
                if (0..3).all(|i| at(r + dr * i, c + dc * i)) {
                    return true;
                }
            }
        }
    }
    false
}

fn board_features(x: &[bool], o: &[bool]) -> Vec<bool> {
    let mut f = Vec::with_capacity(FEATURES);
    f.extend_from_slice(x);
    f.extend_from_slice(o);
    f
}

/// Random labelled positions: class 1 iff X has 3-in-a-row.
fn positions(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut rows = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    while rows.len() < n {
        let mut x = vec![false; CELLS];
        let mut o = vec![false; CELLS];
        let stones = 3 + rng.below(6) as usize;
        for _ in 0..stones {
            let c = rng.below(CELLS as u32) as usize;
            if !x[c] && !o[c] {
                if rng.bern(0.5) {
                    x[c] = true;
                } else {
                    o[c] = true;
                }
            }
        }
        let label = has_three(&x) as usize;
        // keep classes roughly balanced
        if label == 0 && rng.bern(0.6) {
            continue;
        }
        rows.push(board_features(&x, &o));
        labels.push(label);
    }
    Dataset::from_rows("boards", FEATURES, 2, &rows, labels)
}

fn main() {
    // 1. Train the position scorer.
    let train = positions(3000, 1);
    let test = positions(800, 2);
    let params = TMParams::new(2, 200, FEATURES)
        .with_threshold(20)
        .with_s(4.0)
        .with_seed(9);
    let mut trainer = Trainer::new(params.clone(), Backend::Indexed);
    let mut order_rng = Rng::new(11);
    for _ in 0..12 {
        let order = train.epoch_order(&mut order_rng);
        trainer.train_epoch(train.iter_order(&order));
    }
    println!(
        "position scorer: accuracy {:.3} (class 1 = X has 3-in-a-row)\n",
        trainer.accuracy(test.iter())
    );

    // 2. Search: expand random X-move sequences from an empty board;
    //    score class-1 margin at every node.
    let bank = trainer.tm.bank(1).clone();
    let mut full_ev = IndexedEval::new(&params);
    full_ev.rebuild(&bank);
    let index = full_ev.index().clone();

    let playouts = 2000usize;
    let depth = 8usize;

    // -- full re-evaluation baseline
    let mut rng = Rng::new(77);
    let t0 = Instant::now();
    let mut full_sum = 0i64;
    let mut nodes = 0u64;
    for _ in 0..playouts {
        let mut feats = vec![false; FEATURES];
        for _ in 0..depth {
            let cell = rng.below(CELLS as u32) as usize;
            feats[cell] = true; // X plays (idempotent on repeats)
            let lits = Dataset::literals_from_bools(&feats);
            full_sum += full_ev.score(&bank, &lits) as i64;
            nodes += 1;
        }
    }
    let full_s = t0.elapsed().as_secs_f64();

    // -- incremental: one feature flip per move
    let mut rng = Rng::new(77); // identical move stream
    let empty = Dataset::literals_from_bools(&vec![false; FEATURES]);
    let t0 = Instant::now();
    let mut inc_sum = 0i64;
    for _ in 0..playouts {
        let mut inc = IncrementalEval::new(&index, &bank, &empty);
        for _ in 0..depth {
            let cell = rng.below(CELLS as u32) as usize;
            // feature id = cell on the X plane; o = FEATURES total features
            inc.set_feature(&index, FEATURES, cell, true);
            inc_sum += inc.score() as i64;
        }
    }
    let inc_s = t0.elapsed().as_secs_f64();

    assert_eq!(full_sum, inc_sum, "incremental scores must match full re-eval");
    println!("search: {playouts} playouts x depth {depth} = {nodes} node evaluations");
    println!(
        "  full re-eval : {:.3}s  ({:.0} nodes/s)",
        full_s,
        nodes as f64 / full_s
    );
    println!(
        "  incremental  : {:.3}s  ({:.0} nodes/s)  -> {:.1}x faster",
        inc_s,
        nodes as f64 / inc_s,
        full_s / inc_s
    );
    println!("  scores identical across {nodes} nodes (sum {full_sum})");
}
