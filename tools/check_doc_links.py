#!/usr/bin/env python3
"""Check that relative links in the markdown docs resolve.

Scans the repo's markdown documentation for `[text](target)` links and
inline `file.ext` / `dir/file.ext` code references to repo paths, and
fails if any named target does not exist in the tree. External
(`http://`, `https://`, `mailto:`) links are skipped, as are pure
anchors (`#section`). Anchored file links (`FILE.md#section`) check the
file part only.

Run from the repository root:

    python3 tools/check_doc_links.py
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Markdown files whose links must resolve.
DOC_FILES = [
    "rust/README.md",
    "docs/ARCHITECTURE.md",
    "docs/PROTOCOL.md",
    "docs/TUNING.md",
    "ROADMAP.md",
]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path/to/file.rs` or `path/to/file.rs:123` inside backticks
CODE_PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:rs|md|py|json|toml|yml))(?::\d+)?`")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(relpath: str) -> list[str]:
    path = ROOT / relpath
    if not path.exists():
        return [f"{relpath}: file missing"]
    errors = []
    text = path.read_text(encoding="utf-8")
    targets = set()
    for m in LINK_RE.finditer(text):
        targets.add(m.group(1))
    for m in CODE_PATH_RE.finditer(text):
        targets.add(m.group(1))
    for target in sorted(targets):
        if target.startswith(SKIP_PREFIXES):
            continue
        file_part = target.split("#", 1)[0]
        if not file_part:
            continue
        base = file_part.rsplit("/", 1)[-1]
        # generated artifacts (bench reports, registry state, model
        # files) and bare file-name mentions are not checkable paths
        if base.startswith("BENCH_") or base in ("manifest.json", "model.tm"):
            continue
        if "/" not in file_part and not file_part.endswith(".md"):
            continue
        # resolve relative to the doc's directory, then the repo root,
        # then the crate root (docs name both repo-rooted paths like
        # rust/src/... and crate-rooted ones like tests/obs.rs)
        candidates = [path.parent / file_part, ROOT / file_part, ROOT / "rust" / file_part]
        if not any(c.exists() for c in candidates):
            errors.append(f"{relpath}: broken link -> {target}")
    return errors


def main() -> int:
    errors = []
    for relpath in DOC_FILES:
        errors.extend(check_file(relpath))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{len(errors)} broken doc link(s)", file=sys.stderr)
        return 1
    print(f"doc links ok across {len(DOC_FILES)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
