#!/usr/bin/env python3
"""Heuristic `missing_docs` pre-check for the library crate.

Approximates rustc's `missing_docs` lint without a toolchain: walks
`rust/src/**/*.rs` (excluding `main.rs`, which is a bin crate), finds
`pub` items (fn, struct, enum, trait, type, const, static, mod, union,
macro) plus pub struct fields and enum variants inside documented pub
containers, and reports any that lack a `///` or `//!` doc comment (or a
`#[doc = ...]` / `#[doc(hidden)]` attribute) immediately above.

This is a *heuristic*: it understands line structure, not the grammar.
It intentionally skips items inside `impl`/`fn` bodies by tracking brace
depth relative to item starts, and skips `#[cfg(test)]` modules.

Run from the repository root:

    python3 tools/check_missing_docs.py
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "rust" / "src"

PUB_ITEM_RE = re.compile(
    r"^\s*pub(?:\((?:crate|super|self|in [^)]*)\))?\s+"
    r"(?:async\s+|unsafe\s+|extern\s+\"[^\"]*\"\s+|const\s+(?=fn)\s*)*"
    r"(fn|struct|enum|trait|type|const|static|mod|union|macro)\s+(\w+)"
)
FIELD_RE = re.compile(r"^\s*pub(?:\((?:crate|super|self|in [^)]*)\))?\s+(\w+)\s*:")
VARIANT_RE = re.compile(r"^\s*([A-Z]\w*)\s*(?:[({,]|=|$)")


def has_doc(lines: list[str], idx: int) -> bool:
    """True if the item starting at lines[idx] has a doc comment/attr above."""
    j = idx - 1
    while j >= 0:
        s = lines[j].strip()
        if s.startswith("///") or s.startswith("//!"):
            return True
        if s.startswith("#[doc") or "#[doc(hidden)]" in s:
            return True
        # skim other attributes and plain comments
        if s.startswith("#[") or s.startswith("//") or s.endswith("]"):
            j -= 1
            continue
        return False
    return False


def hidden_above(lines: list[str], idx: int) -> bool:
    j = idx - 1
    while j >= 0:
        s = lines[j].strip()
        if "#[doc(hidden)]" in s or "#[cfg(test)]" in s:
            return True
        if s.startswith("#[") or s.startswith("//") or s.endswith("]"):
            j -= 1
            continue
        return False
    return False


def mod_has_inner_docs(decl_path: Path, name: str) -> bool:
    """`pub mod name;` is documented if the module file opens with `//!`."""
    base = decl_path.parent
    for cand in (base / f"{name}.rs", base / name / "mod.rs"):
        if cand.exists():
            for line in cand.read_text(encoding="utf-8").splitlines():
                s = line.strip()
                if not s:
                    continue
                return s.startswith("//!")
    return False


def check_file(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    lines = text.splitlines()
    depth = 0  # brace depth; items at depth 0 (file) or inside pub mods
    item_depths = []  # depths at which a pub container (struct/enum/mod) opened
    container_kind = {}  # depth -> "struct" | "enum" | "mod"
    skip_until_depth = None  # inside fn/impl/test-mod bodies
    for i, raw in enumerate(lines):
        line = raw.split("//")[0] if not raw.lstrip().startswith("//") else ""
        stripped = raw.strip()
        at_depth = depth
        opens = line.count("{")
        closes = line.count("}")

        if skip_until_depth is None:
            m = PUB_ITEM_RE.match(raw)
            documentable = at_depth == 0 or container_kind.get(at_depth) == "mod"
            if container_kind.get(at_depth) == "impl":
                am = re.match(
                    r"^\s*pub\s+(?:async\s+|unsafe\s+|const\s+)*(fn|const|type)\s+(\w+)", raw
                )
                if am and not hidden_above(lines, i) and not has_doc(lines, i):
                    errors.append(
                        f"{path.relative_to(ROOT)}:{i + 1}: assoc {am.group(1)} {am.group(2)}"
                    )
                if am and opens > closes:
                    skip_until_depth = at_depth
                elif not am and re.match(r"^\s*(?:pub\s+)?(?:async\s+|unsafe\s+|const\s+)*fn[\s<]", raw) and opens > closes:
                    skip_until_depth = at_depth
            if m and documentable:
                kind, name = m.group(1), m.group(2)
                if (
                    not hidden_above(lines, i)
                    and not has_doc(lines, i)
                    and not (kind == "mod" and mod_has_inner_docs(path, name))
                ):
                    errors.append(f"{path.relative_to(ROOT)}:{i + 1}: pub {kind} {name}")
                if kind in ("struct", "enum") and opens > closes:
                    container_kind[at_depth + 1] = kind
                elif kind == "mod" and opens > closes:
                    container_kind[at_depth + 1] = "mod"
                elif kind in ("fn",) and opens > closes:
                    skip_until_depth = at_depth
            elif documentable and re.match(r"^\s*(?:pub\s+)?(?:unsafe\s+)?(impl|fn)[\s<]", raw):
                if opens > closes:
                    # inherent impls expose documentable associated items;
                    # trait impls (`impl Trait for T`) inherit trait docs
                    is_impl = re.match(r"^\s*(?:unsafe\s+)?impl[\s<]", raw)
                    if is_impl and " for " not in line:
                        container_kind[at_depth + 1] = "impl"
                    else:
                        skip_until_depth = at_depth
            elif re.match(r"^\s*mod\s+tests\b", raw) or "#[cfg(test)]" in raw:
                if "#[cfg(test)]" in raw:
                    # the next mod/fn body gets skipped when it opens
                    pass
            elif re.match(r"^\s*mod\s+\w+", raw) and opens > closes and hidden_above(lines, i):
                skip_until_depth = at_depth
            elif container_kind.get(at_depth) == "struct":
                fm = FIELD_RE.match(raw)
                if fm and not has_doc(lines, i) and not hidden_above(lines, i):
                    errors.append(f"{path.relative_to(ROOT)}:{i + 1}: pub field {fm.group(1)}")
            elif container_kind.get(at_depth) == "enum":
                vm = VARIANT_RE.match(raw)
                if vm and not stripped.startswith("#") and not has_doc(lines, i):
                    errors.append(f"{path.relative_to(ROOT)}:{i + 1}: variant {vm.group(1)}")
                if opens > closes:
                    # braced variant: its named fields are documentable
                    container_kind[at_depth + 1] = "variant"
                # single-line braced variant: check inline named fields
                if vm and "{" in line and "}" in line:
                    inner = line.split("{", 1)[1].rsplit("}", 1)[0]
                    for fld in re.finditer(r"(\w+)\s*:", inner):
                        errors.append(
                            f"{path.relative_to(ROOT)}:{i + 1}: variant field {fld.group(1)}"
                        )
            elif container_kind.get(at_depth) == "variant":
                fm = re.match(r"^\s*(\w+)\s*:", raw)
                if fm and not has_doc(lines, i):
                    errors.append(
                        f"{path.relative_to(ROOT)}:{i + 1}: variant field {fm.group(1)}"
                    )

        depth += opens - closes
        if skip_until_depth is not None and depth <= skip_until_depth:
            skip_until_depth = None
        # container bookkeeping: drop kinds above the current depth
        for d in [d for d in container_kind if d > depth]:
            del container_kind[d]
    return errors


def main() -> int:
    errors = []
    for path in sorted(SRC.rglob("*.rs")):
        if path.name == "main.rs":
            continue
        errors.extend(check_file(path))
    for e in errors:
        print(e)
    print(f"{len(errors)} potential missing_docs item(s)", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
