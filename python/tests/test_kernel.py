"""L1 correctness: Pallas kernel vs pure-jnp oracle.

The computations are exact (small-integer arithmetic in f32), so equality
is asserted with zero tolerance. Hypothesis sweeps shapes, block sizes and
densities; fixed seeds keep the suite deterministic.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import clause_eval as ce
from compile.kernels import ref


def make_problem(rng, batch, features, clauses, classes, density):
    lits = rng.integers(0, 2, (batch, 2 * features)).astype(np.float32)
    inc = (rng.random((2 * features, clauses)) < density).astype(np.float32)
    count = inc.sum(0).astype(np.float32)
    pol = np.zeros((clauses, classes), np.float32)
    for j in range(clauses):
        pol[j, j % classes] = 1.0 if (j // classes) % 2 == 0 else -1.0
    return lits, inc, count, pol


@pytest.mark.parametrize("batch,features,clauses", [
    (1, 16, 8),
    (3, 100, 37),       # nothing divides the block sizes
    (32, 784, 640),     # MNIST-shaped
    (5, 513, 257),      # just past block boundaries
    (64, 64, 1024),     # clause-heavy
])
def test_falsified_counts_matches_ref(batch, features, clauses):
    rng = np.random.default_rng(42)
    lits, inc, _, _ = make_problem(rng, batch, features, clauses, 2, 0.05)
    got = ce.falsified_counts(jnp.asarray(lits), jnp.asarray(inc))
    want = ref.falsified_counts(lits, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("batch,features,clauses,classes", [
    (1, 16, 8, 2),
    (7, 100, 37, 4),
    (32, 784, 1280, 10),  # the serving artifact shape
    (9, 300, 50, 3),
])
def test_fused_scores_match_ref(batch, features, clauses, classes):
    rng = np.random.default_rng(7)
    lits, inc, count, pol = make_problem(rng, batch, features, clauses, classes, 0.08)
    got = ce.class_scores_fused(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)
    )
    want = ref.class_scores(lits, inc, count, pol)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_empty_clause_votes_zero():
    """Standard TM convention: a clause with no includes outputs 0."""
    lits = np.ones((2, 8), np.float32)
    inc = np.zeros((8, 4), np.float32)
    inc[0, 1] = 1.0  # clause 1 includes literal 0 (true) -> clause out 1
    count = inc.sum(0).astype(np.float32)
    pol = np.ones((4, 1), np.float32)
    got = np.asarray(ce.class_scores_fused(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)))
    # only clause 1 alive and true -> score 1, empty clauses contribute 0
    np.testing.assert_array_equal(got, np.ones((2, 1), np.float32))


def test_all_literals_false_falsifies_everything():
    lits = np.zeros((3, 10), np.float32)
    inc = np.ones((10, 6), np.float32)
    count = inc.sum(0).astype(np.float32)
    pol = np.ones((6, 2), np.float32)
    got = np.asarray(ce.class_scores_fused(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)))
    np.testing.assert_array_equal(got, np.zeros((3, 2), np.float32))


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 40),
    features=st.integers(1, 300),
    clauses=st.integers(1, 300),
    classes=st.integers(1, 8),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_fused_vs_ref(batch, features, clauses, classes, density, seed):
    rng = np.random.default_rng(seed)
    lits, inc, count, pol = make_problem(rng, batch, features, clauses, classes, density)
    got = ce.class_scores_fused(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)
    )
    want = ref.class_scores(lits, inc, count, pol)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    batch=st.integers(1, 16),
    features=st.integers(1, 200),
    clauses=st.integers(1, 200),
    block_b=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([64, 128, 512]),
    block_n=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_block_size_invariance(
    batch, features, clauses, block_b, block_k, block_n, seed
):
    """Tiling must never change the numbers."""
    rng = np.random.default_rng(seed)
    lits, inc, _, _ = make_problem(rng, batch, features, clauses, 2, 0.1)
    got = ce.falsified_counts(
        jnp.asarray(lits), jnp.asarray(inc),
        block_b=block_b, block_k=block_k, block_n=block_n,
    )
    want = ref.falsified_counts(lits, inc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_weighted_polarity_matrix():
    """Weighted TMs encode ±weight in the polarity matrix; the kernel's
    vote epilogue must carry arbitrary integer magnitudes exactly."""
    rng = np.random.default_rng(21)
    lits, inc, count, pol = make_problem(rng, 9, 120, 48, 5, 0.08)
    weights = rng.integers(1, 40, 48).astype(np.float32)
    pol = pol * weights[:, None]
    got = ce.class_scores_fused(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)
    )
    want = ref.class_scores(lits, inc, count, pol)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_int_dtype_inputs_rejected_gracefully():
    """Kernel contract is f32; int inputs should either work or raise."""
    lits = np.ones((2, 8), np.int32)
    inc = np.zeros((8, 4), np.float32)
    count = inc.sum(0).astype(np.float32)
    pol = np.ones((4, 1), np.float32)
    try:
        ce.class_scores_fused(
            jnp.asarray(lits).astype(jnp.float32), jnp.asarray(inc),
            jnp.asarray(count), jnp.asarray(pol))
    except Exception as exc:  # pragma: no cover
        pytest.fail(f"f32-cast path must work: {exc}")
