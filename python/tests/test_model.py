"""L2 model-level tests: forward semantics, fused/unfused agreement, AOT."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref
from tests.test_kernel import make_problem


@pytest.mark.parametrize("batch,features,clauses,classes", [
    (4, 64, 40, 5),
    (32, 784, 1280, 10),
])
def test_fused_and_unfused_agree(batch, features, clauses, classes):
    rng = np.random.default_rng(3)
    lits, inc, count, pol = make_problem(rng, batch, features, clauses, classes, 0.06)
    a = [jnp.asarray(x) for x in (lits, inc, count, pol)]
    s1, p1 = model.tm_forward(*a)
    s2, p2 = model.tm_forward_unfused(*a)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_predictions_are_argmax_of_scores():
    rng = np.random.default_rng(11)
    lits, inc, count, pol = make_problem(rng, 16, 128, 96, 6, 0.1)
    scores, pred = model.tm_forward(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)
    )
    np.testing.assert_array_equal(
        np.asarray(pred), np.argmax(np.asarray(scores), axis=-1)
    )


def test_scores_match_oracle_end_to_end():
    rng = np.random.default_rng(5)
    lits, inc, count, pol = make_problem(rng, 8, 200, 64, 3, 0.07)
    scores, _ = model.tm_forward(
        jnp.asarray(lits), jnp.asarray(inc), jnp.asarray(count), jnp.asarray(pol)
    )
    want = ref.class_scores(lits, inc, count, pol)
    np.testing.assert_array_equal(np.asarray(scores), np.asarray(want))


def test_lower_variant_produces_hlo_text():
    text = aot.lower_variant(2, 16, 8, 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # dense contraction must survive lowering as a real dot
    assert "dot(" in text or "dot." in text


def test_lower_variant_unfused_differs():
    fused = aot.lower_variant(2, 16, 8, 2, fused=True)
    unfused = aot.lower_variant(2, 16, 8, 2, fused=False)
    assert fused != unfused


def test_manifest_consistent_with_artifacts():
    """If artifacts/ exists (built by `make artifacts`), validate it."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    with open(man_path) as fh:
        man = json.load(fh)
    assert man["format"] == "hlo-text"
    for v in man["variants"]:
        path = os.path.join(art, v["file"])
        assert os.path.exists(path), v["file"]
        with open(path) as fh:
            head = fh.read(64)
        assert "HloModule" in head
        for key in ("batch", "features", "clauses", "classes"):
            assert v[key] > 0
