"""Tests for the L1/L2 analysis tooling."""

from compile import analyze


def test_cost_analysis_produces_fields():
    # CPU cost analysis of interpret-mode Pallas under/over-counts loop
    # bodies, so only structural properties are asserted here; the
    # analytic contraction FLOPs are the authoritative L1 number.
    r = analyze.analyze_variant(8, 64, 128, 4)
    assert r["contraction_flops"] == 2.0 * 8 * (2 * 64) * 128  # 2*B*2o*n
    assert r["xla_bytes"] > 0 or r["xla_bytes"] != r["xla_bytes"]


def test_unfused_variant_is_analyzable():
    fused = analyze.analyze_variant(16, 128, 256, 4, fused=True)
    unfused = analyze.analyze_variant(16, 128, 256, 4, fused=False)
    assert fused["contraction_flops"] == unfused["contraction_flops"]
    # both lower + compile successfully and report some byte traffic
    assert unfused["xla_bytes"] > 0 or unfused["xla_bytes"] != unfused["xla_bytes"]


def test_vmem_fits_for_artifact_shapes():
    r = analyze.vmem_report(32, 784, 1280, 10)
    assert r["fits"]
    assert 0.0 < r["mxu_utilization_bound"] <= 1.0


def test_vmem_budget_enforced_for_huge_clause_axis():
    r = analyze.vmem_report(32, 784, 600_000, 10)
    assert not r["fits"]  # fused kernel contract: n bounded by VMEM
