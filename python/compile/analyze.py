"""L1/L2 performance analysis: HLO cost model + VMEM/MXU estimates.

Run after `make artifacts` to produce the §Perf numbers for Layers 1–2
(EXPERIMENTS.md). Two parts:

* **XLA cost analysis** of each lowered variant (FLOPs, bytes accessed,
  fusion count) — catches redundant recomputation and broken fusion at
  the L2 level.
* **Analytical TPU estimate** for the Pallas kernel: VMEM working set
  per grid step and MXU utilization bound from the tile shapes. The CPU
  interpret-mode wallclock is NOT a TPU proxy (DESIGN.md), so the
  real-hardware story is stated as arithmetic: bytes streamed vs FLOPs
  vs the 16 MiB VMEM budget.

Usage: cd python && python -m compile.analyze
"""

import jax
import numpy as np

from . import model
from .kernels import clause_eval


def hlo_cost(fn, *shapes):
    """Compile and return XLA's cost analysis dict."""
    lowered = jax.jit(fn).lower(*shapes)
    compiled = lowered.compile()
    try:
        return compiled.cost_analysis()
    except Exception:
        return {}


def analyze_variant(batch, features, clauses, classes, fused=True):
    args = model.example_args(batch, features, clauses, classes)
    fn = model.tm_forward if fused else model.tm_forward_unfused
    cost = hlo_cost(fn, *args)
    if isinstance(cost, list):  # some jax versions return [dict]
        cost = cost[0]
    flops = cost.get("flops", float("nan"))
    bytes_ = cost.get("bytes accessed", float("nan"))
    # analytic contraction cost: (B x 2o) @ (2o x n) MACs
    mac_flops = 2.0 * batch * 2 * features * clauses
    return {
        "name": f"b{batch}_f{features}_c{clauses}_m{classes}{'':s}"
        + ("" if fused else "_unfused"),
        "xla_flops": flops,
        "xla_bytes": bytes_,
        "contraction_flops": mac_flops,
        "flops_ratio": flops / mac_flops if mac_flops else float("nan"),
    }


def vmem_report(batch, features, clauses, classes):
    """VMEM working set + MXU bound for the fused kernel's tiling."""
    bb = clause_eval.DEFAULT_BLOCK_B
    bk = clause_eval.DEFAULT_BLOCK_K
    n = clauses
    m = classes
    f32 = 4
    lit_tile = bb * bk * f32
    inc_tile = bk * n * f32
    acc = bb * n * f32
    pol = n * m * f32
    count = n * f32
    out = bb * m * f32
    total = lit_tile + inc_tile + acc + pol + count + out
    # double-buffer the streamed operands (lit + inc)
    total_db = total + lit_tile + inc_tile
    # MXU: 128x128 systolic; utilization bound = how full the tiles are
    util_b = min(bb, 128) / 128 if bb < 128 else 1.0
    util = util_b  # k and n dims exceed 128 here
    return {
        "tile_bytes": total,
        "tile_bytes_double_buffered": total_db,
        "vmem_budget": 16 << 20,
        "fits": total_db < (16 << 20),
        "mxu_utilization_bound": util,
    }


def main():
    print("== L2: XLA cost analysis of AOT variants ==")
    for b, f, c, m, fused in [
        (32, 784, 1280, 10, True),
        (32, 784, 1280, 10, False),
        (32, 256, 512, 2, True),
    ]:
        r = analyze_variant(b, f, c, m, fused)
        print(
            f"  {r['name']:<32} xla_flops={r['xla_flops']:.3e} "
            f"contraction={r['contraction_flops']:.3e} "
            f"ratio={r['flops_ratio']:.3f} bytes={r['xla_bytes']:.3e}"
        )
    print(
        "\n  ratio ~1.0 => no redundant recompute; fused < unfused bytes =>\n"
        "  the vote epilogue stayed in registers/VMEM instead of HBM."
    )

    print("\n== L1: Pallas kernel VMEM/MXU estimate (fused variant) ==")
    for b, f, c, m in [(32, 784, 1280, 10), (32, 256, 512, 2)]:
        r = vmem_report(b, f, c, m)
        print(
            f"  b{b}_f{f}_c{c}_m{m}: tile {r['tile_bytes']/1024:.0f} KiB "
            f"(x2 buf {r['tile_bytes_double_buffered']/1024:.0f} KiB) "
            f"of {r['vmem_budget']>>20} MiB VMEM -> fits={r['fits']}, "
            f"MXU bound {r['mxu_utilization_bound']:.2f} (batch-limited)"
        )
    print(
        "\n  note: batch=32 fills 32/128 MXU rows; serve with batch>=128 on\n"
        "  real TPUs for full systolic occupancy (artifact variants are a\n"
        "  build-time knob)."
    )


if __name__ == "__main__":
    main()
