"""AOT entry point: lower the L2 model to HLO text artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
resulting ``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file``
and never touches Python again.

HLO *text* — not ``lowered.compile().serialize()`` and not a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

A ``manifest.json`` records every variant's shapes so the Rust artifact
registry can pick an executable by (batch, features, clauses, classes) and
marshal buffers without re-deriving shapes from HLO.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (name, batch, features, clauses_total, classes, fused)
# Shapes chosen for the serving demo + the backend ablation bench; the
# datasets' full 20k-clause configs run on the Rust CPU paths (that is the
# paper's own setting), the XLA backend handles the batched-serving sizes.
DEFAULT_VARIANTS = [
    ("tm_b32_f784_c1280_m10", 32, 784, 1280, 10, True),
    ("tm_b1_f784_c1280_m10", 1, 784, 1280, 10, True),
    ("tm_b32_f784_c1280_m10_unfused", 32, 784, 1280, 10, False),
    ("tm_b32_f256_c512_m2", 32, 256, 512, 2, True),
    ("tm_b8_f128_c128_m4", 8, 128, 128, 4, True),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(batch, features, clauses, classes, fused=True) -> str:
    fn = model.tm_forward if fused else model.tm_forward_unfused
    args = model.example_args(batch, features, clauses, classes)
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variant",
        action="append",
        default=None,
        metavar="NAME:B:F:C:M[:unfused]",
        help="extra variant spec; may repeat",
    )
    args = ap.parse_args()

    variants = list(DEFAULT_VARIANTS)
    for spec in args.variant or []:
        parts = spec.split(":")
        name, b, f, c, m = parts[0], *map(int, parts[1:5])
        fused = len(parts) < 6 or parts[5] != "unfused"
        variants.append((name, b, f, c, m, fused))

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "variants": []}
    for name, b, f, c, m, fused in variants:
        text = lower_variant(b, f, c, m, fused)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        manifest["variants"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "batch": b,
                "features": f,
                "clauses": c,
                "classes": m,
                "fused": fused,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote manifest with {len(manifest['variants'])} variants")


if __name__ == "__main__":
    main()
