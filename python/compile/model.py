"""Layer-2 JAX model: dense Tsetlin-Machine forward pass.

This is the compute graph the Rust runtime executes for batched inference
(the "dense vectorized baseline" of DESIGN.md and the XLA backend of the
serving coordinator). It calls the Layer-1 Pallas kernel for the
falsification contraction and adds the vote/argmax epilogue.

The TM state is passed IN as dense arrays (the Rust side owns the TA
states and densifies its include-masks when it refreshes the XLA model):

  literals  (B, 2o) f32 0/1 — batch literal values [x, ¬x]
  include   (2o, n) f32 0/1 — include-mask over all classes' clauses
  count     (n,)    f32     — included-literal count per clause
  polarity  (n, m)  f32     — ±1 at (clause, its class), 0 elsewhere

Outputs are a tuple (scores, prediction) so one executable serves both the
vote-margin path (the coordinator applies its own thresholding) and the
plain classification path.
"""

import jax.numpy as jnp

from .kernels import clause_eval


def tm_forward(literals, include, count, polarity):
    """Class scores (B, m) and argmax predictions (B,) as int32."""
    scores = clause_eval.class_scores_fused(literals, include, count, polarity)
    pred = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    return scores, pred


def tm_forward_unfused(literals, include, count, polarity):
    """Same semantics, tiled (unfused) kernel + XLA-side epilogue.

    Used for the L1 ablation (fused vs unfused) and as a fallback when the
    clause axis exceeds the fused kernel's VMEM budget.
    """
    fals = clause_eval.falsified_counts(literals, include)
    alive = count > 0.5
    out = jnp.where((fals < 0.5) & alive[None, :], 1.0, 0.0)
    scores = out @ polarity
    pred = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    return scores, pred


def example_args(batch: int, features: int, clauses_total: int, classes: int):
    """ShapeDtypeStructs for AOT lowering of either forward."""
    import jax

    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((batch, 2 * features), f32),
        jax.ShapeDtypeStruct((2 * features, clauses_total), f32),
        jax.ShapeDtypeStruct((clauses_total,), f32),
        jax.ShapeDtypeStruct((clauses_total, classes), f32),
    )
