"""Layer-1 Pallas kernel: Tsetlin-Machine clause evaluation by falsification.

The paper evaluates clauses on a CPU by walking per-literal inclusion
*lists* — pointer chasing that a TPU cannot express. The same insight
("count falsifying literals; a clause is true iff the count is zero") maps
onto the MXU as a dense contraction:

    falsified[b, j] = sum_k include[k, j] * (1 - literal[b, k])

which is a (B, 2o) x (2o, n) matmul over the 0/1 include-mask. This kernel
tiles that contraction for VMEM:

  * grid = (B/Bb, n/Bn, 2o/Bk); the k axis is innermost so each (i, j)
    output tile stays resident in VMEM across the whole reduction —
    falsification counts never round-trip to HBM mid-reduction.
  * the literal tile (Bb, Bk) and include tile (Bk, Bn) stream through
    VMEM; with the default blocks the working set is
    Bb*Bk + Bk*Bn + Bb*Bn floats = (32*512 + 512*256 + 32*256)*4B ≈ 0.6 MiB,
    comfortably inside the ~16 MiB VMEM budget with room for
    double-buffering the streamed operands.
  * on a real MXU the operands would be bf16 with f32 accumulation; counts
    are small integers (≤ 2o ≤ 40000) so f32 accumulation is exact.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is validated against ``ref.py`` and the real-TPU
performance story is an analytical estimate (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default VMEM tile shape. Bk is the streamed reduction depth; Bb x Bn is
# the resident accumulator tile.
DEFAULT_BLOCK_B = 32
DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_K = 512


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _falsify_kernel(lit_ref, inc_ref, out_ref):
    """One (i, j, k) grid step: accumulate falsification counts.

    out_ref is the (Bb, Bn) accumulator tile, revisited for every k; the
    first k step zero-initialises it.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Complement of the literal tile: 1 where the literal is FALSE.
    comp = 1.0 - lit_ref[...]
    out_ref[...] += jnp.dot(comp, inc_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "block_k"))
def falsified_counts(
    literals,
    include,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
):
    """(B, 2o) literals x (2o, n) include-mask -> (B, n) falsified counts.

    Shapes need not be multiples of the block sizes; inputs are
    zero-padded. Padding is semantically inert: padded literal columns are
    set to 1 (a true literal never falsifies) and padded include
    rows/columns are 0.
    """
    b, k = literals.shape
    k2, n = include.shape
    assert k == k2, f"literal width {k} != include rows {k2}"

    bp, kp, np_ = _ceil_to(b, block_b), _ceil_to(k, block_k), _ceil_to(n, block_n)
    lit_p = jnp.pad(literals, ((0, bp - b), (0, kp - k)), constant_values=1.0)
    inc_p = jnp.pad(include, ((0, kp - k), (0, np_ - n)))

    grid = (bp // block_b, np_ // block_n, kp // block_k)
    out = pl.pallas_call(
        _falsify_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=True,
    )(lit_p, inc_p)
    return out[:b, :n]


def _fused_kernel(lit_ref, inc_ref, count_ref, pol_ref, out_ref, acc_ref):
    """Fused variant: falsify + threshold + vote, one kernel.

    Grid = (B/Bb, 2o/Bk) — the clause axis is NOT tiled (whole rows of the
    include-mask stream through), so the ==0 epilogue and the polarity
    vote run per batch-tile without clause outputs ever touching HBM.
    ``acc_ref`` is the (Bb, n) VMEM scratch accumulator.
    """
    k = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    comp = 1.0 - lit_ref[...]
    acc_ref[...] += jnp.dot(comp, inc_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        alive = count_ref[...] > 0.5
        clause_out = jnp.where((acc_ref[...] < 0.5) & alive[None, :], 1.0, 0.0)
        out_ref[...] = jnp.dot(
            clause_out, pol_ref[...], preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("block_b", "block_k"))
def class_scores_fused(
    literals,
    include,
    count,
    polarity,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_k: int = DEFAULT_BLOCK_K,
):
    """(B, m) class scores with the vote epilogue fused into the kernel.

    Clause outputs live only in VMEM scratch — the paper's "don't
    materialise per-clause work" idea, TPU edition. Applicable while
    Bb * n * 4B fits VMEM alongside the streamed tiles (n ≤ ~64k).
    """
    b, k = literals.shape
    k2, n = include.shape
    m = polarity.shape[1]
    assert k == k2 and polarity.shape[0] == n and count.shape == (n,)

    bp, kp = _ceil_to(b, block_b), _ceil_to(k, block_k)
    lit_p = jnp.pad(literals, ((0, bp - b), (0, kp - k)), constant_values=1.0)
    inc_p = jnp.pad(include, ((0, kp - k), (0, 0)))

    grid = (bp // block_b, kp // block_k)
    out = pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, kk: (i, kk)),
            pl.BlockSpec((block_k, n), lambda i, kk: (kk, 0)),
            pl.BlockSpec((n,), lambda i, kk: (0,)),
            pl.BlockSpec((n, m), lambda i, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, n), jnp.float32)],
        interpret=True,
    )(lit_p, inc_p, count, polarity)
    return out[:b, :]
