"""Pure-jnp oracle for the clause-evaluation kernel.

This is the CORE correctness signal for Layer 1: the Pallas kernel in
``clause_eval.py`` must agree with these functions exactly (they are exact
small-integer computations carried in f32, so ``assert_allclose`` with
rtol=0 is appropriate).

Semantics (paper §2–§3, re-expressed densely — see DESIGN.md
§Hardware-Adaptation):

  falsified[b, j] = sum_k include[k, j] * (1 - literal[b, k])
  clause_out[b, j] = 1  iff  falsified[b, j] == 0 and count[j] > 0
  score[b, i]     = sum_j polarity[j, i] * clause_out[b, j]

``include`` is the dense (2o, n_total) 0/1 include-mask — the transpose of
the paper's inclusion lists. ``count[j]`` is the number of included
literals of clause j; empty clauses vote 0 at inference time (standard TM
convention). ``polarity`` is (n_total, m) with +1/-1 at (j, class(j)) and 0
elsewhere, so the vote reduction is a second matmul.
"""

import jax.numpy as jnp


def falsified_counts(literals, include):
    """(B, 2o) x (2o, n) -> (B, n) count of included-but-false literals."""
    return (1.0 - literals) @ include


def clause_outputs(literals, include, count):
    """0/1 clause outputs with the empty-clause-votes-zero convention."""
    fals = falsified_counts(literals, include)
    alive = count > 0.5
    return jnp.where((fals < 0.5) & alive[None, :], 1.0, 0.0)


def class_scores(literals, include, count, polarity):
    """(B, m) class vote sums — the quantity eq. (3) argmaxes over."""
    out = clause_outputs(literals, include, count)
    return out @ polarity


def predict(literals, include, count, polarity):
    return jnp.argmax(class_scores(literals, include, count, polarity), axis=-1)
