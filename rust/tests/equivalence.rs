//! Cross-backend equivalence — the paper's implicit correctness claim:
//! indexed evaluation computes *exactly* what exhaustive evaluation
//! computes, during inference and throughout training.
//!
//! These are property tests driven by the crate's deterministic RNG
//! (the offline build has no proptest; the loops below shrink nothing
//! but cover the same invariant space with fixed seeds).

use tsetlin_index::data::synth::{bow, image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::traits::{reference_score, FlipSink};
use tsetlin_index::eval::{Backend, Evaluator};
use tsetlin_index::index::IndexedEval;
use tsetlin_index::tm::bank::{ClauseBank, Flip};
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

/// Property: for arbitrary machines and inputs, all three evaluators
/// agree with the reference semantics (inference + training modes).
#[test]
fn property_all_evaluators_match_reference() {
    let mut rng = Rng::new(2024);
    for trial in 0..200 {
        let clauses = 2 * (1 + rng.below(12) as usize);
        let features = 1 + rng.below(60) as usize;
        let n_lit = 2 * features;
        let density = rng.unit_f64() * 0.4;
        let mut bank = ClauseBank::new(clauses, n_lit);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    bank.set_state(j, k, (rng.below(11) as i8) - 5);
                }
            }
        }
        let params = TMParams::new(2, clauses, features);
        let p_true = rng.unit_f64();
        let lits = BitVec::from_bools(
            &(0..n_lit).map(|_| rng.bern(p_true)).collect::<Vec<_>>(),
        );
        let want_inf = reference_score(&bank, &lits, false);
        let want_train = reference_score(&bank, &lits, true);
        for backend in Backend::ALL {
            let mut ev = backend.make(&params);
            ev.rebuild(&bank);
            assert_eq!(
                ev.score(&bank, &lits),
                want_inf,
                "inference {} trial {trial}",
                backend.name()
            );
            let mut out = BitVec::zeros(clauses);
            assert_eq!(
                ev.eval_train(&bank, &lits, &mut out),
                want_train,
                "training {} trial {trial}",
                backend.name()
            );
        }
    }
}

/// Property: the index survives arbitrary flip sequences with all
/// invariants intact (list/matrix bijection, counts, vote baselines).
#[test]
fn property_index_invariants_under_flip_storm() {
    let mut rng = Rng::new(77);
    for trial in 0..20 {
        let clauses = 2 * (2 + rng.below(8) as usize);
        let features = 2 + rng.below(30) as usize;
        let n_lit = 2 * features;
        let mut bank = ClauseBank::new(clauses, n_lit);
        let params = TMParams::new(2, clauses, features);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        for _ in 0..3000 {
            let j = rng.below(clauses as u32) as usize;
            let k = rng.below(n_lit as u32) as usize;
            if rng.bern(0.55) {
                if bank.bump_up(j, k) == Flip::Included {
                    ev.on_include(j as u32, k as u32, bank.count(j), bank.weight(j));
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                ev.on_exclude(j as u32, k as u32, bank.count(j), bank.weight(j));
            }
        }
        ev.index()
            .check_invariants(&bank)
            .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
    }
}

/// End-to-end: full training runs on realistic workloads produce
/// bit-identical machines across backends, epoch by epoch.
fn assert_identical_training(train: &Dataset, total_clauses: usize, epochs: usize) {
    let params = TMParams::from_total_clauses(train.classes, total_clauses, train.features)
        .with_threshold(15)
        .with_s(4.5)
        .with_seed(99);
    let mut trainers: Vec<Trainer> = Backend::ALL
        .iter()
        .map(|&b| Trainer::new(params.clone(), b))
        .collect();
    for epoch in 0..epochs {
        for tr in trainers.iter_mut() {
            let mut order_rng = Rng::new(500 + epoch as u64);
            let order = train.epoch_order(&mut order_rng);
            tr.train_epoch(train.iter_order(&order));
        }
        for i in 0..train.classes {
            let s0 = trainers[0].tm.bank(i).states();
            for tr in &trainers[1..] {
                assert_eq!(
                    s0,
                    tr.tm.bank(i).states(),
                    "epoch {epoch} class {i}: {} diverged from {}",
                    tr.backend().name(),
                    trainers[0].backend().name()
                );
            }
        }
    }
    for tr in &trainers {
        tr.check_invariants().unwrap();
    }
}

#[test]
fn training_identical_on_image_workload() {
    let train = image_dataset(ImageStyle::Digits, 4, 150, 2, 31);
    assert_identical_training(&train, 80, 3);
}

#[test]
fn training_identical_on_bow_workload() {
    let train = bow(800, 120, 32);
    assert_identical_training(&train, 60, 3);
}

/// Inference agreement on trained (not random) machines — clause
/// structure after training is adversarial in its own way (correlated
/// literals, empty clauses, saturated TAs).
#[test]
fn trained_machine_inference_agreement() {
    let all = image_dataset(ImageStyle::Fashion, 3, 260, 1, 33);
    let train = all.slice(0, 200);
    let test = all.slice(200, 260);
    let params = TMParams::from_total_clauses(3, 90, train.features).with_seed(5);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(1);
    for _ in 0..4 {
        let order = train.epoch_order(&mut order_rng);
        tr.train_epoch(train.iter_order(&order));
    }
    let mut naive = Trainer::from_machine(tr.tm.clone(), Backend::Naive);
    let mut packed = Trainer::from_machine(tr.tm.clone(), Backend::BitPacked);
    for (lits, _) in test.iter() {
        let s = tr.scores(lits);
        assert_eq!(s, naive.scores(lits));
        assert_eq!(s, packed.scores(lits));
    }
}
