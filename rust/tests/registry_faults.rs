//! Fault-injection harness for the durable registry and the serving
//! supervisor: every scenario either recovers or quarantines — never a
//! panic — surviving routes keep serving, and a recovered route scores
//! bit-identically to what was published.
//!
//! Covered faults: truncated snapshot, bit-flipped snapshot,
//! half-written manifest, worker panic mid-swap, kill -9 of a serving
//! process followed by restart.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::Command;

use tsetlin_index::coordinator::server::fault;
use tsetlin_index::coordinator::{BatchPolicy, Coordinator, RouteConfig};
use tsetlin_index::engine::{InferMode, ModelSnapshot};
use tsetlin_index::eval::Backend;
use tsetlin_index::obs::journal;
use tsetlin_index::registry::{Registry, RegistryError};
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

fn temp_registry(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tmi-faults-{tag}-{}-{}",
        std::process::id(),
        std::thread::current().name().unwrap_or("t").replace("::", "-")
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn trained(seed: u64) -> MultiClassTM {
    let params = TMParams::new(2, 8, 10).with_seed(seed);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut rng = Rng::new(seed ^ 0xfau64);
    let samples: Vec<(BitVec, usize)> = (0..100)
        .map(|_| {
            let y = rng.bern(0.5) as usize;
            let bits: Vec<bool> = (0..10)
                .map(|k| if k == 0 { y == 1 } else { rng.bern(0.4) })
                .collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            (BitVec::from_bools(&lits), y)
        })
        .collect();
    for _ in 0..3 {
        tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
    }
    tr.tm
}

#[test]
fn truncated_snapshot_falls_back_to_prior_version_bit_identically() {
    let dir = temp_registry("trunc");
    let v1_model = trained(11);
    let v2_model = trained(12);
    let v1_digest = io::model_digest(&v1_model);
    {
        let mut reg = Registry::open(&dir, 4).unwrap();
        assert_eq!(reg.publish("cpu", &v1_model, InferMode::Auto).unwrap(), 1);
        assert_eq!(reg.publish("cpu", &v2_model, InferMode::Auto).unwrap(), 2);
    }
    // tear the newest snapshot as a crash mid-write would
    let v2_file = dir.join("cpu/v000002.tm");
    let bytes = std::fs::read(&v2_file).unwrap();
    std::fs::write(&v2_file, &bytes[..bytes.len() / 2]).unwrap();

    let mut reg = Registry::open(&dir, 4).unwrap();
    let rec = reg.load_published("cpu").unwrap();
    assert_eq!(rec.version, 1);
    assert_eq!(rec.quarantined, vec![2]);
    assert_eq!(
        io::model_digest(&rec.tm),
        v1_digest,
        "recovered model must be bit-identical to what was published"
    );
    assert!(
        dir.join("quarantine/cpu-v000002.tm").exists(),
        "torn file must be quarantined, not deleted"
    );
    // the quarantine is also journaled as a typed event, so a serving
    // process surfaces it through `stats events <model>`
    assert!(
        journal()
            .events_for("cpu")
            .iter()
            .any(|e| e.kind.name() == "quarantine" && e.to_line().contains("version=2")),
        "quarantining v2 must leave a journal event"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bit_flipped_only_version_is_typed_error_and_other_routes_survive() {
    let dir = temp_registry("flip");
    {
        let mut reg = Registry::open(&dir, 4).unwrap();
        reg.publish("broken", &trained(21), InferMode::Auto).unwrap();
        reg.publish("healthy", &trained(22), InferMode::Auto).unwrap();
    }
    let f = dir.join("broken/v000001.tm");
    let mut bytes = std::fs::read(&f).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&f, &bytes).unwrap();

    let mut reg = Registry::open(&dir, 4).unwrap();
    match reg.load_published("broken") {
        Err(RegistryError::NoIntactVersion(route)) => assert_eq!(route, "broken"),
        other => panic!("expected NoIntactVersion, got {other:?}"),
    }
    // the sibling route is untouched by the quarantine
    let rec = reg.load_published("healthy").unwrap();
    assert_eq!(rec.version, 1);
    assert!(rec.quarantined.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn half_written_manifest_recovers_from_backup() {
    let dir = temp_registry("manifest");
    let digest = {
        let model = trained(31);
        let mut reg = Registry::open(&dir, 4).unwrap();
        reg.publish("cpu", &model, InferMode::Auto).unwrap();
        // second publish demotes the first manifest to .bak
        reg.publish("cpu", &model, InferMode::Auto).unwrap();
        io::model_digest(&model)
    };
    // simulate a crash mid-rewrite: truncate the live manifest
    let live = dir.join("manifest.json");
    let text = std::fs::read(&live).unwrap();
    std::fs::write(&live, &text[..text.len() / 2]).unwrap();

    let mut reg = Registry::open(&dir, 4).unwrap();
    let rec = reg.load_published("cpu").unwrap();
    assert_eq!(
        io::model_digest(&rec.tm),
        digest,
        "backup manifest must recover the published route"
    );
    // reopening healed the live manifest from the backup
    let reg2 = Registry::open(&dir, 4).unwrap();
    assert_eq!(reg2.generation(), reg.generation());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn worker_panic_mid_swap_restarts_and_keeps_serving() {
    let tm = trained(41);
    let snap = std::sync::Arc::new(ModelSnapshot::with_mode(tm, 1, InferMode::Auto));
    let features: Vec<bool> = (0..10).map(|k| k == 0).collect();
    let mut coord = Coordinator::new();
    coord.register_model(
        "midswap",
        snap,
        RouteConfig {
            policy: BatchPolicy::default(),
            workers: 1,
            queue_cap: 64,
            ..RouteConfig::default()
        },
    );
    let h = coord.handle();
    let want = h.infer_features("midswap", &features).unwrap().scores;

    fault::arm_worker_panics("midswap", 1);
    // the batch that takes the injected panic fails its client...
    assert!(h.infer_features("midswap", &features).is_err());
    // ...and the supervised worker restarts: same scores, restart counted
    assert_eq!(h.infer_features("midswap", &features).unwrap().scores, want);
    let st = coord.stats("midswap").unwrap();
    assert_eq!(st.metrics.restarts, 1);
    coord.shutdown();
}

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

fn free_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().port()
}

/// Ask one infer over TCP, retrying until the server is up; returns the
/// full reply line.
fn infer_once(addr: &str, line: &str) -> String {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while std::time::Instant::now() < deadline {
        if let Ok(conn) = std::net::TcpStream::connect(addr) {
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut conn = conn;
            if conn.write_all(line.as_bytes()).is_ok() {
                let mut reply = String::new();
                if reader.read_line(&mut reply).is_ok() && reply.starts_with("ok ") {
                    return reply;
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    panic!("server at {addr} never answered '{}'", line.trim());
}

#[test]
fn kill_nine_then_restart_serves_identical_scores() {
    let dir = temp_registry("kill9");
    // publish through the real CLI: train -> registry
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "120", "--clauses", "80",
            "--epochs", "1", "--registry", dir.to_str().unwrap(), "--route", "cpu",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --registry failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let request = format!("infer cpu {}\n", "10".repeat(392)); // 784 features
    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi()
        .args(["serve", "--registry", dir.to_str().unwrap(), "--listen", &addr])
        .spawn()
        .unwrap();
    let before = infer_once(&addr, &request);

    // hard-kill the serving process: no drain, no manifest flush
    server.kill().unwrap();
    server.wait().unwrap();

    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi()
        .args(["serve", "--registry", dir.to_str().unwrap(), "--listen", &addr])
        .spawn()
        .unwrap();
    let after = infer_once(&addr, &request);
    assert_eq!(
        before, after,
        "restarted server must score bit-identically from the manifest alone"
    );
    server.kill().unwrap();
    server.wait().unwrap();

    // and the registry itself still verifies clean
    let out = tmi()
        .args(["registry", "verify", "--registry", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "registry verify failed: {}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
