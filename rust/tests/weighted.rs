//! Weighted TM (paper ref [8]) integration: the clause-weight extension
//! composes with the index — weighted vote baselines stay consistent
//! under training, all backends agree, and fewer weighted clauses match
//! the accuracy of more unweighted ones (the reference's compression
//! claim, qualitatively).

use tsetlin_index::data::synth::{bow, image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

fn train(params: TMParams, backend: Backend, data: &Dataset, epochs: usize) -> Trainer {
    let mut tr = Trainer::new(params, backend);
    let mut order_rng = Rng::new(7);
    for _ in 0..epochs {
        let order = data.epoch_order(&mut order_rng);
        tr.train_epoch(data.iter_order(&order));
    }
    tr
}

#[test]
fn weighted_backends_train_identically() {
    let data = image_dataset(ImageStyle::Digits, 3, 120, 1, 41);
    let params = TMParams::from_total_clauses(3, 60, data.features)
        .with_weighted(true)
        .with_seed(3);
    let trainers: Vec<Trainer> = Backend::ALL
        .iter()
        .map(|&b| train(params.clone(), b, &data, 3))
        .collect();
    for i in 0..3 {
        let b0 = trainers[0].tm.bank(i);
        for tr in &trainers[1..] {
            assert_eq!(b0.states(), tr.tm.bank(i).states(), "class {i} states");
            assert_eq!(b0.weights(), tr.tm.bank(i).weights(), "class {i} weights");
        }
    }
    for tr in &trainers {
        tr.check_invariants().unwrap();
    }
}

#[test]
fn weights_actually_move_and_scores_agree() {
    let data = bow(400, 150, 42);
    let params = TMParams::from_total_clauses(2, 40, data.features)
        .with_weighted(true)
        .with_seed(5);
    let mut tr = train(params, Backend::Indexed, &data, 5);
    let moved = (0..2)
        .flat_map(|i| tr.tm.bank(i).weights().to_vec())
        .filter(|&w| w > 1)
        .count();
    assert!(moved > 0, "training should grow some clause weights");
    tr.check_invariants().unwrap();

    // weighted scores agree across backends at inference time
    let mut naive = Trainer::from_machine(tr.tm.clone(), Backend::Naive);
    let mut packed = Trainer::from_machine(tr.tm.clone(), Backend::BitPacked);
    for (lits, _) in data.iter().take(50) {
        let s = tr.scores(lits);
        assert_eq!(s, naive.scores(lits));
        assert_eq!(s, packed.scores(lits));
    }
}

#[test]
fn weighted_save_load_preserves_weights() {
    let data = bow(300, 120, 43);
    let params = TMParams::from_total_clauses(2, 30, data.features)
        .with_weighted(true)
        .with_seed(8);
    let tr = train(params, Backend::Indexed, &data, 4);
    let mut buf = Vec::new();
    io::save_to(&tr.tm, &mut buf).unwrap();
    let tm2 = io::load_from(&mut buf.as_slice()).unwrap();
    assert!(tm2.params.weighted);
    for i in 0..2 {
        assert_eq!(tr.tm.bank(i).weights(), tm2.bank(i).weights());
        assert_eq!(tr.tm.bank(i).states(), tm2.bank(i).states());
    }
}

#[test]
fn weighted_matches_unweighted_accuracy_with_fewer_clauses() {
    // Compression claim (qualitative): a weighted TM with n/2 clauses
    // should be in the same accuracy band as a plain TM with n.
    let all = bow(600, 500, 44);
    let train_set = all.slice(0, 350);
    let test_set = all.slice(350, 500);
    let plain = TMParams::from_total_clauses(2, 80, all.features).with_seed(11);
    let weighted = TMParams::from_total_clauses(2, 40, all.features)
        .with_weighted(true)
        .with_seed(11);
    let mut plain_tr = train(plain, Backend::Indexed, &train_set, 6);
    let mut weighted_tr = train(weighted, Backend::Indexed, &train_set, 6);
    let acc_plain = plain_tr.accuracy(test_set.iter());
    let acc_weighted = weighted_tr.accuracy(test_set.iter());
    assert!(
        acc_weighted >= acc_plain - 0.12,
        "weighted/40 {acc_weighted} vs plain/80 {acc_plain}"
    );
}

#[test]
fn plain_tm_weights_stay_at_one() {
    let data = image_dataset(ImageStyle::Digits, 2, 80, 1, 45);
    let params = TMParams::from_total_clauses(2, 20, data.features).with_seed(2);
    let tr = train(params, Backend::Indexed, &data, 3);
    for i in 0..2 {
        assert!(tr.tm.bank(i).weights().iter().all(|&w| w == 1));
    }
}
