//! Graceful shutdown e2e: SIGTERM a serving `tmi` process mid-load and
//! assert it stops accepting, drains, and exits 0 — and that every
//! reply clients did receive is well-formed (no torn writes).
#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::process::Command;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

#[test]
fn sigterm_mid_load_drains_and_exits_zero() {
    let dir = std::env::temp_dir().join(format!("tmi-shutdown-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "120", "--clauses", "80",
            "--epochs", "1", "--registry", dir.to_str().unwrap(), "--route", "cpu",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --registry failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi()
        .args(["serve", "--registry", dir.to_str().unwrap(), "--listen", &addr])
        .spawn()
        .unwrap();

    // wait for readiness
    let request = format!("infer cpu {}\n", "01".repeat(392));
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let mut ready = false;
    while std::time::Instant::now() < deadline {
        if let Ok(conn) = std::net::TcpStream::connect(&addr) {
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut conn = conn;
            if conn.write_all(request.as_bytes()).is_ok() {
                let mut reply = String::new();
                if reader.read_line(&mut reply).is_ok() && reply.starts_with("ok ") {
                    ready = true;
                    break;
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    assert!(ready, "server never became ready");

    // sustained load from several closed-loop clients
    let run = Arc::new(AtomicBool::new(true));
    let clients: Vec<_> = (0..4)
        .map(|_| {
            let addr = addr.clone();
            let request = request.clone();
            let run = Arc::clone(&run);
            std::thread::spawn(move || {
                let (mut replies, mut malformed) = (0u64, 0u64);
                'outer: while run.load(Ordering::Relaxed) {
                    let Ok(conn) = std::net::TcpStream::connect(&addr) else {
                        break; // listener gone: shutdown in progress
                    };
                    let mut reader = BufReader::new(conn.try_clone().unwrap());
                    let mut conn = conn;
                    while run.load(Ordering::Relaxed) {
                        if conn.write_all(request.as_bytes()).is_err() {
                            continue 'outer;
                        }
                        let mut reply = String::new();
                        match reader.read_line(&mut reply) {
                            Ok(0) | Err(_) => continue 'outer, // server closed
                            Ok(_) => {
                                replies += 1;
                                // every received reply must be complete
                                if !(reply.ends_with('\n')
                                    && (reply.starts_with("ok ") || reply.starts_with("err ")))
                                {
                                    malformed += 1;
                                }
                            }
                        }
                    }
                }
                (replies, malformed)
            })
        })
        .collect();

    // let the load ramp, then SIGTERM the server mid-flight
    std::thread::sleep(std::time::Duration::from_millis(300));
    let kill = Command::new("kill")
        .args(["-TERM", &server.id().to_string()])
        .status()
        .unwrap();
    assert!(kill.success(), "kill -TERM failed");

    // the server must exit on its own, with status 0
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let status = loop {
        if let Some(st) = server.try_wait().unwrap() {
            break st;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "server did not exit after SIGTERM"
        );
        std::thread::sleep(std::time::Duration::from_millis(25));
    };
    assert!(status.success(), "expected exit 0, got {status:?}");

    run.store(false, Ordering::Relaxed);
    let (mut replies, mut malformed) = (0u64, 0u64);
    for c in clients {
        let (r, m) = c.join().unwrap();
        replies += r;
        malformed += m;
    }
    assert!(replies > 0, "load never reached the server");
    assert_eq!(malformed, 0, "torn replies during shutdown");
    std::fs::remove_dir_all(&dir).unwrap();
}
