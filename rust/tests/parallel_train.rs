//! Clause-sharded parallel training — the subsystem's correctness
//! contract:
//!
//! 1. one worker == the sequential trainer, **bit-identically** (same
//!    RNG seeding contract, same feedback body);
//! 2. after multi-threaded epochs every index invariant holds and the
//!    rebuilt class-fused serving engine scores exactly what a fresh
//!    per-class indexed evaluation of the trained banks scores;
//! 3. asynchronous (stale-vote-sum) training reaches sequential-level
//!    accuracy on noisy XOR — the arXiv 2009.04861 claim.

use tsetlin_index::data::synth::noisy_xor;
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::{Backend, Evaluator};
use tsetlin_index::index::IndexedEval;
use tsetlin_index::parallel::ParallelTrainer;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

fn xor_params(clauses: usize) -> TMParams {
    TMParams::new(2, clauses, 12)
        .with_threshold(15)
        .with_s(3.9)
        .with_seed(11)
}

fn train_both(
    epochs: usize,
    data: &Dataset,
    params: &TMParams,
    threads: usize,
    window: usize,
) -> (Trainer, ParallelTrainer) {
    let mut seq = Trainer::new(params.clone(), Backend::Indexed);
    let mut par = ParallelTrainer::new(params.clone(), threads).with_stale_window(window);
    let mut order_rng_a = Rng::new(77);
    let mut order_rng_b = Rng::new(77);
    for _ in 0..epochs {
        let order_a = data.epoch_order(&mut order_rng_a);
        let order_b = data.epoch_order(&mut order_rng_b);
        assert_eq!(order_a, order_b);
        seq.train_epoch(data.iter_order(&order_a));
        par.train_epoch(data.iter_order(&order_b));
    }
    (seq, par)
}

#[test]
fn one_worker_is_bit_identical_to_sequential() {
    let params = xor_params(20);
    let data = noisy_xor(12, 300, 0.1, 5);
    let (seq, mut par) = train_both(3, &data, &params, 1, 1);
    for c in 0..2 {
        assert_eq!(
            seq.tm.bank(c).states(),
            par.tm().bank(c).states(),
            "class {c} TA states diverge at 1 worker"
        );
        assert_eq!(seq.tm.bank(c).weights(), par.tm().bank(c).weights());
    }
    seq.check_invariants().unwrap();
    par.check_invariants().unwrap();
}

#[test]
fn one_worker_bit_identity_holds_for_weighted_tm() {
    let params = xor_params(16).with_weighted(true);
    let data = noisy_xor(12, 200, 0.1, 6);
    let (seq, mut par) = train_both(2, &data, &params, 1, 1);
    for c in 0..2 {
        assert_eq!(seq.tm.bank(c).states(), par.tm().bank(c).states());
        assert_eq!(
            seq.tm.bank(c).weights(),
            par.tm().bank(c).weights(),
            "class {c} clause weights diverge at 1 worker (weighted TM)"
        );
    }
    par.check_invariants().unwrap();
}

#[test]
fn stale_window_is_inert_for_one_worker() {
    // a single worker always runs sequential-consistent (window 1),
    // whatever window was requested
    let params = xor_params(16);
    let data = noisy_xor(12, 200, 0.1, 7);
    let (_, par_a) = train_both(2, &data, &params, 1, 1);
    let (_, par_b) = train_both(2, &data, &params, 1, 64);
    for c in 0..2 {
        assert_eq!(par_a.tm().bank(c).states(), par_b.tm().bank(c).states());
    }
}

#[test]
fn multithread_epoch_preserves_invariants_and_fused_scores() {
    let params = TMParams::new(4, 24, 10).with_threshold(12).with_seed(21);
    // 4-class toy: label = 2*x0 + x1 with distractors, learnable enough
    // to drive plenty of flips through the shard indexes
    let mut rng = Rng::new(31);
    let rows: Vec<Vec<bool>> = (0..400)
        .map(|_| (0..10).map(|_| rng.bern(0.5)).collect())
        .collect();
    let labels: Vec<usize> = rows
        .iter()
        .map(|r| 2 * (r[0] as usize) + r[1] as usize)
        .collect();
    let data = Dataset::from_rows("toy4", 10, 4, &rows, labels);

    for threads in [2usize, 3] {
        let mut par = ParallelTrainer::new(params.clone(), threads).with_stale_window(8);
        for _ in 0..3 {
            par.train_epoch(data.iter());
        }
        // (b1) every structural invariant: global per-class indexes,
        // per-shard indexes, shard/global bank agreement
        par.check_invariants()
            .unwrap_or_else(|e| panic!("{threads} threads: {e}"));

        // (b2) the rebuilt fused engine == fresh per-class indexed
        // evaluation of the trained banks
        for i in 0..40 {
            let got = par.scores(data.literals(i));
            let mut want = vec![0i32; 4];
            for (c, slot) in want.iter_mut().enumerate() {
                let bank = par.tm().bank(c);
                let mut ev = IndexedEval::with_shape(bank.clauses(), 20);
                ev.rebuild(bank);
                *slot = ev.score(bank, data.literals(i));
            }
            assert_eq!(got, want, "{threads} threads, sample {i}");
        }
    }
}

#[test]
fn multithread_training_is_deterministic() {
    // the tally is a sum of per-shard integer partials (order-free) and
    // feedback reads it only after the window barrier, so even
    // multi-thread runs are exactly reproducible given seed, data
    // order, thread count, and window
    let params = xor_params(16);
    let data = noisy_xor(12, 400, 0.1, 8);
    let run = || {
        let mut par = ParallelTrainer::new(params.clone(), 3).with_stale_window(8);
        for _ in 0..2 {
            par.train_epoch(data.iter());
        }
        (
            par.tm().bank(0).states().to_vec(),
            par.tm().bank(1).states().to_vec(),
        )
    };
    assert_eq!(run(), run());
}

#[test]
fn async_training_matches_sequential_accuracy_on_noisy_xor() {
    let params = xor_params(20);
    let train = noisy_xor(12, 4000, 0.15, 1);
    let test = noisy_xor(12, 1500, 0.0, 2);
    let (mut seq, mut par) = train_both(20, &train, &params, 2, 8);
    let acc_seq = seq.accuracy(test.iter());
    let acc_par = par.accuracy(test.iter());
    assert!(acc_seq > 0.95, "sequential accuracy {acc_seq}");
    assert!(acc_par > 0.95, "parallel accuracy {acc_par}");
    assert!(
        (acc_seq - acc_par).abs() <= 0.015,
        "stale vote sums cost accuracy: seq {acc_seq} vs par {acc_par}"
    );
    par.check_invariants().unwrap();
}

#[test]
fn saved_parallel_model_serves_like_sequentially_loaded_one() {
    // end-to-end: parallel-train, reassemble, move the machine into a
    // plain trainer on a different backend — predictions must carry over
    let params = xor_params(16);
    let data = noisy_xor(12, 800, 0.1, 3);
    let mut par = ParallelTrainer::new(params, 3).with_stale_window(4);
    for _ in 0..8 {
        par.train_epoch(data.iter());
    }
    let probe = noisy_xor(12, 100, 0.0, 4);
    let from_par: Vec<usize> = (0..probe.len()).map(|i| par.predict(probe.literals(i))).collect();
    let mut naive = Trainer::from_machine(par.tm().clone(), Backend::Naive);
    let from_naive: Vec<usize> =
        (0..probe.len()).map(|i| naive.predict(probe.literals(i))).collect();
    assert_eq!(from_par, from_naive);
}
