//! Observability integration: the counter invariant
//! `requests == completed + shed + errors` across every request
//! outcome, the extended `stats` / `stats events` protocol verbs,
//! Prometheus exposition conformance (TCP verb and HTTP scrape), and
//! stats/exposition consistency under a hot-swap storm.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsetlin_index::coordinator::backend::Scored;
use tsetlin_index::coordinator::server::{fault, serve_metrics_http, serve_tcp, serve_tcp_with};
use tsetlin_index::coordinator::{BatchPolicy, Coordinator, RouteConfig, ServeBackend, ServeOptions};
use tsetlin_index::eval::Backend;
use tsetlin_index::obs::journal;
use tsetlin_index::obs::prometheus::validate_exposition;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

/// Small random-but-learnable trainer (same shape as `serve_e2e`).
fn quick_trainer(seed: u64) -> Trainer {
    let params = TMParams::new(3, 16, 24).with_seed(seed).with_threshold(12);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let samples: Vec<(BitVec, usize)> = (0..250)
        .map(|_| {
            let y = rng.below(3) as usize;
            let bits: Vec<bool> = (0..24).map(|k| k % 3 == y || rng.bern(0.25)).collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            (BitVec::from_bools(&lits), y)
        })
        .collect();
    for _ in 0..3 {
        tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
    }
    tr
}

fn random_probe(rng: &mut Rng, features: usize) -> BitVec {
    let bits: Vec<bool> = (0..features).map(|_| rng.bern(0.4)).collect();
    let mut lits = bits.clone();
    lits.extend(bits.iter().map(|b| !b));
    BitVec::from_bools(&lits)
}

/// Parse one `key=value` token out of a stats line.
fn kv_u64(line: &str, key: &str) -> u64 {
    kv(line, key).parse().unwrap_or_else(|_| panic!("{key} not a u64 in: {line}"))
}

fn kv_f64(line: &str, key: &str) -> f64 {
    kv(line, key).parse().unwrap_or_else(|_| panic!("{key} not a f64 in: {line}"))
}

fn kv<'a>(line: &'a str, key: &str) -> &'a str {
    line.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .find(|(k, _)| *k == key)
        .unwrap_or_else(|| panic!("missing {key}= in: {line}"))
        .1
}

/// Poll until `cond` holds (probe flushes are batch-wise, so counter
/// equality can land a moment after the last reply).
fn settle(mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while !cond() {
        assert!(Instant::now() < deadline, "condition never settled");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A backend slow enough to saturate a tiny queue (shedding driver).
struct SlowBackend;

impl ServeBackend for SlowBackend {
    fn infer_batch(&mut self, batch: &[BitVec]) -> anyhow::Result<Vec<Scored>> {
        std::thread::sleep(Duration::from_millis(4));
        Ok(batch
            .iter()
            .map(|_| Scored {
                prediction: 0,
                scores: vec![0, 0],
            })
            .collect())
    }
    fn n_literals(&self) -> usize {
        8
    }
    fn name(&self) -> String {
        "slow".into()
    }
}

/// A backend whose every batch fails at scoring time.
struct FailingBackend;

impl ServeBackend for FailingBackend {
    fn infer_batch(&mut self, _batch: &[BitVec]) -> anyhow::Result<Vec<Scored>> {
        anyhow::bail!("injected scoring failure")
    }
    fn n_literals(&self) -> usize {
        4
    }
    fn name(&self) -> String {
        "failing".into()
    }
}

/// Under a shed storm every request lands in exactly one counter:
/// `requests == completed + shed + errors`, and the shed episode is
/// bracketed in the journal as `shed_start` / `shed_end`.
#[test]
fn counters_balance_under_sustained_shedding() {
    let mut coord = Coordinator::new();
    coord
        .register_with_config(
            "obs-slow",
            || Ok(Box::new(SlowBackend) as _),
            RouteConfig {
                workers: 1,
                queue_cap: 2,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::ZERO,
                },
                ..RouteConfig::default()
            },
        )
        .unwrap();
    let h = coord.handle();

    let clients: Vec<_> = (0..12)
        .map(|_| {
            let h = h.clone();
            std::thread::spawn(move || {
                let (mut ok, mut shed) = (0u64, 0u64);
                for _ in 0..8 {
                    match h.infer("obs-slow", BitVec::zeros(8)) {
                        Ok(_) => ok += 1,
                        Err(tsetlin_index::coordinator::InferError::Overloaded) => shed += 1,
                        Err(e) => panic!("unexpected outcome: {e}"),
                    }
                }
                (ok, shed)
            })
        })
        .collect();
    let (mut ok, mut shed) = (0u64, 0u64);
    for c in clients {
        let (o, s) = c.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, 96, "every request must resolve");
    assert!(shed > 0 && ok > 0, "storm must both shed and serve");

    let m = coord.metrics("obs-slow").unwrap();
    assert_eq!(m.requests, 96);
    assert_eq!(m.completed, ok);
    assert_eq!(m.shed, shed);
    assert_eq!(m.errors, 0);
    assert_eq!(m.requests, m.completed + m.shed + m.errors);

    // one healthy request after the storm closes any open shed episode
    h.infer("obs-slow", BitVec::zeros(8)).unwrap();
    let m = coord.metrics("obs-slow").unwrap();
    assert_eq!(m.requests, m.completed + m.shed + m.errors);

    let events = journal().events_for("obs-slow");
    let count = |kind: &str| events.iter().filter(|e| e.kind.name() == kind).count();
    assert!(count("shed_start") >= 1, "episode start must be journaled");
    assert!(count("shed_end") >= 1, "episode end must be journaled");
    coord.shutdown();
}

/// Backend scoring failures are booked as `errors`, keeping the
/// invariant — not silently dropped, not double-counted.
#[test]
fn counters_balance_through_backend_errors() {
    let mut coord = Coordinator::new();
    coord
        .register_with("obs-bad", || Ok(Box::new(FailingBackend) as _), BatchPolicy::default())
        .unwrap();
    let h = coord.handle();
    for _ in 0..3 {
        match h.infer("obs-bad", BitVec::zeros(4)) {
            Err(tsetlin_index::coordinator::InferError::BackendError(msg)) => {
                assert!(msg.contains("injected"), "{msg}")
            }
            other => panic!("expected backend error, got {other:?}"),
        }
    }
    let m = coord.metrics("obs-bad").unwrap();
    assert_eq!((m.requests, m.completed, m.shed, m.errors), (3, 0, 0, 3));
    coord.shutdown();
}

/// A worker panic books the dropped batch as errors (via the armed
/// `Drop` accounting), the supervisor restart is journaled, and the
/// invariant holds once the route is serving again.
#[test]
fn counters_balance_through_worker_panic() {
    let mut tr = quick_trainer(17);
    let mut coord = Coordinator::new();
    coord.register_model(
        "obs-panic",
        tr.publish(),
        RouteConfig {
            workers: 1,
            queue_cap: 64,
            ..RouteConfig::default()
        },
    );
    let h = coord.handle();
    let features: Vec<bool> = (0..24).map(|k| k % 3 == 0).collect();
    h.infer_features("obs-panic", &features).unwrap();

    fault::arm_worker_panics("obs-panic", 1);
    assert!(
        h.infer_features("obs-panic", &features).is_err(),
        "the batch taking the injected panic must fail its client"
    );
    // the supervised restart brings the route back
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if h.infer_features("obs-panic", &features).is_ok() {
            break;
        }
        assert!(Instant::now() < deadline, "route never came back");
        std::thread::sleep(Duration::from_millis(20));
    }

    let m = coord.metrics("obs-panic").unwrap();
    assert!(m.errors >= 1, "panicked batch must be booked as error(s)");
    assert!(m.restarts >= 1);
    assert_eq!(m.requests, m.completed + m.shed + m.errors);
    assert!(
        journal()
            .events_for("obs-panic")
            .iter()
            .any(|e| e.kind.name() == "worker_restart"),
        "the supervisor restart must be journaled"
    );
    coord.shutdown();
}

/// Read protocol lines until the `# EOF` trailer (the `metrics` verb's
/// end-of-reply marker).
fn read_exposition(reader: &mut BufReader<TcpStream>) -> String {
    let mut text = String::new();
    loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0, "EOF before # EOF");
        text.push_str(&line);
        if line.trim_end() == "# EOF" {
            return text;
        }
    }
}

/// The extended `stats` line, the `stats events` drain, and the
/// `metrics` verb over one live TCP connection.
#[test]
fn stats_and_events_verbs_over_tcp() {
    let mut tr = quick_trainer(31);
    let mut next = quick_trainer(32);
    let mut coord = Coordinator::new();
    coord.register_model(
        "obs-tcp",
        tr.publish(),
        RouteConfig {
            workers: 2,
            queue_cap: 1024,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            ..RouteConfig::default()
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

    let conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut conn = conn;
    let mut rng = Rng::new(9);
    let n = 32usize;
    for _ in 0..n {
        let bits: String = (0..24).map(|_| if rng.bern(0.4) { '1' } else { '0' }).collect();
        conn.write_all(format!("infer obs-tcp {bits}\n").as_bytes()).unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok "), "reply: {reply}");
    }
    // a hot swap mid-session lands in the journal for `stats events`
    coord.handle().swap("obs-tcp", next.publish()).unwrap();

    // engine probes flush batch-wise: wait for them to cover every
    // completed request before reading the line we assert on
    let h = coord.handle();
    settle(|| {
        let m = h.stats("obs-tcp").unwrap().metrics;
        m.dense_requests + m.sparse_requests == m.completed
    });

    conn.write_all(b"stats obs-tcp\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ok model=obs-tcp"), "reply: {line}");
    for key in [
        "uptime_s",
        "dense_requests",
        "sparse_requests",
        "index_efficiency",
        "queue_p50_us",
        "queue_p95_us",
        "queue_p99_us",
        "batch_p50_us",
        "score_p99_us",
        "write_p99_us",
    ] {
        kv(&line, key); // panics with context if absent
    }
    assert_eq!(kv_u64(&line, "requests"), n as u64);
    assert_eq!(
        kv_u64(&line, "completed") + kv_u64(&line, "shed") + kv_u64(&line, "errors"),
        kv_u64(&line, "requests"),
    );
    assert_eq!(
        kv_u64(&line, "dense_requests") + kv_u64(&line, "sparse_requests"),
        kv_u64(&line, "completed"),
        "every scored request must be probed: {line}"
    );
    let eff = kv_f64(&line, "index_efficiency");
    assert!(eff > 0.0 && eff <= 1.0, "index_efficiency={eff}");

    conn.write_all(b"stats events obs-tcp\n").unwrap();
    let mut head = String::new();
    reader.read_line(&mut head).unwrap();
    assert!(head.starts_with("ok events="), "reply: {head}");
    let count = kv_u64(&head, "events");
    assert!(count >= 1, "the swap must be drainable: {head}");
    let mut saw_swap = false;
    for _ in 0..count {
        let mut ev = String::new();
        reader.read_line(&mut ev).unwrap();
        assert!(ev.starts_with("seq="), "event line: {ev}");
        if kv(&ev, "kind") == "swap" {
            assert_eq!(kv(&ev, "route"), "obs-tcp");
            saw_swap = true;
        }
    }
    assert!(saw_swap, "swap event must appear in the route's drain");

    conn.write_all(b"metrics\n").unwrap();
    let text = read_exposition(&mut reader);
    validate_exposition(&text).unwrap();
    assert!(text.contains("tmi_requests_total{route=\"obs-tcp\"}"), "{text}");

    stop.store(true, Ordering::Relaxed);
    drop(conn);
    drop(reader);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

/// The `--metrics-addr` HTTP endpoint answers a real GET with a 200
/// and a conformant exposition, and the in-process render agrees.
#[test]
fn http_scrape_serves_conformant_exposition() {
    let mut tr = quick_trainer(41);
    let mut coord = Coordinator::new();
    coord.register_model("obs-http", tr.publish(), RouteConfig::default());
    let h = coord.handle();
    let mut rng = Rng::new(4);
    for _ in 0..8 {
        h.infer("obs-http", random_probe(&mut rng, 24)).unwrap();
    }

    let text = h.prometheus();
    validate_exposition(&text).unwrap();
    assert!(text.ends_with("# EOF\n"), "exposition must end with # EOF");
    for family in [
        "tmi_requests_total",
        "tmi_index_efficiency",
        "tmi_stage_latency_us_bucket",
        "tmi_feedback_flips_total",
        "tmi_journal_events_total",
    ] {
        assert!(text.contains(family), "missing family {family}");
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = h.clone();
    let server = std::thread::spawn(move || serve_metrics_http(listener, handle, stop2));

    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
    let mut resp = String::new();
    conn.read_to_string(&mut resp).unwrap(); // server closes after one reply
    assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "resp: {resp}");
    assert!(resp.contains("text/plain; version=0.0.4"), "resp: {resp}");
    let body = resp.split("\r\n\r\n").nth(1).expect("response body");
    validate_exposition(body).unwrap();
    assert!(body.ends_with("# EOF\n"));

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

/// The configurable per-connection read timeout (`--read-timeout-ms`)
/// preserves the counter invariant: a client that stalls mid-line for
/// many timeout ticks keeps its partial request buffered (and can
/// finish it later), a client that disconnects mid-line books nothing,
/// and a healthy connection is served throughout. Every admitted
/// request — and only admitted requests — lands in exactly one counter.
#[test]
fn stalled_partial_requests_survive_read_timeout_ticks() {
    let mut tr = quick_trainer(61);
    let mut coord = Coordinator::new();
    coord.register_model("obs-stall", tr.publish(), RouteConfig::default());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    // a timeout far below the stall durations: the connection loop must
    // tick WouldBlock/TimedOut many times without dropping buffered bytes
    let opts = ServeOptions {
        read_timeout: Duration::from_millis(5),
        ..ServeOptions::default()
    };
    let server = std::thread::spawn(move || serve_tcp_with(listener, handle, stop2, opts));

    let bits: String = (0..24).map(|k| if k % 3 == 0 { '1' } else { '0' }).collect();
    let line = format!("infer obs-stall {bits}\n");

    // stalling client: half a request, then silence across >=10 ticks
    let mut stall = TcpStream::connect(addr).unwrap();
    let mut stall_reader = BufReader::new(stall.try_clone().unwrap());
    let (head, tail) = line.split_at(line.len() / 2);
    stall.write_all(head.as_bytes()).unwrap();
    std::thread::sleep(Duration::from_millis(80));

    // a healthy client is served while the other connection stalls
    let mut healthy = TcpStream::connect(addr).unwrap();
    let mut healthy_reader = BufReader::new(healthy.try_clone().unwrap());
    healthy.write_all(line.as_bytes()).unwrap();
    let mut reply = String::new();
    healthy_reader.read_line(&mut reply).unwrap();
    assert!(reply.starts_with("ok "), "healthy reply: {reply}");

    // the stalled connection completes its line — the partial bytes
    // must have survived every timeout tick
    stall.write_all(tail.as_bytes()).unwrap();
    let mut reply = String::new();
    stall_reader.read_line(&mut reply).unwrap();
    assert!(reply.starts_with("ok "), "post-stall reply: {reply}");

    // a third client disconnects mid-line: the half request was never
    // admitted, so no counter may move for it
    let mut dead = TcpStream::connect(addr).unwrap();
    dead.write_all(format!("infer obs-stall {}", &bits[..8]).as_bytes()).unwrap();
    std::thread::sleep(Duration::from_millis(40));
    drop(dead);

    settle(|| {
        let m = coord.stats("obs-stall").unwrap().metrics;
        m.requests == 2 && m.requests == m.completed + m.shed + m.errors
    });
    let m = coord.stats("obs-stall").unwrap().metrics;
    assert_eq!(
        (m.requests, m.completed, m.shed, m.errors),
        (2, 2, 0, 0),
        "exactly the two completed lines may be booked"
    );

    stop.store(true, Ordering::Relaxed);
    drop(stall);
    drop(stall_reader);
    drop(healthy);
    drop(healthy_reader);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

/// Concurrent stats and exposition readers stay consistent through a
/// hot-swap storm under live traffic: every render is conformant, the
/// request counter is monotonic, completions never overrun admissions,
/// and every swap is journaled.
#[test]
fn hot_swap_storm_keeps_readers_consistent() {
    let mut tr_a = quick_trainer(51);
    let mut tr_b = quick_trainer(52);
    let snap_a = tr_a.publish();
    let snap_b = tr_b.publish();
    let mut coord = Coordinator::new();
    coord.register_model(
        "obs-storm",
        Arc::clone(&snap_a),
        RouteConfig {
            workers: 2,
            queue_cap: 4096,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            ..RouteConfig::default()
        },
    );
    let h = coord.handle();
    let run = Arc::new(AtomicBool::new(true));
    let swaps = 30u64;

    let clients: Vec<_> = (0..3)
        .map(|c| {
            let h = h.clone();
            let run = Arc::clone(&run);
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + c);
                while run.load(Ordering::Relaxed) {
                    h.infer("obs-storm", random_probe(&mut rng, 24)).unwrap();
                }
            })
        })
        .collect();

    let readers: Vec<_> = (0..2)
        .map(|r| {
            let h = h.clone();
            let run = Arc::clone(&run);
            std::thread::spawn(move || {
                let mut last_requests = 0u64;
                while run.load(Ordering::Relaxed) {
                    if r == 0 {
                        validate_exposition(&h.prometheus())
                            .expect("exposition must stay conformant mid-swap");
                    } else {
                        let m = h.stats("obs-storm").unwrap().metrics;
                        assert!(m.requests >= last_requests, "requests must be monotonic");
                        assert!(
                            m.completed + m.shed + m.errors <= m.requests,
                            "resolutions can never overrun admissions"
                        );
                        last_requests = m.requests;
                    }
                }
            })
        })
        .collect();

    for i in 0..swaps {
        let snap = if i % 2 == 0 { &snap_b } else { &snap_a };
        h.swap("obs-storm", Arc::clone(snap)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    run.store(false, Ordering::Relaxed);
    for t in clients.into_iter().chain(readers) {
        t.join().unwrap();
    }

    let st = coord.stats("obs-storm").unwrap();
    assert_eq!(st.generation, Some(swaps), "every swap must land");
    settle(|| {
        let m = coord.stats("obs-storm").unwrap().metrics;
        m.requests == m.completed + m.shed + m.errors
    });
    let journaled = journal()
        .events_for("obs-storm")
        .iter()
        .filter(|e| e.kind.name() == "swap")
        .count() as u64;
    assert_eq!(journaled, swaps, "every swap must be journaled");
    coord.shutdown();
}
