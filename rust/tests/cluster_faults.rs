//! Cluster fault-injection harness: the three headline scenarios plus
//! liveness bookkeeping, all driven through real TCP (subprocess nodes
//! where kill -9 matters, the [`faultnet`] chaos proxy where byte-level
//! damage matters).
//!
//! 1. kill -9 one node under client load → the router re-homes every
//!    request; zero torn replies, zero errors surface to clients.
//! 2. partition the control plane → the router and nodes keep serving
//!    the last-known assignment.
//! 3. corrupt / truncate the replication stream mid-transfer → the
//!    node's CRC check quarantines the push while the old version
//!    keeps serving; a clean retry installs it.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tsetlin_index::cluster::faultnet::{ChaosProxy, FaultPlan};
use tsetlin_index::cluster::{
    push_snapshot, serve_control, serve_node, ControlConfig, ControlPlane, NodeOptions, NodeSpec,
    NodeState, Router, RouterConfig,
};
use tsetlin_index::coordinator::{Coordinator, RouteConfig, ServeOptions};
use tsetlin_index::engine::{InferMode, ModelSnapshot};
use tsetlin_index::eval::Backend;
use tsetlin_index::obs::journal;
use tsetlin_index::registry::Registry;
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::io as model_io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tmi-cluster-{tag}-{}-{}",
        std::process::id(),
        std::thread::current().name().unwrap_or("t").replace("::", "-")
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small real model: 10 features, 2 classes (the registry_faults
/// fixture). Infer lines carry 10 feature bits.
fn trained(seed: u64) -> MultiClassTM {
    let params = TMParams::new(2, 8, 10).with_seed(seed);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut rng = Rng::new(seed ^ 0xfau64);
    let samples: Vec<(BitVec, usize)> = (0..100)
        .map(|_| {
            let y = rng.bern(0.5) as usize;
            let bits: Vec<bool> = (0..10)
                .map(|k| if k == 0 { y == 1 } else { rng.bern(0.4) })
                .collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            (BitVec::from_bools(&lits), y)
        })
        .collect();
    for _ in 0..3 {
        tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
    }
    tr.tm
}

fn free_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().to_string()
}

/// Spawn a subprocess cluster node (`tmi serve --node-id`), empty.
fn spawn_node_proc(id: &str, addr: &str) -> Child {
    tmi()
        .args(["serve", "--node-id", id, "--listen", addr])
        .spawn()
        .expect("spawning tmi node")
}

/// One request/one reply over a fresh connection. `None` on any
/// transport failure or torn (newline-less) reply.
fn ask(addr: &str, line: &str) -> Option<String> {
    let stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok()?;
    let mut reader = BufReader::new(stream.try_clone().ok()?);
    let mut stream = stream;
    stream.write_all(line.as_bytes()).ok()?;
    let mut reply = String::new();
    reader.read_line(&mut reply).ok()?;
    reply.ends_with('\n').then_some(reply)
}

fn wait_until(what: &str, deadline: Duration, mut pred: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !pred() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Start an in-process node (listener thread + NodeState) serving a
/// pre-registered `cpu` route at `version`.
fn inproc_node(
    id: &str,
    tm: &MultiClassTM,
    version: u64,
) -> (Arc<NodeState>, String, Arc<AtomicBool>) {
    let mut coord = Coordinator::new();
    let snap = Arc::new(ModelSnapshot::with_mode(tm.clone(), version, InferMode::Auto));
    coord.register_model("cpu", snap, RouteConfig::default());
    let node = Arc::new(NodeState::new(coord, NodeOptions::new(id)));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let (node2, stop2) = (Arc::clone(&node), Arc::clone(&stop));
    std::thread::spawn(move || {
        let _ = serve_node(listener, node2, stop2, ServeOptions::default());
    });
    (node, addr, stop)
}

fn router_over(specs: Vec<NodeSpec>, deadline: Duration) -> Router {
    let mut cfg = RouterConfig::new(specs);
    cfg.deadline = deadline;
    cfg.backoff_base = Duration::from_millis(5);
    cfg.backoff_cap = Duration::from_millis(50);
    Router::new(cfg)
}

/// Scenario 1 — kill -9 a node under load: every reply the clients see
/// is complete and successful; the router re-homes to the survivor.
#[test]
fn killing_a_node_under_load_reroutes_with_zero_torn_replies() {
    let dir = temp_dir("kill");
    let tm = trained(21);
    {
        let mut reg = Registry::open(&dir, 4).unwrap();
        assert_eq!(reg.publish("cpu", &tm, InferMode::Auto).unwrap(), 1);
    }
    let (addr1, addr2) = (free_addr(), free_addr());
    let mut n1 = KillOnDrop(spawn_node_proc("n1", &addr1));
    let _n2 = KillOnDrop(spawn_node_proc("n2", &addr2));

    // control plane replicates cpu to both nodes (replicas=2)
    let mut cfg = ControlConfig::new(
        vec![
            NodeSpec::parse(&format!("n1@{addr1}")).unwrap(),
            NodeSpec::parse(&format!("n2@{addr2}")).unwrap(),
        ],
        &dir,
    );
    cfg.heartbeat = Duration::from_millis(100);
    cfg.probe_timeout = Duration::from_millis(300);
    let mut plane = ControlPlane::new(cfg);
    let stop_plane = Arc::new(AtomicBool::new(false));
    let plane_thread = {
        let stop = Arc::clone(&stop_plane);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                plane.tick();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };
    // both nodes must hold the route before load starts
    for addr in [&addr1, &addr2] {
        wait_until("replication to both nodes", Duration::from_secs(30), || {
            ask(addr, "stats cpu\n").is_some_and(|r| r.starts_with("ok model=cpu"))
        });
    }

    let router = Arc::new(router_over(
        vec![
            NodeSpec::parse(&format!("n1@{addr1}")).unwrap(),
            NodeSpec::parse(&format!("n2@{addr2}")).unwrap(),
        ],
        Duration::from_secs(5),
    ));
    let run = Arc::new(AtomicBool::new(true));
    let clients: Vec<_> = (0..3)
        .map(|c| {
            let router = Arc::clone(&router);
            let run = Arc::clone(&run);
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64 + 7);
                let (mut ok, mut torn, mut errs) = (0u64, 0u64, 0u64);
                while run.load(Ordering::Relaxed) {
                    let bits: String =
                        (0..10).map(|_| if rng.bern(0.5) { '1' } else { '0' }).collect();
                    let reply = router.respond(&format!("infer cpu {bits}\n"));
                    if !reply.ends_with('\n')
                        || !(reply.starts_with("ok ") || reply.starts_with("err "))
                    {
                        torn += 1;
                    } else if reply.starts_with("ok ") {
                        ok += 1;
                    } else {
                        errs += 1;
                    }
                }
                (ok, torn, errs)
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(400));
    n1.0.kill().expect("kill -9 n1"); // SIGKILL: no drain, no goodbye
    n1.0.wait().unwrap();
    std::thread::sleep(Duration::from_millis(800));
    run.store(false, Ordering::Relaxed);
    let (mut ok, mut torn, mut errs) = (0u64, 0u64, 0u64);
    for c in clients {
        let (o, t, e) = c.join().unwrap();
        ok += o;
        torn += t;
        errs += e;
    }
    stop_plane.store(true, Ordering::Relaxed);
    plane_thread.join().unwrap();
    assert_eq!(torn, 0, "client saw a torn reply across the kill");
    assert_eq!(errs, 0, "client saw an error; failover must absorb the kill");
    assert!(ok > 50, "load should have flowed throughout (ok={ok})");
    // the survivor alone still answers
    let reply = ask(&addr2, "infer cpu 1010101010\n").expect("survivor must serve");
    assert!(reply.starts_with("ok "), "survivor reply: {reply:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Scenario 2 — control-plane partition: the router's last-known
/// assignment keeps the data path alive with the control plane gone.
#[test]
fn control_plane_partition_keeps_last_known_assignment_serving() {
    let tm = trained(22);
    let (_node, node_addr, node_stop) = inproc_node("n1", &tm, 1);

    // a live control plane the router learns membership from
    let control_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let control_addr = control_listener.local_addr().unwrap().to_string();
    let dir = temp_dir("partition"); // empty registry: nothing to replicate
    let mut ccfg =
        ControlConfig::new(vec![NodeSpec::parse(&format!("n1@{node_addr}")).unwrap()], &dir);
    ccfg.heartbeat = Duration::from_millis(100);
    ccfg.probe_timeout = Duration::from_millis(200);
    let mut plane = ControlPlane::new(ccfg);
    plane.tick(); // one real heartbeat so the view is honest
    let view = plane.shared_view();
    let control_stop = Arc::new(AtomicBool::new(false));
    let control_thread = {
        let stop = Arc::clone(&control_stop);
        std::thread::spawn(move || {
            let _ = serve_control(control_listener, view, stop);
        })
    };

    let mut rcfg = RouterConfig::new(vec![]);
    rcfg.control = Some(control_addr.clone());
    rcfg.deadline = Duration::from_secs(2);
    let router = Router::new(rcfg);
    router.poll_membership();
    let before = router.respond("cluster\n");
    assert!(before.contains("nodes=1"), "membership poll failed: {before:?}");
    assert!(
        router.respond("infer cpu 1010101010\n").starts_with("ok "),
        "data path must work with the control plane up"
    );

    // partition: the control plane vanishes entirely
    control_stop.store(true, Ordering::Relaxed);
    control_thread.join().unwrap();
    router.poll_membership(); // must keep last-known on failure
    for _ in 0..20 {
        let reply = router.respond("infer cpu 1010101010\n");
        assert!(
            reply.starts_with("ok "),
            "last-known assignment must keep serving through the partition: {reply:?}"
        );
    }
    let after = router.respond("cluster\n");
    assert!(
        after.contains("id=n1"),
        "last-known membership must survive the partition: {after:?}"
    );
    node_stop.store(true, Ordering::Relaxed);
}

/// Scenario 3 — corrupted replication stream: the CRC check refuses
/// the transfer (quarantine journaled), the old version keeps serving,
/// and a clean retry installs the new version.
#[test]
fn corrupt_replication_stream_is_quarantined_and_old_version_serves() {
    let v1 = trained(23);
    let v2 = trained(24);
    let (node, node_addr, node_stop) = inproc_node("n1", &v1, 1);
    let proxy = ChaosProxy::spawn(node_addr.as_str()).unwrap();
    let image = model_io::serialize(&v2);

    // flip one byte mid-stream (after the replicate header would have
    // passed; offsets are absolute over the client->node byte stream)
    proxy.set(FaultPlan {
        corrupt_at: Some(64 + image.len() as u64 / 2),
        ..FaultPlan::default()
    });
    let err = push_snapshot(
        proxy.addr(),
        "cpu",
        2,
        InferMode::Auto,
        &image,
        Duration::from_secs(10),
    )
    .expect_err("a corrupted stream must be refused");
    assert!(err.contains("corrupt"), "refusal must name the CRC failure: {err}");
    let stats = ask(&node_addr, "stats cpu\n").unwrap();
    assert!(stats.contains("version=1"), "old version must keep serving: {stats}");
    assert!(
        ask(&node_addr, "infer cpu 1010101010\n").unwrap().starts_with("ok "),
        "route must keep answering after a refused push"
    );
    let quarantines = journal()
        .events_for("cpu")
        .iter()
        .filter(|e| e.kind.name() == "quarantine")
        .count();
    assert!(quarantines >= 1, "the refusal must be journaled as a quarantine");

    // truncation mid-body: refused the same way
    proxy.set(FaultPlan {
        truncate_after: Some(64 + image.len() as u64 / 3),
        ..FaultPlan::default()
    });
    let err = push_snapshot(
        proxy.addr(),
        "cpu",
        3,
        InferMode::Auto,
        &image,
        Duration::from_secs(10),
    )
    .expect_err("a truncated stream must be refused");
    // whether the node's "err truncated" verdict survives the proxy
    // tearing both directions down is racy; the binding guarantees are
    // the refusal itself and that nothing was installed
    assert!(!err.is_empty());
    let stats = ask(&node_addr, "stats cpu\n").unwrap();
    assert!(stats.contains("version=1"), "old version must still serve: {stats}");

    // healed proxy: the retry lands and v2 serves
    proxy.heal();
    let okay = push_snapshot(
        proxy.addr(),
        "cpu",
        2,
        InferMode::Auto,
        &image,
        Duration::from_secs(10),
    )
    .expect("clean retry must install");
    assert!(okay.contains("version=2"), "install ack: {okay}");
    let stats = ask(&node_addr, "stats cpu\n").unwrap();
    assert!(stats.contains("version=2"), "new version must serve after retry: {stats}");
    assert_eq!(node.handle().models(), vec!["cpu".to_string()]);
    proxy.shutdown();
    node_stop.store(true, Ordering::Relaxed);
}

/// Heartbeats: missed beats evict, recovery re-admits, and the
/// replication cache is cleared so the re-admitted node is resynced.
#[test]
fn missed_beats_evict_and_recovery_readmits_with_resync() {
    let dir = temp_dir("evict");
    let tm = trained(25);
    {
        let mut reg = Registry::open(&dir, 4).unwrap();
        assert_eq!(reg.publish("cpu", &tm, InferMode::Auto).unwrap(), 1);
    }
    let (node, node_addr, node_stop) = inproc_node("n1", &tm, 0);
    let proxy = ChaosProxy::spawn(node_addr.as_str()).unwrap();

    let mut cfg = ControlConfig::new(
        vec![NodeSpec::parse(&format!("n1@{}", proxy.addr())).unwrap()],
        &dir,
    );
    cfg.miss_threshold = 2;
    cfg.probe_timeout = Duration::from_millis(200);
    let mut plane = ControlPlane::new(cfg);

    plane.tick(); // probe ok + replicate v1
    let v = plane.view();
    assert!(v.nodes[0].alive);
    assert_eq!(v.nodes[0].replications, 1, "first tick must replicate: {v:?}");
    assert_eq!(v.routes.len(), 1);
    assert_eq!(v.routes[0].owners, vec!["n1".to_string()]);

    proxy.set(FaultPlan {
        blackhole: true,
        ..FaultPlan::default()
    });
    plane.tick();
    assert!(plane.view().nodes[0].alive, "one miss must not evict at threshold 2");
    plane.tick();
    let v = plane.view();
    assert!(!v.nodes[0].alive, "threshold crossed: evicted");
    assert!(v.routes[0].owners.is_empty(), "an evicted node owns nothing");
    let names: Vec<&str> = journal()
        .events_for("") // process-wide events only
        .iter()
        .map(|e| e.kind.name())
        .filter(|n| n.starts_with("node_"))
        .collect();
    assert!(names.contains(&"node_down"), "journal: {names:?}");
    assert!(names.contains(&"node_evict"), "journal: {names:?}");

    proxy.heal();
    plane.tick();
    let v = plane.view();
    assert!(v.nodes[0].alive, "recovery must re-admit");
    assert_eq!(
        v.nodes[0].replications,
        2,
        "re-admission must resync the route (pushed-cache cleared): {v:?}"
    );
    assert_eq!(v.routes[0].owners, vec!["n1".to_string()]);
    proxy.shutdown();
    node_stop.store(true, Ordering::Relaxed);
    drop(node);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Degradation: when every replica is blackholed the router answers a
/// complete `err unavailable` line within the deadline — no hang, no
/// torn reply.
#[test]
fn blackholed_replicas_degrade_to_err_unavailable_within_deadline() {
    let tm = trained(26);
    let (_node, node_addr, node_stop) = inproc_node("n1", &tm, 1);
    let proxy = ChaosProxy::spawn(node_addr.as_str()).unwrap();
    proxy.set(FaultPlan {
        blackhole: true,
        ..FaultPlan::default()
    });
    let router = router_over(
        vec![NodeSpec::parse(&format!("n1@{}", proxy.addr())).unwrap()],
        Duration::from_millis(600),
    );
    let t0 = Instant::now();
    let reply = router.respond("infer cpu 1010101010\n");
    let took = t0.elapsed();
    assert!(reply.starts_with("err unavailable:"), "got {reply:?}");
    assert!(reply.ends_with('\n'), "degraded reply must be complete");
    assert!(
        took < Duration::from_secs(3),
        "deadline must bound the hang: took {took:?}"
    );
    proxy.shutdown();
    node_stop.store(true, Ordering::Relaxed);
}

/// RAII kill for subprocess nodes so a failing assert doesn't leak
/// listeners across test runs.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}
