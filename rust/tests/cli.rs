//! CLI integration: drive the `tmi` binary end-to-end.

use std::process::Command;

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

#[test]
fn no_args_prints_usage() {
    let out = tmi().output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn unknown_command_fails() {
    let out = tmi().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_eval_roundtrip() {
    let model = std::env::temp_dir().join(format!("tmi-cli-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "150", "--clauses", "100",
            "--epochs", "2", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("accuracy"), "stdout: {stdout}");

    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "mnist",
            "--samples", "100",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "eval failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("accuracy"));
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn work_ratio_reports_stats() {
    let out = tmi()
        .args([
            "work-ratio", "--dataset", "imdb", "--features", "500", "--samples", "80",
            "--clauses", "60", "--epochs", "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "work-ratio failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("work ratio"), "stdout: {stdout}");
}

/// Train a tiny IMDb-synthetic model (sparse workload) and return the
/// model path; caller removes the file.
fn train_tiny_imdb(tag: &str) -> std::path::PathBuf {
    let model = std::env::temp_dir().join(format!("tmi-cli-{tag}-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "imdb", "--features", "1500", "--samples", "60",
            "--clauses", "40", "--epochs", "1", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    model
}

#[test]
fn eval_auto_selects_sparse_on_imdb() {
    let model = train_tiny_imdb("auto");
    // the Zipf IMDb fallback is low-density, so auto picks sparse and
    // says so (the selection is otherwise invisible)
    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
            "--features", "1500", "--samples", "40",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "eval failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("auto-selected sparse inference"),
        "stderr: {stderr}"
    );
    assert!(stderr.contains("feature density"), "stderr: {stderr}");
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_forced_infer_modes_agree() {
    let model = train_tiny_imdb("forced");
    let mut accuracies = Vec::new();
    for mode in ["dense", "sparse"] {
        let out = tmi()
            .args([
                "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
                "--features", "1500", "--samples", "40", "--infer", mode,
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "eval --infer {mode} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            stderr.contains(&format!("inference engine: {mode} (forced)")),
            "stderr: {stderr}"
        );
        // same model, same data: the accuracy line must be identical
        let stdout = String::from_utf8_lossy(&out.stdout).to_string();
        let acc = stdout
            .split_whitespace()
            .nth(1)
            .expect("accuracy value")
            .to_string();
        accuracies.push(acc);
    }
    assert_eq!(accuracies[0], accuracies[1], "dense vs sparse accuracy");
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_rejects_bad_infer_mode() {
    let model = train_tiny_imdb("badmode");
    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
            "--features", "1500", "--samples", "10", "--infer", "warp",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown infer mode"));
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_missing_model_errors() {
    let out = tmi()
        .args(["eval", "--model", "/nonexistent/x.tm", "--dataset", "mnist"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn serve_then_loadgen_writes_bench_json() {
    use std::io::{BufRead, BufReader, Write};

    let model = std::env::temp_dir().join(format!("tmi-cli-serve-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "120", "--clauses", "80",
            "--epochs", "1", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // grab a free port, release it, hand it to the server (single CI
    // process: the window for someone else to steal it is negligible)
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi()
        .args([
            "serve", "--model", model.to_str().unwrap(), "--listen", &addr,
            "--workers", "2", "--queue-cap", "64",
        ])
        .spawn()
        .unwrap();

    // wait until the server accepts and answers a stats line
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let mut ready = false;
    while std::time::Instant::now() < deadline {
        if let Ok(conn) = std::net::TcpStream::connect(&addr) {
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut conn = conn;
            if conn.write_all(b"stats cpu\n").is_ok() {
                let mut reply = String::new();
                if reader.read_line(&mut reply).is_ok() && reply.starts_with("ok model=cpu") {
                    assert!(reply.contains("version=1"), "stats: {reply}");
                    ready = true;
                    break;
                }
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    assert!(ready, "server never became ready on {addr}");

    let bench = std::env::temp_dir().join(format!(
        "tmi-cli-bench-serve-{}.json",
        std::process::id()
    ));
    let out = tmi()
        .args([
            "loadgen", "--addr", &addr, "--model", "cpu", "--features", "784",
            "--connections", "2", "--duration", "1", "--out", bench.to_str().unwrap(),
            "--assert-min-ok", "1", "--assert-max-shed-rate", "1.0",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "loadgen failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("closed loop"), "stdout: {stdout}");

    let text = std::fs::read_to_string(&bench).expect("BENCH_serve.json written");
    let parsed = tsetlin_index::util::Json::parse(&text).expect("well-formed bench json");
    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve_load"));
    assert!(parsed.get("ok").unwrap().as_usize().unwrap() >= 1);

    server.kill().unwrap();
    let _ = server.wait();
    std::fs::remove_file(&model).unwrap();
    std::fs::remove_file(&bench).unwrap();
}
