//! CLI integration: drive the `tmi` binary end-to-end.

use std::process::Command;

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

#[test]
fn no_args_prints_usage() {
    let out = tmi().output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn unknown_command_fails() {
    let out = tmi().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_eval_roundtrip() {
    let model = std::env::temp_dir().join(format!("tmi-cli-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "150", "--clauses", "100",
            "--epochs", "2", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("accuracy"), "stdout: {stdout}");

    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "mnist",
            "--samples", "100",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "eval failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("accuracy"));
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn work_ratio_reports_stats() {
    let out = tmi()
        .args([
            "work-ratio", "--dataset", "imdb", "--features", "500", "--samples", "80",
            "--clauses", "60", "--epochs", "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "work-ratio failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("work ratio"), "stdout: {stdout}");
}

#[test]
fn eval_missing_model_errors() {
    let out = tmi()
        .args(["eval", "--model", "/nonexistent/x.tm", "--dataset", "mnist"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
