//! CLI integration: drive the `tmi` binary end-to-end.

use std::process::Command;

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

#[test]
fn no_args_prints_usage() {
    let out = tmi().output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("usage:"));
}

#[test]
fn unknown_command_fails() {
    let out = tmi().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_eval_roundtrip() {
    let model = std::env::temp_dir().join(format!("tmi-cli-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "150", "--clauses", "100",
            "--epochs", "2", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("accuracy"), "stdout: {stdout}");

    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "mnist",
            "--samples", "100",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "eval failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("accuracy"));
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn work_ratio_reports_stats() {
    let out = tmi()
        .args([
            "work-ratio", "--dataset", "imdb", "--features", "500", "--samples", "80",
            "--clauses", "60", "--epochs", "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "work-ratio failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("work ratio"), "stdout: {stdout}");
}

/// Train a tiny IMDb-synthetic model (sparse workload) and return the
/// model path; caller removes the file.
fn train_tiny_imdb(tag: &str) -> std::path::PathBuf {
    let model = std::env::temp_dir().join(format!("tmi-cli-{tag}-{}.tm", std::process::id()));
    let out = tmi()
        .args([
            "train", "--dataset", "imdb", "--features", "1500", "--samples", "60",
            "--clauses", "40", "--epochs", "1", "--out", model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    model
}

#[test]
fn eval_auto_selects_sparse_on_imdb() {
    let model = train_tiny_imdb("auto");
    // the Zipf IMDb fallback is low-density, so auto picks sparse and
    // says so (the selection is otherwise invisible)
    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
            "--features", "1500", "--samples", "40",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "eval failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("auto-selected sparse inference"),
        "stderr: {stderr}"
    );
    assert!(stderr.contains("feature density"), "stderr: {stderr}");
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_forced_infer_modes_agree() {
    let model = train_tiny_imdb("forced");
    let mut accuracies = Vec::new();
    for mode in ["dense", "sparse"] {
        let out = tmi()
            .args([
                "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
                "--features", "1500", "--samples", "40", "--infer", mode,
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "eval --infer {mode} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(
            stderr.contains(&format!("inference engine: {mode} (forced)")),
            "stderr: {stderr}"
        );
        // same model, same data: the accuracy line must be identical
        let stdout = String::from_utf8_lossy(&out.stdout).to_string();
        let acc = stdout
            .split_whitespace()
            .nth(1)
            .expect("accuracy value")
            .to_string();
        accuracies.push(acc);
    }
    assert_eq!(accuracies[0], accuracies[1], "dense vs sparse accuracy");
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_rejects_bad_infer_mode() {
    let model = train_tiny_imdb("badmode");
    let out = tmi()
        .args([
            "eval", "--model", model.to_str().unwrap(), "--dataset", "imdb",
            "--features", "1500", "--samples", "10", "--infer", "warp",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown infer mode"));
    std::fs::remove_file(&model).unwrap();
}

#[test]
fn eval_missing_model_errors() {
    let out = tmi()
        .args(["eval", "--model", "/nonexistent/x.tm", "--dataset", "mnist"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}
