//! Differential suite: bit-sliced vs scalar TA banks.
//!
//! The bit-sliced layout replaces per-literal `i8` bumps with
//! word-parallel bitplane arithmetic and recovers flips from sign-plane
//! XOR. This suite proves the replacement is **bit-exact** under the
//! shared RNG contract (both layouts consume the same skip-sampled
//! Bernoulli masks from the same stream):
//!
//! * identical TA states, include counts, and clause weights,
//! * the *exact same* [`FlipSink`] event stream (order, counts,
//!   weights) — the contract the paper's O(1) index maintenance hangs
//!   off,
//! * over random machines, long feedback storms, full sequential and
//!   parallel training runs on `data/synth::noisy_xor`, and every
//!   evaluation backend.

use tsetlin_index::data::synth::noisy_xor;
use tsetlin_index::eval::traits::FlipSink;
use tsetlin_index::eval::Backend;
use tsetlin_index::parallel::ParallelTrainer;
use tsetlin_index::tm::bank::{ClauseBank, TaLayout};
use tsetlin_index::tm::feedback::{update_clause_range, FeedbackCtx, FeedbackScratch};
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

/// Every observable feedback event, in order.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Ev {
    Inc { j: u32, k: u32, count: u32, weight: u32 },
    Exc { j: u32, k: u32, count: u32, weight: u32 },
    Weight { j: u32, delta: i32, nonempty: bool },
}

#[derive(Default)]
struct Recorder {
    events: Vec<Ev>,
}

impl FlipSink for Recorder {
    fn on_include(&mut self, j: u32, k: u32, count: u32, weight: u32) {
        self.events.push(Ev::Inc { j, k, count, weight });
    }
    fn on_exclude(&mut self, j: u32, k: u32, count: u32, weight: u32) {
        self.events.push(Ev::Exc { j, k, count, weight });
    }
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.events.push(Ev::Weight { j, delta, nonempty });
    }
}

/// A random mid-training bank materialized in both layouts (states
/// include the saturation extremes), plus matching weights.
fn random_pair(
    rng: &mut Rng,
    clauses: usize,
    n_lit: usize,
    density: f64,
    weighted: bool,
) -> (ClauseBank, ClauseBank) {
    let mut scalar = ClauseBank::new_with_layout(clauses, n_lit, TaLayout::Scalar);
    for j in 0..clauses {
        for k in 0..n_lit {
            if rng.bern(density) {
                let v = match rng.below(12) {
                    0 => i8::MAX,
                    1 => i8::MIN,
                    _ => (rng.below(21) as i8) - 10,
                };
                scalar.set_state(j, k, v);
            }
        }
        if weighted && rng.bern(0.5) {
            scalar.set_weight(j, 1 + rng.below(6));
        }
    }
    let sliced = scalar.convert_layout(TaLayout::Sliced);
    assert_eq!(scalar.states(), sliced.states());
    (scalar, sliced)
}

fn random_lits(rng: &mut Rng, n: usize, p: f64) -> BitVec {
    BitVec::from_bools(&(0..n).map(|_| rng.bern(p)).collect::<Vec<_>>())
}

/// Training-mode clause outputs straight off the documented semantics
/// (empty clauses output 1 during learning).
fn reference_outputs(bank: &ClauseBank, lits: &BitVec) -> BitVec {
    let mut out = BitVec::zeros(bank.clauses());
    for j in 0..bank.clauses() {
        let o = bank.count(j) == 0 || bank.included_literals(j).all(|k| lits.get(k));
        out.assign(j, o);
    }
    out
}

/// One differential feedback step on a layout pair: same RNG seed in,
/// states + counts + weights + event stream compared out.
#[allow(clippy::too_many_arguments)]
fn step_both(
    scalar: &mut ClauseBank,
    sliced: &mut ClauseBank,
    ctx: &FeedbackCtx,
    outputs: &BitVec,
    lits: &BitVec,
    p_update: u32,
    is_target: bool,
    seed: u64,
    tag: &str,
) {
    let mut rec_a = Recorder::default();
    let mut rec_b = Recorder::default();
    let mut rng_a = Rng::new(seed);
    let mut rng_b = Rng::new(seed);
    let mut scratch_a = FeedbackScratch::new(scalar.n_literals());
    let mut scratch_b = FeedbackScratch::new(sliced.n_literals());
    let ua = update_clause_range(
        scalar, &mut rec_a, &mut rng_a, ctx, outputs, lits, p_update, is_target,
        &mut scratch_a,
    );
    let ub = update_clause_range(
        sliced, &mut rec_b, &mut rng_b, ctx, outputs, lits, p_update, is_target,
        &mut scratch_b,
    );
    assert_eq!(ua, ub, "{tag}: update counts diverge");
    assert_eq!(rec_a.events, rec_b.events, "{tag}: FlipSink streams diverge");
    assert_eq!(scalar.states(), sliced.states(), "{tag}: states diverge");
    assert_eq!(scalar.weights(), sliced.weights(), "{tag}: weights diverge");
    for j in 0..scalar.clauses() {
        assert_eq!(scalar.count(j), sliced.count(j), "{tag}: count({j}) diverges");
    }
    // and the two RNG streams consumed the same number of draws
    assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{tag}: RNG streams diverge");
}

#[test]
fn random_machines_single_steps_are_bit_identical() {
    let mut rng = Rng::new(0xfeedbac0);
    let mut seed = 1u64;
    for &(clauses, n_lit) in &[(4usize, 6usize), (8, 64), (6, 70), (4, 200)] {
        for &weighted in &[false, true] {
            let (mut scalar, mut sliced) = random_pair(&mut rng, clauses, n_lit, 0.3, weighted);
            for trial in 0..60 {
                let s = [1.0, 2.0, 4.0, 27.0][trial % 4];
                let boost = trial % 3 != 0;
                let ctx = FeedbackCtx::new(s, boost, weighted);
                let lits = random_lits(&mut rng, n_lit, 0.5);
                let outputs = reference_outputs(&scalar, &lits);
                let p_update = match trial % 3 {
                    0 => u32::MAX,
                    1 => rng.next_u32(),
                    _ => u32::MAX / 2,
                };
                seed += 1;
                step_both(
                    &mut scalar,
                    &mut sliced,
                    &ctx,
                    &outputs,
                    &lits,
                    p_update,
                    trial % 2 == 0,
                    seed,
                    &format!("{clauses}x{n_lit} weighted={weighted} trial={trial}"),
                );
            }
            assert!(scalar.check_counts() && sliced.check_counts());
        }
    }
}

#[test]
fn saturation_storms_stay_bit_identical() {
    // s = 1 makes every forget mask full; hammering the same bank
    // drives states into both saturation rails and back while the
    // layouts must agree at every step (tail word exercised: 2o = 70).
    let mut rng = Rng::new(0x5a7a5a7a);
    let (mut scalar, mut sliced) = random_pair(&mut rng, 6, 70, 0.6, false);
    for step in 0..400 {
        let s = if step % 2 == 0 { 1.0 } else { 1e9 };
        let ctx = FeedbackCtx::new(s, step % 5 == 0, false);
        let lits = match step % 4 {
            0 => BitVec::ones(70),
            1 => BitVec::zeros(70),
            _ => random_lits(&mut rng, 70, 0.5),
        };
        let outputs = reference_outputs(&scalar, &lits);
        step_both(
            &mut scalar,
            &mut sliced,
            &ctx,
            &outputs,
            &lits,
            u32::MAX,
            step % 2 == 0,
            9000 + step as u64,
            &format!("storm step {step}"),
        );
    }
    assert!(scalar.check_counts() && sliced.check_counts());
}

#[test]
fn wide_lanes_preserve_cross_layout_equivalence() {
    // The lane selector composes with the layout swap: a scalar-layout
    // bank stepped with scalar-lane scratch must match a sliced-layout
    // bank stepped with wide-lane scratch and wide bank kernels — the
    // two extremes of the representation/dispatch matrix (the pure
    // same-layout lane diff lives in tests/simd_equiv.rs).
    use tsetlin_index::util::SimdLanes;
    let mut rng = Rng::new(0xc105_5e17);
    let mut seed = 40_000u64;
    for &(clauses, n_lit) in &[(6usize, 70usize), (4, 200)] {
        for &weighted in &[false, true] {
            let (mut scalar, mut sliced) = random_pair(&mut rng, clauses, n_lit, 0.3, weighted);
            sliced.set_simd(SimdLanes::Wide);
            for trial in 0..40 {
                let ctx = FeedbackCtx::new([1.0, 3.0, 9.0][trial % 3], trial % 2 == 0, weighted);
                let lits = random_lits(&mut rng, n_lit, 0.5);
                let outputs = reference_outputs(&scalar, &lits);
                seed += 1;
                let mut rec_a = Recorder::default();
                let mut rec_b = Recorder::default();
                let mut rng_a = Rng::new(seed);
                let mut rng_b = Rng::new(seed);
                let mut scratch_a = FeedbackScratch::with_simd(n_lit, SimdLanes::Scalar);
                let mut scratch_b = FeedbackScratch::with_simd(n_lit, SimdLanes::Wide);
                let tag = format!("{clauses}x{n_lit} weighted={weighted} trial={trial}");
                let ua = update_clause_range(
                    &mut scalar, &mut rec_a, &mut rng_a, &ctx, &outputs, &lits, u32::MAX,
                    trial % 2 == 0, &mut scratch_a,
                );
                let ub = update_clause_range(
                    &mut sliced, &mut rec_b, &mut rng_b, &ctx, &outputs, &lits, u32::MAX,
                    trial % 2 == 0, &mut scratch_b,
                );
                assert_eq!(ua, ub, "{tag}: update counts diverge");
                assert_eq!(rec_a.events, rec_b.events, "{tag}: FlipSink streams diverge");
                assert_eq!(scalar.states(), sliced.states(), "{tag}: states diverge");
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{tag}: RNG positions diverge");
            }
            assert!(scalar.check_counts() && sliced.check_counts());
        }
    }
}

fn xor_params(weighted: bool, layout: TaLayout) -> TMParams {
    TMParams::new(2, 20, 8)
        .with_threshold(12)
        .with_s(4.0)
        .with_seed(77)
        .with_weighted(weighted)
        .with_ta_layout(layout)
}

#[test]
fn full_noisy_xor_training_runs_are_bit_identical_across_layouts() {
    let train = noisy_xor(8, 800, 0.05, 11);
    let test = noisy_xor(8, 200, 0.0, 12);
    for weighted in [false, true] {
        for backend in Backend::ALL {
            let mut machines = vec![];
            for layout in [TaLayout::Scalar, TaLayout::Sliced] {
                let mut tr = Trainer::new(xor_params(weighted, layout), backend);
                for _ in 0..8 {
                    tr.train_epoch(train.iter());
                }
                tr.check_invariants().unwrap();
                machines.push(tr);
            }
            let [a, b] = &mut machines[..] else { unreachable!() };
            for c in 0..2 {
                assert_eq!(
                    a.tm.bank(c).states(),
                    b.tm.bank(c).states(),
                    "{} weighted={weighted} class {c}: states diverge",
                    backend.name()
                );
                assert_eq!(a.tm.bank(c).weights(), b.tm.bank(c).weights());
            }
            for (lits, _) in test.iter() {
                assert_eq!(a.scores(lits), b.scores(lits));
            }
            // the sliced run still *learns* (sanity floor — the real
            // assertion of this test is the bit-identity above)
            let acc = b.accuracy(test.iter());
            assert!(acc > 0.85, "{} sliced accuracy {acc}", backend.name());
        }
    }
}

#[test]
fn parallel_training_is_bit_identical_across_layouts() {
    let train = noisy_xor(8, 200, 0.05, 21);
    for threads in [1usize, 2, 3] {
        let mut machines = vec![];
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let mut tr = ParallelTrainer::new(xor_params(false, layout), threads)
                .with_stale_window(4);
            for _ in 0..3 {
                tr.train_epoch(train.iter());
            }
            tr.check_invariants().unwrap();
            machines.push(tr);
        }
        let [a, b] = &mut machines[..] else { unreachable!() };
        for c in 0..2 {
            assert_eq!(
                a.tm().bank(c).states(),
                b.tm().bank(c).states(),
                "{threads} threads class {c}: states diverge"
            );
        }
    }
}

#[test]
fn one_worker_sliced_parallel_matches_sequential_sliced() {
    // the 1-worker == sequential bit-identity contract survives the
    // layout swap
    let train = noisy_xor(8, 200, 0.05, 31);
    let params = xor_params(true, TaLayout::Sliced);
    let mut seq = Trainer::new(params.clone(), Backend::Indexed);
    let mut par = ParallelTrainer::new(params, 1);
    for _ in 0..3 {
        seq.train_epoch(train.iter());
        par.train_epoch(train.iter());
    }
    for c in 0..2 {
        assert_eq!(seq.tm.bank(c).states(), par.tm().bank(c).states());
        assert_eq!(seq.tm.bank(c).weights(), par.tm().bank(c).weights());
    }
}
