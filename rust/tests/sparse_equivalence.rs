//! Differential testing of the O(nnz) sparse-delta inference engine.
//!
//! The sparse walk must be **bit-identical** to every dense evaluation
//! path — the class-fused engine, the per-class indexed evaluator, and
//! the reference semantics — on arbitrary machines (plain and
//! weighted, fresh and mid-training) and arbitrary k-hot inputs, and
//! its baseline/delta bookkeeping must survive arbitrary flip
//! sequences with invariants intact. Property tests driven by the
//! crate's deterministic RNG (fixed seeds, no shrinking).

use tsetlin_index::data::imdb;
use tsetlin_index::data::synth::{bow, noisy_xor};
use tsetlin_index::data::{Dataset, SparseDataset, SparseSample};
use tsetlin_index::engine::{
    BatchScorer, FusedEngine, InferMode, Maintenance, SparseEngine, SparseFusedIndex,
};
use tsetlin_index::eval::traits::{reference_score, FlipSink};
use tsetlin_index::eval::{Backend, Evaluator};
use tsetlin_index::index::IndexedEval;
use tsetlin_index::tm::bank::Flip;
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

/// Random machine with states forced through `set_state` (arbitrary
/// mid-training-shaped TA configurations), optionally with random
/// clause weights.
fn random_machine(
    rng: &mut Rng,
    classes: usize,
    clauses: usize,
    features: usize,
    density: f64,
    weighted: bool,
) -> MultiClassTM {
    let params = TMParams::new(classes, clauses, features).with_weighted(weighted);
    let mut tm = MultiClassTM::new(params);
    let n_lit = 2 * features;
    for c in 0..classes {
        let bank = tm.bank_mut(c);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    bank.set_state(j, k, (rng.below(11) as i8) - 5);
                }
            }
            if weighted {
                bank.set_weight(j, 1 + rng.below(7));
            }
        }
    }
    tm
}

fn random_khot(rng: &mut Rng, features: usize, density: f64) -> SparseSample {
    let set: Vec<u32> = (0..features as u32).filter(|_| rng.bern(density)).collect();
    SparseSample::new(features, set)
}

/// Assert the four paths agree on one machine + sample set: sparse
/// engine == fused engine == per-class IndexedEval == reference.
fn assert_all_paths_agree(tm: &MultiClassTM, samples: &[SparseSample], tag: &str) {
    let classes = tm.classes();
    let lits: Vec<BitVec> = samples.iter().map(SparseSample::to_literals).collect();
    let mut sparse = SparseEngine::from_machine(tm, 1);
    let mut fused = FusedEngine::from_machine(tm, 1);
    let mut evals: Vec<IndexedEval> = (0..classes).map(|_| IndexedEval::new(&tm.params)).collect();
    for (c, ev) in evals.iter_mut().enumerate() {
        ev.rebuild(tm.bank(c));
    }
    let mut s_out = vec![0i32; classes];
    let mut f_out = vec![0i32; classes];
    for (i, sample) in samples.iter().enumerate() {
        sparse.score_sparse_into(sample, &mut s_out);
        fused.scores_into(&lits[i], &mut f_out);
        assert_eq!(s_out, f_out, "{tag}: sparse != fused at sample {i}");
        for c in 0..classes {
            assert_eq!(
                s_out[c],
                evals[c].score(tm.bank(c), &lits[i]),
                "{tag}: sparse != IndexedEval at sample {i} class {c}"
            );
            assert_eq!(
                s_out[c],
                reference_score(tm.bank(c), &lits[i], false),
                "{tag}: sparse != reference at sample {i} class {c}"
            );
        }
    }
    // batch entry points (dense-literal and native-sparse) agree too
    let mut via_lits = vec![0i32; samples.len() * classes];
    sparse.score_batch_into(&lits, &mut via_lits);
    let mut via_sparse = vec![0i32; samples.len() * classes];
    sparse.score_sparse_batch_into(samples, &mut via_sparse);
    assert_eq!(via_lits, via_sparse, "{tag}: batch entry points diverge");
    let mut fused_batch = vec![0i32; samples.len() * classes];
    fused.score_batch_into(&lits, &mut fused_batch);
    assert_eq!(via_sparse, fused_batch, "{tag}: sparse batch != fused batch");
}

#[test]
fn property_random_machines_all_paths_agree() {
    let mut rng = Rng::new(0x5bab5e);
    for trial in 0..25 {
        let classes = 2 + rng.below(3) as usize;
        let clauses = 2 * (1 + rng.below(8) as usize);
        let features = 3 + rng.below(50) as usize;
        let weighted = trial % 2 == 1;
        let machine_density = 0.05 + rng.unit_f64() * 0.3;
        let tm = random_machine(&mut rng, classes, clauses, features, machine_density, weighted);
        let samples: Vec<SparseSample> = (0..12)
            .map(|_| {
                let d = rng.unit_f64() * 0.5;
                random_khot(&mut rng, features, d)
            })
            .collect();
        assert_all_paths_agree(&tm, &samples, &format!("trial {trial} weighted={weighted}"));
    }
}

#[test]
fn extreme_inputs_agree() {
    let mut rng = Rng::new(0xedfe);
    let tm = random_machine(&mut rng, 3, 10, 30, 0.2, true);
    let samples = vec![
        SparseSample::new(30, vec![]),               // all zeros
        SparseSample::new(30, (0..30).collect()),    // all ones
        SparseSample::new(30, vec![0]),              // single low bit
        SparseSample::new(30, vec![29]),             // single high bit
        SparseSample::new(30, vec![0, 29]),
    ];
    assert_all_paths_agree(&tm, &samples, "extremes");
}

/// Baseline/delta invariants hold after **every** insert/delete — the
/// sparse mirror of the dense index's flip-storm property, checked at
/// every step rather than only at the end.
#[test]
fn invariants_hold_after_every_flip() {
    let mut rng = Rng::new(0xf11b);
    for weighted in [false, true] {
        let classes = 2;
        let clauses = 6;
        let features = 8;
        let n_lit = 2 * features;
        let mut tm = random_machine(&mut rng, classes, clauses, features, 0.1, weighted);
        let mut idx = SparseFusedIndex::from_machine(&tm, Maintenance::Maintained);
        idx.check_invariants(&tm).unwrap();
        for step in 0..1200 {
            let c = rng.below(classes as u32) as usize;
            let j = rng.below(clauses as u32) as usize;
            let k = rng.below(n_lit as u32) as usize;
            let gid = idx.global_id(c, j);
            let bank = tm.bank_mut(c);
            let mut flipped = false;
            if rng.bern(0.5) {
                if bank.bump_up(j, k) == Flip::Included {
                    let (count, weight) = (bank.count(j), bank.weight(j));
                    idx.on_include(gid, k as u32, count, weight);
                    flipped = true;
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                let (count, weight) = (bank.count(j), bank.weight(j));
                idx.on_exclude(gid, k as u32, count, weight);
                flipped = true;
            }
            if weighted && rng.bern(0.1) {
                let nonempty = tm.bank(c).count(j) > 0;
                let delta = if rng.bern(0.5) { 1 } else { -1 };
                let w = tm.bank(c).weight(j) as i32;
                if w + delta >= 1 {
                    tm.bank_mut(c).set_weight(j, (w + delta) as u32);
                    idx.on_weight(gid, delta, nonempty);
                    flipped = true;
                }
            }
            if flipped {
                idx.check_invariants(&tm)
                    .unwrap_or_else(|e| panic!("step {step} weighted={weighted}: {e}"));
            }
        }
        // the stormed index still scores bit-identically
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; classes];
        for _ in 0..20 {
            let sample = random_khot(&mut rng, features, 0.3);
            let lits = sample.to_literals();
            idx.score_sparse_into(&mut scratch, sample.ones(), &mut out);
            for c in 0..classes {
                assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
            }
        }
    }
}

/// Mid-training states: after each epoch of real feedback (plain and
/// weighted), a fresh sparse snapshot scores bit-identically to the
/// dense paths, and the trainer's own auto/sparse/dense modes agree.
#[test]
fn mid_training_states_agree() {
    for weighted in [false, true] {
        let train = noisy_xor(12, 200, 0.1, 77);
        let params = TMParams::new(2, 16, 12)
            .with_threshold(10)
            .with_s(3.0)
            .with_weighted(weighted);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(0x7e57);
        let probe: Vec<SparseSample> = (0..25).map(|_| random_khot(&mut rng, 12, 0.3)).collect();
        for epoch in 0..4 {
            let order = train.epoch_order(&mut rng);
            tr.train_epoch(train.iter_order(&order));
            assert_all_paths_agree(
                &tr.tm,
                &probe,
                &format!("epoch {epoch} weighted={weighted}"),
            );
            // the trainer's engine selection never changes scores
            let probe_lits: Vec<BitVec> = probe.iter().map(SparseSample::to_literals).collect();
            let mut by_mode: Vec<Vec<i32>> = Vec::new();
            for mode in [InferMode::Dense, InferMode::Sparse, InferMode::Auto] {
                tr.set_infer_mode(mode);
                let mut flat = vec![0i32; probe_lits.len() * 2];
                tr.score_batch_into(&probe_lits, &mut flat);
                by_mode.push(flat);
            }
            assert_eq!(by_mode[0], by_mode[1], "dense != sparse (weighted={weighted})");
            assert_eq!(by_mode[0], by_mode[2], "dense != auto (weighted={weighted})");
        }
    }
}

/// The Zipf IMDb fallback (the workload the sparse engine exists for):
/// train on it, then check every path on real low-density documents.
#[test]
fn imdb_fallback_workload_agrees() {
    // the Zipf generator draws >= 120 tokens per document, so features
    // must be well above that for the workload to be genuinely sparse
    let features = 2000;
    let train = imdb::load_or_synthesize(None, features, 80, 0, 5);
    let test_sparse = imdb::load_or_synthesize_sparse(None, features, 40, 1, 5);
    assert!(
        test_sparse.mean_density() < 0.2,
        "synthetic IMDb should be sparse, got {}",
        test_sparse.mean_density()
    );
    let params = TMParams::new(2, 10, features).with_threshold(12).with_s(4.0);
    let mut tr = Trainer::new(params, Backend::Indexed);
    tr.train_epoch(train.iter());
    assert_all_paths_agree(&tr.tm, test_sparse.all_samples(), "imdb");
    // auto mode picks sparse on this workload and dense on a dense one
    let test_dense = test_sparse.to_dense();
    assert_eq!(
        tr.resolve_infer_mode(test_dense.all_literals()),
        InferMode::Sparse
    );
    let dense_lits: Vec<BitVec> = (0..10)
        .map(|i| {
            SparseSample::new(features, (0..features as u32).filter(|k| (k + i) % 2 == 0).collect())
                .to_literals()
        })
        .collect();
    assert_eq!(tr.resolve_infer_mode(&dense_lits), InferMode::Dense);
}

/// Thread sharding never changes sparse scores.
#[test]
fn sparse_sharding_is_bit_identical() {
    let mut rng = Rng::new(0x5aa2_d911);
    let tm = random_machine(&mut rng, 4, 12, 40, 0.15, true);
    let samples: Vec<SparseSample> = (0..64).map(|_| random_khot(&mut rng, 40, 0.1)).collect();
    let mut serial = SparseEngine::from_machine(&tm, 1);
    let mut want = vec![0i32; 64 * 4];
    serial.score_sparse_batch_into(&samples, &mut want);
    for threads in [2usize, 3, 8] {
        let mut eng = SparseEngine::from_machine(&tm, threads);
        let mut got = vec![0i32; 64 * 4];
        eng.score_sparse_batch_into(&samples, &mut got);
        assert_eq!(got, want, "{threads} threads");
    }
}

/// Dense↔sparse dataset conversion round-trips exactly, including
/// through the BoW file format.
#[test]
fn dataset_conversion_roundtrip() {
    let ds = bow(200, 40, 9);
    let sp = SparseDataset::from_dense(&ds);
    let back = sp.to_dense();
    for i in 0..ds.len() {
        assert_eq!(back.literals(i), ds.literals(i), "sample {i}");
        assert_eq!(back.label(i), ds.label(i));
    }
    let again = back.to_sparse();
    for i in 0..ds.len() {
        assert_eq!(again.sample(i), sp.sample(i));
    }
    let _ = Dataset::from_literal_vecs(
        "t",
        ds.features,
        ds.classes,
        (0..ds.len()).map(|i| ds.literals(i).clone()).collect(),
        (0..ds.len()).map(|i| ds.label(i)).collect(),
    );
}
