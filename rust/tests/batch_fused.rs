//! Batch / class-fused equivalence — the engine's correctness
//! contract: `score_batch` must equal per-sample `reference_score` for
//! every backend and for the fused engine, on arbitrary machines,
//! through flip storms, and across thread counts.
//!
//! Property tests driven by the crate's deterministic RNG (no proptest
//! in the offline build; fixed seeds cover the same invariant space).

use tsetlin_index::engine::{BatchScorer, FusedEngine, FusedIndex, Maintenance};
use tsetlin_index::eval::traits::{reference_score, FlipSink};
use tsetlin_index::eval::{Backend, Evaluator};
use tsetlin_index::tm::bank::Flip;
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

fn random_machine(rng: &mut Rng, classes: usize, clauses: usize, features: usize) -> MultiClassTM {
    let mut tm = MultiClassTM::new(TMParams::new(classes, clauses, features));
    let n_lit = 2 * features;
    let density = rng.unit_f64() * 0.35;
    for c in 0..classes {
        let bank = tm.bank_mut(c);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    bank.set_state(j, k, (rng.below(11) as i8) - 5);
                }
            }
        }
    }
    tm
}

fn random_batch(rng: &mut Rng, n: usize, n_lit: usize) -> Vec<BitVec> {
    (0..n)
        .map(|_| {
            let p = rng.unit_f64();
            BitVec::from_bools(&(0..n_lit).map(|_| rng.bern(p)).collect::<Vec<_>>())
        })
        .collect()
}

/// Reference score matrix: per-sample, per-class, straight from the
/// trait's documented semantics.
fn reference_matrix(tm: &MultiClassTM, batch: &[BitVec]) -> Vec<Vec<i32>> {
    batch
        .iter()
        .map(|lits| {
            (0..tm.classes())
                .map(|c| reference_score(tm.bank(c), lits, false))
                .collect()
        })
        .collect()
}

/// Property: `Evaluator::score_batch` (the per-class hook every
/// backend inherits) equals per-sample `reference_score` on random
/// machines.
#[test]
fn property_evaluator_score_batch_matches_reference() {
    let mut rng = Rng::new(7001);
    for trial in 0..30 {
        let classes = 2 + rng.below(3) as usize;
        let clauses = 2 * (1 + rng.below(8) as usize);
        let features = 1 + rng.below(40) as usize;
        let tm = random_machine(&mut rng, classes, clauses, features);
        let batch = random_batch(&mut rng, 1 + rng.below(20) as usize, 2 * features);
        let params = tm.params.clone();
        for backend in Backend::ALL {
            let mut ev = backend.make(&params);
            for c in 0..classes {
                ev.rebuild(tm.bank(c));
                let mut out = vec![0i32; batch.len()];
                ev.score_batch(tm.bank(c), &batch, &mut out);
                for (i, lits) in batch.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        reference_score(tm.bank(c), lits, false),
                        "{} class {c} sample {i} trial {trial}",
                        backend.name()
                    );
                }
            }
        }
    }
}

/// Property: the fused engine's `score_batch` equals the reference
/// matrix on random machines, for serial and sharded configurations.
#[test]
fn property_fused_score_batch_matches_reference() {
    let mut rng = Rng::new(7002);
    for trial in 0..25 {
        let classes = 2 + rng.below(5) as usize;
        let clauses = 2 * (1 + rng.below(10) as usize);
        let features = 1 + rng.below(50) as usize;
        let tm = random_machine(&mut rng, classes, clauses, features);
        let batch = random_batch(&mut rng, 1 + rng.below(40) as usize, 2 * features);
        let want = reference_matrix(&tm, &batch);
        for threads in [1usize, 3] {
            let mut eng = FusedEngine::from_machine(&tm, threads);
            assert_eq!(eng.classes(), classes);
            assert_eq!(eng.n_literals(), 2 * features);
            assert_eq!(eng.score_batch(&batch), want, "trial {trial} threads {threads}");
        }
    }
}

/// Property: after a random include/exclude flip storm driven through
/// the `FlipSink` hooks (the `maintenance_tracks_random_flips`
/// pattern, fused across classes), the maintained index still scores
/// exactly like the reference — and its structural invariants hold.
#[test]
fn property_fused_index_survives_flip_storms() {
    let mut rng = Rng::new(7003);
    for trial in 0..10 {
        let classes = 2 + rng.below(3) as usize;
        let clauses = 2 * (2 + rng.below(6) as usize);
        let features = 2 + rng.below(20) as usize;
        let n_lit = 2 * features;
        let mut tm = random_machine(&mut rng, classes, clauses, features);
        let mut idx = FusedIndex::from_machine(&tm, Maintenance::Maintained);
        for _ in 0..5000 {
            let c = rng.below(classes as u32) as usize;
            let j = rng.below(clauses as u32) as usize;
            let k = rng.below(n_lit as u32) as usize;
            let gid = idx.global_id(c, j);
            let bank = tm.bank_mut(c);
            if rng.bern(0.55) {
                if bank.bump_up(j, k) == Flip::Included {
                    let (count, weight) = (bank.count(j), bank.weight(j));
                    idx.on_include(gid, k as u32, count, weight);
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                let (count, weight) = (bank.count(j), bank.weight(j));
                idx.on_exclude(gid, k as u32, count, weight);
            }
        }
        idx.check_invariants(&tm)
            .unwrap_or_else(|e| panic!("trial {trial}: {e}"));
        let batch = random_batch(&mut rng, 12, n_lit);
        let want = reference_matrix(&tm, &batch);
        let mut eng = FusedEngine::from_index(idx, 2);
        assert_eq!(eng.score_batch(&batch), want, "trial {trial}");
    }
}

/// The trainer's serving path (fused engine for the indexed backend,
/// per-class sweeps otherwise) is bit-identical across backends on a
/// *trained* machine — the shape the coordinator actually serves.
#[test]
fn trained_machine_batch_scores_agree_across_backends() {
    use tsetlin_index::data::synth::{image_dataset, ImageStyle};
    let all = image_dataset(ImageStyle::Digits, 4, 220, 1, 77);
    let train = all.slice(0, 160);
    let test = all.slice(160, 220);
    let params = TMParams::from_total_clauses(4, 96, train.features).with_seed(3);
    let mut indexed = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(9);
    for _ in 0..3 {
        let order = train.epoch_order(&mut order_rng);
        indexed.train_epoch(train.iter_order(&order));
    }
    let batch: Vec<BitVec> = (0..test.len()).map(|i| test.literals(i).clone()).collect();
    let m = 4;
    let mut fused_flat = vec![0i32; batch.len() * m];
    indexed.score_batch_into(&batch, &mut fused_flat);
    for backend in [Backend::Naive, Backend::BitPacked] {
        let mut tr = Trainer::from_machine(indexed.tm.clone(), backend);
        let mut flat = vec![0i32; batch.len() * m];
        tr.score_batch_into(&batch, &mut flat);
        assert_eq!(flat, fused_flat, "{}", backend.name());
    }
    // and the engine agrees with the per-sample reference
    let want = reference_matrix(&indexed.tm, &batch);
    for (i, row) in fused_flat.chunks(m).enumerate() {
        assert_eq!(row, want[i].as_slice(), "sample {i}");
    }
}

/// Thread sharding is an implementation detail: any worker count gives
/// byte-identical output, including degenerate batch sizes.
#[test]
fn sharding_is_invisible_in_results() {
    let mut rng = Rng::new(7005);
    let tm = random_machine(&mut rng, 6, 14, 30);
    let mut serial = FusedEngine::from_machine(&tm, 1);
    for batch_len in [0usize, 1, 3, 7, 64, 130] {
        let batch = random_batch(&mut rng, batch_len, 60);
        let want = serial.score_batch(&batch);
        for threads in [2usize, 4, 9] {
            let mut eng = FusedEngine::from_machine(&tm, threads);
            assert_eq!(
                eng.score_batch(&batch),
                want,
                "batch {batch_len} threads {threads}"
            );
        }
    }
}
