//! Runtime round-trip: the AOT-compiled XLA artifact (Layers 1/2) must
//! score batches bit-identically to the Rust evaluators (Layer 3).
//!
//! Requires `make artifacts`; tests skip (with a notice) when
//! `artifacts/manifest.json` is absent so `cargo test` stays green on a
//! fresh checkout.

use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::eval::Backend;
use tsetlin_index::runtime::{Manifest, Runtime};
use tsetlin_index::tm::io::DenseModel;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

const FEATURES: usize = 784;
const CLAUSES_TOTAL: usize = 1280;
const CLASSES: usize = 10;

fn artifacts() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn trained_model() -> Trainer {
    let all = image_dataset(ImageStyle::Digits, CLASSES, 700, 1, 42);
    let train = all.slice(0, 600);
    let params = TMParams::from_total_clauses(CLASSES, CLAUSES_TOTAL, FEATURES)
        .with_threshold(20)
        .with_s(5.0)
        .with_seed(8);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(3);
    for _ in 0..2 {
        let order = train.epoch_order(&mut order_rng);
        tr.train_epoch(train.iter_order(&order));
    }
    tr
}

#[test]
fn manifest_lists_expected_variants() {
    let Some(m) = artifacts() else { return };
    assert!(m.by_name("tm_b32_f784_c1280_m10").is_some());
    assert!(m.by_name("tm_b1_f784_c1280_m10").is_some());
    let v = m.pick(32, FEATURES, CLAUSES_TOTAL, CLASSES).unwrap();
    assert_eq!(v.batch, 32);
}

#[test]
fn xla_scores_match_cpu_exactly() {
    let Some(manifest) = artifacts() else { return };
    let mut tr = trained_model();
    let dense = DenseModel::from_tm(&tr.tm);
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let meta = manifest
        .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
        .unwrap()
        .clone();
    let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta).unwrap();
    let prepared = rt.prepare_model(&exe, &dense).unwrap();

    let all = image_dataset(ImageStyle::Digits, CLASSES, 96, 1, 77);
    let n_lit = 2 * FEATURES;
    for chunk_start in (0..96).step_by(32) {
        let rows = 32usize;
        let mut lits = vec![0f32; rows * n_lit];
        for b in 0..rows {
            for k in all.literals(chunk_start + b).iter_ones() {
                lits[b * n_lit + k] = 1.0;
            }
        }
        let fwd = exe.run(&rt, &prepared, &lits, rows).unwrap();
        for b in 0..rows {
            let want = tr.scores(all.literals(chunk_start + b));
            for (i, &w) in want.iter().enumerate() {
                assert_eq!(
                    fwd.scores[b * CLASSES + i],
                    w as f32,
                    "row {b} class {i}"
                );
            }
            // prediction consistent with CPU argmax
            assert_eq!(
                fwd.predictions[b] as usize,
                tr.predict(all.literals(chunk_start + b)),
                "row {b}"
            );
        }
    }
}

#[test]
fn short_batches_are_padded_and_truncated() {
    let Some(manifest) = artifacts() else { return };
    let mut tr = trained_model();
    let dense = DenseModel::from_tm(&tr.tm);
    let rt = Runtime::cpu().unwrap();
    let meta = manifest
        .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
        .unwrap()
        .clone();
    let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta).unwrap();
    let prepared = rt.prepare_model(&exe, &dense).unwrap();

    let all = image_dataset(ImageStyle::Digits, CLASSES, 5, 1, 78);
    let n_lit = 2 * FEATURES;
    let rows = 5usize;
    let mut lits = vec![0f32; rows * n_lit];
    for b in 0..rows {
        for k in all.literals(b).iter_ones() {
            lits[b * n_lit + k] = 1.0;
        }
    }
    let fwd = exe.run(&rt, &prepared, &lits, rows).unwrap();
    assert_eq!(fwd.predictions.len(), rows);
    assert_eq!(fwd.scores.len(), rows * CLASSES);
    for b in 0..rows {
        assert_eq!(fwd.predictions[b] as usize, tr.predict(all.literals(b)));
    }
}

#[test]
fn unfused_variant_agrees_with_fused() {
    let Some(manifest) = artifacts() else { return };
    let Some(unfused) = manifest.by_name("tm_b32_f784_c1280_m10_unfused") else {
        eprintln!("SKIP: unfused variant not in manifest");
        return;
    };
    let tr = trained_model();
    let dense = DenseModel::from_tm(&tr.tm);
    let rt = Runtime::cpu().unwrap();
    let fused_meta = manifest.by_name("tm_b32_f784_c1280_m10").unwrap().clone();
    let fused = rt
        .load_artifact(&manifest.hlo_path(&fused_meta), fused_meta)
        .unwrap();
    let unfused_exe = rt
        .load_artifact(&manifest.hlo_path(unfused), unfused.clone())
        .unwrap();

    let all = image_dataset(ImageStyle::Digits, CLASSES, 32, 1, 79);
    let n_lit = 2 * FEATURES;
    let mut lits = vec![0f32; 32 * n_lit];
    for b in 0..32 {
        for k in all.literals(b).iter_ones() {
            lits[b * n_lit + k] = 1.0;
        }
    }
    let a = fused.run_unprepared(&rt, &dense, &lits, 32).unwrap();
    let b = unfused_exe.run_unprepared(&rt, &dense, &lits, 32).unwrap();
    assert_eq!(a.scores, b.scores);
    assert_eq!(a.predictions, b.predictions);
}

#[test]
fn weighted_model_scores_match_through_xla() {
    // The same artifact serves weighted machines: ±weight rides in the
    // polarity matrix (DenseModel::from_tm), no recompilation needed.
    let Some(manifest) = artifacts() else { return };
    let all = image_dataset(ImageStyle::Digits, CLASSES, 500, 1, 52);
    let params = TMParams::from_total_clauses(CLASSES, CLAUSES_TOTAL, FEATURES)
        .with_threshold(20)
        .with_s(5.0)
        .with_seed(13)
        .with_weighted(true);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(6);
    for _ in 0..2 {
        let order = all.epoch_order(&mut order_rng);
        tr.train_epoch(all.iter_order(&order));
    }
    let has_weights = (0..CLASSES)
        .any(|i| tr.tm.bank(i).weights().iter().any(|&w| w > 1));
    assert!(has_weights, "weighted training should move weights");

    let dense = DenseModel::from_tm(&tr.tm);
    let rt = Runtime::cpu().unwrap();
    let meta = manifest
        .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
        .unwrap()
        .clone();
    let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta).unwrap();
    let prepared = rt.prepare_model(&exe, &dense).unwrap();
    let n_lit = 2 * FEATURES;
    let mut lits = vec![0f32; 32 * n_lit];
    for b in 0..32 {
        for k in all.literals(b).iter_ones() {
            lits[b * n_lit + k] = 1.0;
        }
    }
    let fwd = exe.run(&rt, &prepared, &lits, 32).unwrap();
    for b in 0..32 {
        let want = tr.scores(all.literals(b));
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(fwd.scores[b * CLASSES + i], w as f32, "row {b} class {i}");
        }
    }
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(manifest) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = manifest
        .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
        .unwrap()
        .clone();
    let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta).unwrap();
    // model with the wrong clause count
    let params = TMParams::new(CLASSES, 64, FEATURES);
    let tm = tsetlin_index::tm::classifier::MultiClassTM::new(params);
    let dense = DenseModel::from_tm(&tm);
    assert!(rt.prepare_model(&exe, &dense).is_err());
}
