//! Serving integration: train → persist → reload → coordinate → TCP —
//! plus the production-hardening criteria: overload shedding, hot swap
//! under live traffic, and the loadgen → `BENCH_serve.json` pipeline.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tsetlin_index::coordinator::server::serve_tcp;
use tsetlin_index::coordinator::{
    loadgen, BatchPolicy, Coordinator, CpuBackend, LoadgenConfig, RouteConfig,
};
use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Json, Rng};

fn train_and_save(path: &std::path::Path) -> (Dataset, f64) {
    let all = image_dataset(ImageStyle::Digits, 4, 700, 1, 55);
    let train = all.slice(0, 500);
    let test = all.slice(500, 700);
    let params = TMParams::from_total_clauses(4, 120, train.features)
        .with_threshold(20)
        .with_s(5.0);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(2);
    for _ in 0..4 {
        let order = train.epoch_order(&mut order_rng);
        tr.train_epoch(train.iter_order(&order));
    }
    let acc = tr.accuracy(test.iter());
    io::save(&tr.tm, path).unwrap();
    (test, acc)
}

#[test]
fn train_save_reload_serve_over_tcp() {
    let model_path = std::env::temp_dir().join(format!("tmi-e2e-{}.tm", std::process::id()));
    let (test, trained_acc) = train_and_save(&model_path);
    assert!(trained_acc > 0.6, "model should learn, got {trained_acc}");

    // reload and register under two backends
    let tm = io::load(&model_path).unwrap();
    let mut coord = Coordinator::new();
    coord.register(
        "indexed",
        Box::new(CpuBackend::new(tm.clone(), Backend::Indexed)),
        BatchPolicy::default(),
    );
    coord.register(
        "naive",
        Box::new(CpuBackend::new(tm, Backend::Naive)),
        BatchPolicy::default(),
    );
    assert_eq!(coord.models(), vec!["indexed".to_string(), "naive".to_string()]);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

    // drive both routes over one connection; they must agree
    let conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut conn_w = conn.try_clone().unwrap();
    let mut served_correct = 0usize;
    let n = 40usize;
    for i in 0..n {
        let bits: String = (0..test.features)
            .map(|k| if test.literals(i).get(k) { '1' } else { '0' })
            .collect();
        let mut replies = Vec::new();
        for route in ["indexed", "naive"] {
            conn_w
                .write_all(format!("{route} {bits}\n").as_bytes())
                .unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.starts_with("ok "), "reply: {reply}");
            replies.push(reply);
        }
        assert_eq!(replies[0], replies[1], "routes disagree on sample {i}");
        let class: usize = replies[0].split_whitespace().nth(1).unwrap().parse().unwrap();
        if class == test.label(i) {
            served_correct += 1;
        }
    }
    // served accuracy should track trained accuracy
    let served_acc = served_correct as f64 / n as f64;
    assert!(
        (served_acc - trained_acc).abs() < 0.25,
        "served {served_acc} vs trained {trained_acc}"
    );

    let m = coord.metrics("indexed").unwrap();
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.errors, 0);

    stop.store(true, Ordering::Relaxed);
    drop(conn_w);
    drop(reader);
    drop(conn);
    server.join().unwrap().unwrap();
    coord.shutdown();
    std::fs::remove_file(&model_path).unwrap();
}

/// Small random-but-learnable trainer for the hardening tests.
fn quick_trainer(seed: u64) -> Trainer {
    let params = TMParams::new(3, 16, 24).with_seed(seed).with_threshold(12);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let samples: Vec<(BitVec, usize)> = (0..250)
        .map(|_| {
            let y = rng.below(3) as usize;
            let bits: Vec<bool> = (0..24).map(|k| k % 3 == y || rng.bern(0.25)).collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            (BitVec::from_bools(&lits), y)
        })
        .collect();
    for _ in 0..3 {
        tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
    }
    tr
}

fn random_probe(rng: &mut Rng, features: usize) -> BitVec {
    let bits: Vec<bool> = (0..features).map(|_| rng.bern(0.4)).collect();
    let mut lits = bits.clone();
    lits.extend(bits.iter().map(|b| !b));
    BitVec::from_bools(&lits)
}

/// A backend slow enough to saturate a tiny queue: drives the
/// overload-shedding criterion over real TCP.
struct SlowBackend;

impl tsetlin_index::coordinator::ServeBackend for SlowBackend {
    fn infer_batch(
        &mut self,
        batch: &[BitVec],
    ) -> anyhow::Result<Vec<tsetlin_index::coordinator::backend::Scored>> {
        std::thread::sleep(Duration::from_millis(4));
        Ok(batch
            .iter()
            .map(|_| tsetlin_index::coordinator::backend::Scored {
                prediction: 0,
                scores: vec![0, 0],
            })
            .collect())
    }
    fn n_literals(&self) -> usize {
        8
    }
    fn name(&self) -> String {
        "slow".into()
    }
}

/// Under sustained overload the server sheds with `err overloaded`
/// instead of queueing unboundedly — and keeps serving afterwards.
#[test]
fn overload_sheds_over_tcp_instead_of_queueing() {
    let mut coord = Coordinator::new();
    coord
        .register_with_config(
            "slow",
            || Ok(Box::new(SlowBackend) as _),
            RouteConfig {
                workers: 1,
                queue_cap: 2,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::ZERO,
                },
                ..RouteConfig::default()
            },
        )
        .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

    let clients: Vec<_> = (0..12)
        .map(|_| {
            std::thread::spawn(move || {
                let conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                let mut conn = conn;
                let (mut ok, mut shed) = (0u64, 0u64);
                for _ in 0..8 {
                    conn.write_all(b"infer slow 0000\n").unwrap();
                    let mut reply = String::new();
                    reader.read_line(&mut reply).unwrap();
                    if reply.starts_with("ok ") {
                        ok += 1;
                    } else if reply.starts_with("err overloaded") {
                        shed += 1;
                    } else {
                        panic!("unexpected reply: {reply}");
                    }
                }
                (ok, shed)
            })
        })
        .collect();
    let (mut ok, mut shed) = (0u64, 0u64);
    for c in clients {
        let (o, s) = c.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, 96, "every request must be answered");
    assert!(shed > 0, "12 conns vs queue_cap=2 must shed");
    assert!(ok > 0, "admitted requests must complete");

    // the stats verb agrees with the client-side tallies and the
    // server still answers after the storm
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.write_all(b"stats slow\n").unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    assert!(reply.starts_with("ok model=slow"), "reply: {reply}");
    assert!(reply.contains(&format!("shed={shed}")), "reply: {reply}");
    assert!(reply.contains(&format!("completed={ok}")), "reply: {reply}");

    stop.store(true, Ordering::Relaxed);
    drop(conn);
    drop(reader);
    server.join().unwrap().unwrap();
    coord.shutdown();
}

/// A swap mid-traffic never drops, tears, or mis-scores a request:
/// every reply matches one of the two published snapshots bit-exactly,
/// traffic flows on both sides of the swap, and after the swap the new
/// version serves.
#[test]
fn hot_swap_mid_traffic_is_atomic_and_lossless() {
    let mut tr_a = quick_trainer(11);
    let mut tr_b = quick_trainer(29);
    let mut rng = Rng::new(77);
    let probes: Vec<BitVec> = (0..24).map(|_| random_probe(&mut rng, 24)).collect();
    let expected_a: Vec<Vec<i32>> = probes.iter().map(|p| tr_a.scores(p)).collect();
    let expected_b: Vec<Vec<i32>> = probes.iter().map(|p| tr_b.scores(p)).collect();
    assert!(
        expected_a != expected_b,
        "the two models must be distinguishable for this test to bite"
    );

    let mut coord = Coordinator::new();
    coord.register_model(
        "m",
        tr_a.publish(),
        RouteConfig {
            workers: 3,
            queue_cap: 4096,
            policy: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            ..RouteConfig::default()
        },
    );
    let h = coord.handle();
    let run = Arc::new(AtomicBool::new(true));
    let clients: Vec<_> = (0..6)
        .map(|c| {
            let h = h.clone();
            let run = Arc::clone(&run);
            let probes = probes.clone();
            let expected_a = expected_a.clone();
            let expected_b = expected_b.clone();
            std::thread::spawn(move || {
                let (mut a_hits, mut b_hits) = (0u64, 0u64);
                let mut i = c; // stagger probe phase across clients
                while run.load(Ordering::Relaxed) {
                    let k = i % probes.len();
                    i += 1;
                    let p = h.infer("m", probes[k].clone()).expect("no request may fail");
                    let is_a = p.scores == expected_a[k];
                    let is_b = p.scores == expected_b[k];
                    assert!(
                        is_a || is_b,
                        "torn reply on probe {k}: {:?} matches neither snapshot",
                        p.scores
                    );
                    // count only version-exclusive matches: probes where
                    // the two snapshots agree prove nothing about which
                    // version served
                    if is_a && !is_b {
                        a_hits += 1;
                    }
                    if is_b && !is_a {
                        b_hits += 1;
                    }
                }
                (a_hits, b_hits)
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(60));
    let retired = coord.swap("m", tr_b.publish()).unwrap();
    assert_eq!(retired, 1);
    std::thread::sleep(Duration::from_millis(60));
    run.store(false, Ordering::Relaxed);
    let mut only_a = 0u64; // replies matching a exclusively, resp. b
    let mut only_b = 0u64;
    for c in clients {
        let (a, b) = c.join().unwrap();
        only_a += a;
        only_b += b;
    }
    // traffic flowed on both sides of the swap
    assert!(only_a > 0, "no pre-swap traffic observed");
    assert!(only_b > 0, "no post-swap traffic observed");

    // after the swap, fresh requests serve the new snapshot exactly
    for (k, p) in probes.iter().enumerate() {
        let got = h.infer("m", p.clone()).unwrap();
        assert_eq!(got.scores, expected_b[k], "post-swap probe {k}");
    }
    let st = coord.stats("m").unwrap();
    assert_eq!(st.version, Some(1)); // tr_b's first publish
    assert_eq!(st.generation, Some(1)); // ...but the route counted the swap
    assert_eq!(st.metrics.errors, 0);
    coord.shutdown();
}

/// `tmi loadgen`'s engine drives a live TCP server and produces a
/// well-formed `BENCH_serve.json` in both loop disciplines.
#[test]
fn loadgen_writes_wellformed_bench_json() {
    let mut tr = quick_trainer(5);
    let mut coord = Coordinator::new();
    coord.register_model(
        "cpu",
        tr.publish(),
        RouteConfig {
            workers: 2,
            queue_cap: 256,
            policy: BatchPolicy::default(),
            ..RouteConfig::default()
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

    for (rate, mode) in [(0.0, "closed"), (400.0, "open")] {
        let cfg = LoadgenConfig {
            addr: addr.to_string(),
            model: "cpu".into(),
            connections: 2,
            rate,
            duration: Duration::from_millis(400),
            features: 24,
            seed: 3,
        };
        let report = loadgen::run(&cfg).unwrap();
        assert_eq!(report.mode, mode);
        assert!(report.sent > 0, "{mode}: nothing sent");
        assert!(report.ok > 0, "{mode}: nothing served");
        assert_eq!(report.errors, 0, "{mode}: unexpected errors");
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
        // shed-rate sanity: this load is far below capacity
        assert!(
            report.shed_rate < 0.5,
            "{mode}: implausible shed rate {}",
            report.shed_rate
        );
        let stats = report.server_stats.as_deref().unwrap_or("");
        assert!(stats.contains("model=cpu"), "stats: {stats}");

        // the BENCH_serve.json payload round-trips through the parser
        let path = std::env::temp_dir().join(format!(
            "tmi-bench-serve-{}-{mode}.json",
            std::process::id()
        ));
        tsetlin_index::bench_harness::report::write_json(&path, &report.to_json(&cfg))
            .unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve_load"));
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some(mode));
        assert_eq!(
            parsed.get("ok").unwrap().as_usize(),
            Some(report.ok as usize)
        );
        assert!(parsed.get("latency_us").unwrap().get("p99").unwrap().as_f64().is_some());
        assert!(parsed.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_file(&path).unwrap();
    }

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();
}
