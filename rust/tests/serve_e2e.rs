//! Serving integration: train → persist → reload → coordinate → TCP.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use tsetlin_index::coordinator::server::serve_tcp;
use tsetlin_index::coordinator::{BatchPolicy, Coordinator, CpuBackend};
use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

fn train_and_save(path: &std::path::Path) -> (Dataset, f64) {
    let all = image_dataset(ImageStyle::Digits, 4, 700, 1, 55);
    let train = all.slice(0, 500);
    let test = all.slice(500, 700);
    let params = TMParams::from_total_clauses(4, 120, train.features)
        .with_threshold(20)
        .with_s(5.0);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(2);
    for _ in 0..4 {
        let order = train.epoch_order(&mut order_rng);
        tr.train_epoch(train.iter_order(&order));
    }
    let acc = tr.accuracy(test.iter());
    io::save(&tr.tm, path).unwrap();
    (test, acc)
}

#[test]
fn train_save_reload_serve_over_tcp() {
    let model_path = std::env::temp_dir().join(format!("tmi-e2e-{}.tm", std::process::id()));
    let (test, trained_acc) = train_and_save(&model_path);
    assert!(trained_acc > 0.6, "model should learn, got {trained_acc}");

    // reload and register under two backends
    let tm = io::load(&model_path).unwrap();
    let mut coord = Coordinator::new();
    coord.register(
        "indexed",
        Box::new(CpuBackend::new(tm.clone(), Backend::Indexed)),
        BatchPolicy::default(),
    );
    coord.register(
        "naive",
        Box::new(CpuBackend::new(tm, Backend::Naive)),
        BatchPolicy::default(),
    );
    assert_eq!(coord.models(), vec!["indexed".to_string(), "naive".to_string()]);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

    // drive both routes over one connection; they must agree
    let conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut conn_w = conn.try_clone().unwrap();
    let mut served_correct = 0usize;
    let n = 40usize;
    for i in 0..n {
        let bits: String = (0..test.features)
            .map(|k| if test.literals(i).get(k) { '1' } else { '0' })
            .collect();
        let mut replies = Vec::new();
        for route in ["indexed", "naive"] {
            conn_w
                .write_all(format!("{route} {bits}\n").as_bytes())
                .unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.starts_with("ok "), "reply: {reply}");
            replies.push(reply);
        }
        assert_eq!(replies[0], replies[1], "routes disagree on sample {i}");
        let class: usize = replies[0].split_whitespace().nth(1).unwrap().parse().unwrap();
        if class == test.label(i) {
            served_correct += 1;
        }
    }
    // served accuracy should track trained accuracy
    let served_acc = served_correct as f64 / n as f64;
    assert!(
        (served_acc - trained_acc).abs() < 0.25,
        "served {served_acc} vs trained {trained_acc}"
    );

    let m = coord.metrics("indexed").unwrap();
    assert_eq!(m.completed, n as u64);
    assert_eq!(m.errors, 0);

    stop.store(true, Ordering::Relaxed);
    drop(conn_w);
    drop(reader);
    drop(conn);
    server.join().unwrap().unwrap();
    coord.shutdown();
    std::fs::remove_file(&model_path).unwrap();
}
