//! End-to-end online learning (`tmi serve --feedback`): the paper's
//! "constant-time updating, thus use also during learning" claim
//! exercised over the real TCP protocol.
//!
//! Two witnesses:
//! * **Bit-identity** — interleaved `infer` + `feedback`/`train`
//!   traffic yields a served model whose state digest equals the same
//!   labeled examples applied offline through a plain [`Trainer`] in
//!   arrival order; a second round after the first check proves the
//!   RNG streams are positioned identically too (a divergent draw
//!   would split the digests immediately).
//! * **Durability** — `kill -9` mid-feedback, restart, WAL replay:
//!   the restarted server republishes the exact pre-crash machine
//!   (digest equality against an offline replay of the same events)
//!   and `registry verify` stays clean.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::Command;
use std::time::{Duration, Instant};

use tsetlin_index::coordinator::online::{replay_feedback, reseed_seed};
use tsetlin_index::data::Dataset;
use tsetlin_index::engine::InferMode;
use tsetlin_index::eval::Backend;
use tsetlin_index::registry::{FeedbackWal, Registry};
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::io;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng};

fn tmi() -> Command {
    Command::new(env!("CARGO_BIN_EXE_tmi"))
}

fn free_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    l.local_addr().unwrap().port()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "tmi-online-{tag}-{}-{}",
        std::process::id(),
        std::thread::current().name().unwrap_or("t").replace("::", "-")
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trained(seed: u64) -> MultiClassTM {
    let params = TMParams::new(2, 16, 12).with_seed(seed);
    let mut tr = Trainer::new(params, Backend::Indexed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let samples: Vec<(BitVec, usize)> = (0..120)
        .map(|_| {
            let y = rng.bern(0.5) as usize;
            let bits: Vec<bool> = (0..12)
                .map(|k| if k == 0 { y == 1 } else { rng.bern(0.4) })
                .collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            (BitVec::from_bools(&lits), y)
        })
        .collect();
    for _ in 0..2 {
        tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
    }
    tr.tm
}

fn bits_string(bools: &[bool]) -> String {
    bools.iter().map(|b| if *b { '1' } else { '0' }).collect()
}

/// Block until the server answers `line` with an `ok …` reply.
fn wait_ready(addr: &str, line: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while Instant::now() < deadline {
        if let Some(reply) = request_once(addr, line) {
            if reply.starts_with("ok ") {
                return;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("server at {addr} never answered '{}'", line.trim_end());
}

/// One request over a fresh connection; `None` on any transport error.
fn request_once(addr: &str, line: &str) -> Option<String> {
    let stream = TcpStream::connect(addr).ok()?;
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok()?;
    let mut reader = BufReader::new(stream.try_clone().ok()?);
    let mut stream = stream;
    stream.write_all(line.as_bytes()).ok()?;
    let mut reply = String::new();
    reader.read_line(&mut reply).ok()?;
    (!reply.is_empty()).then_some(reply)
}

/// One request on an established session (strictly request-ordered).
fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> String {
    stream.write_all(line.as_bytes()).unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply
}

fn stat_get(stats: &str, key: &str) -> Option<String> {
    let prefix = format!("{key}=");
    stats
        .split_whitespace()
        .find_map(|t| t.strip_prefix(prefix.as_str()).map(str::to_string))
}

/// Poll `stats <model>` until its digest equals `want` (publishes
/// happen on the learner thread after the ack, so digest equality is
/// eventually consistent); returns the final stats line.
fn poll_digest(addr: &str, model: &str, want: u32) -> String {
    let line = format!("stats {model}\n");
    let deadline = Instant::now() + Duration::from_secs(15);
    let mut last = String::new();
    while Instant::now() < deadline {
        if let Some(reply) = request_once(addr, &line) {
            if stat_get(&reply, "digest") == Some(want.to_string()) {
                return reply;
            }
            last = reply;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    panic!("served digest never reached {want}; last stats: {}", last.trim_end());
}

#[test]
fn interleaved_online_feedback_is_bit_identical_to_offline_replay() {
    let dir = temp_dir("bitident");
    let tm = trained(7);
    let model_path = dir.join("model.tm");
    io::save(&tm, model_path.to_str().unwrap()).unwrap();

    // labeled events in the exact order they will arrive (one
    // connection => arrival order is send order)
    let mut rng = Rng::new(99);
    let events: Vec<(usize, Vec<bool>)> = (0..35)
        .map(|_| {
            let label = rng.below(2) as usize;
            let bools: Vec<bool> = (0..12).map(|_| rng.bern(0.5)).collect();
            (label, bools)
        })
        .collect();

    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi()
        .args([
            "serve",
            "--model",
            model_path.to_str().unwrap(),
            "--feedback",
            "--publish-every",
            "1",
            "--publish-interval",
            "0",
            "--listen",
            &addr,
        ])
        .spawn()
        .unwrap();
    let probe = format!("infer cpu {}\n", bits_string(&events[0].1));
    wait_ready(&addr, &probe);

    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;

    // phase 1: 30 interleaved infer+feedback, then one 3-example train
    for (label, bools) in &events[..30] {
        let bits = bits_string(bools);
        let infer = request(&mut stream, &mut reader, &format!("infer cpu {bits}\n"));
        assert!(infer.starts_with("ok "), "infer under live learning: {infer}");
        let fb = request(
            &mut stream,
            &mut reader,
            &format!("feedback cpu {label} {bits}\n"),
        );
        assert_eq!(fb.trim_end(), "ok applied=1", "feedback reply: {fb}");
    }
    let batch: Vec<String> = events[30..33]
        .iter()
        .map(|(l, b)| format!("{l}:{}", bits_string(b)))
        .collect();
    let train = request(
        &mut stream,
        &mut reader,
        &format!("train cpu {}\n", batch.join(" ")),
    );
    assert_eq!(train.trim_end(), "ok applied=3", "train reply: {train}");

    // offline comparator: the same machine, the same events, in
    // arrival order, through a plain Trainer (virgin streams — plain
    // --model serving never reseeds)
    let mut offline = Trainer::from_machine(io::load(model_path.to_str().unwrap()).unwrap(), Backend::Indexed);
    for (label, bools) in &events[..33] {
        offline.train_sample(&Dataset::literals_from_bools(bools), *label);
    }
    let stats = poll_digest(&addr, "cpu", io::model_digest(&offline.tm));
    assert_eq!(stat_get(&stats, "feedback_applied"), Some("33".into()));
    assert_eq!(stat_get(&stats, "feedback_errors"), Some("0".into()));

    // phase 2: two more events — digests can only stay equal if the
    // trainer's RNG streams are positioned exactly where the offline
    // replay's are after phase 1
    for (label, bools) in &events[33..] {
        let fb = request(
            &mut stream,
            &mut reader,
            &format!("feedback cpu {label} {}\n", bits_string(bools)),
        );
        assert_eq!(fb.trim_end(), "ok applied=1");
        offline.train_sample(&Dataset::literals_from_bools(bools), *label);
    }
    let stats = poll_digest(&addr, "cpu", io::model_digest(&offline.tm));
    assert_eq!(stat_get(&stats, "feedback_applied"), Some("35".into()));
    // every publish bumped the route's swap generation monotonically
    let generation: u64 = stat_get(&stats, "generation").unwrap().parse().unwrap();
    assert!(generation >= 35, "expected >=35 swaps, saw {generation}");

    server.kill().unwrap();
    server.wait().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The truncation-idempotence crash window: the learner's durable
/// publish persists the snapshot to the registry and *then* truncates
/// the WAL. A `kill -9` between the two leaves records in the log
/// that the published snapshot already owns — replay must skip them
/// (per-record version stamp below the recovered version), or the
/// restart silently lands on a different machine than the one that
/// crashed. Exercised in-process with the exact restart discipline
/// `tmi serve --registry --feedback` runs.
#[test]
fn crash_between_publish_and_truncate_does_not_double_apply() {
    let dir = temp_dir("pubcrash");
    let reg_dir = dir.join("registry");
    let base = trained(21);
    let base_seed = base.params.seed;
    let n_feat = base.params.features;
    let mut reg = Registry::open(&reg_dir, 4).unwrap();
    assert_eq!(reg.publish("cpu", &base, InferMode::Auto).unwrap(), 1);

    let mut rng = Rng::new(77);
    let events: Vec<(usize, Vec<bool>)> = (0..25)
        .map(|_| {
            let label = rng.below(2) as usize;
            let bools: Vec<bool> = (0..n_feat).map(|_| rng.bern(0.5)).collect();
            (label, bools)
        })
        .collect();

    // live learner discipline: WAL-first appends at the v1 stamp, then
    // a durable publish of v2 ... and a crash before wal.truncate()
    let mut live = Trainer::from_machine(base, Backend::Indexed);
    live.reseed_streams(reseed_seed(base_seed, 1));
    let wal_path = FeedbackWal::route_path(&reg_dir.join("cpu"));
    let (mut wal, _) = FeedbackWal::open(&wal_path).unwrap();
    wal.set_version(1);
    for (label, bools) in &events {
        let lits = Dataset::literals_from_bools(bools);
        wal.append(*label as u32, &lits).unwrap();
        live.train_sample(&lits, *label);
    }
    wal.sync().unwrap();
    assert_eq!(reg.publish("cpu", &live.tm, InferMode::Auto).unwrap(), 2);
    let pre_crash = io::model_digest(&live.tm);
    drop(wal); // kill -9: no truncate, no version advance
    drop(reg);

    // restart discipline (what cmd_serve_registry does before serving)
    let mut reg = Registry::open(&reg_dir, 4).unwrap();
    let rec = reg.load_published("cpu").unwrap();
    assert_eq!(rec.version, 2, "the durable publish must have landed");
    let mut recovered = Trainer::from_machine(rec.tm, Backend::Indexed);
    recovered.reseed_streams(reseed_seed(base_seed, rec.version));
    let (_, replay) = FeedbackWal::open(&wal_path).unwrap();
    assert_eq!(replay.records.len(), events.len());
    // sanity: without the version stamp the records WOULD replay onto
    // v2 and produce a different machine — the bug this test pins
    {
        let mut doubled = Trainer::from_machine(
            reg.load_published("cpu").unwrap().tm,
            Backend::Indexed,
        );
        doubled.reseed_streams(reseed_seed(base_seed, rec.version));
        let naive = replay_feedback(&mut doubled, &replay.records, 1);
        assert_eq!(naive.applied, events.len() as u64);
        assert_ne!(
            io::model_digest(&doubled.tm),
            pre_crash,
            "double-applying owned records must be observable"
        );
    }
    let summary = replay_feedback(&mut recovered, &replay.records, rec.version);
    assert_eq!(summary.applied, 0, "v2 already owns every logged record");
    assert_eq!(summary.stale, events.len() as u64);
    assert_eq!(summary.skipped, 0);
    assert_eq!(
        io::model_digest(&recovered.tm),
        pre_crash,
        "restart must land on the exact pre-crash machine"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn kill_nine_mid_feedback_replays_wal_to_exact_digest() {
    let dir = temp_dir("kill9wal");
    let reg_dir = dir.join("registry");
    // publish v1 through the real CLI (mnist synthetic: 784 features,
    // 10 classes)
    let out = tmi()
        .args([
            "train", "--dataset", "mnist", "--samples", "120", "--clauses", "80",
            "--epochs", "1", "--registry", reg_dir.to_str().unwrap(), "--route", "cpu",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --registry failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    let mut rng = Rng::new(4242);
    let events: Vec<(usize, Vec<bool>)> = (0..6)
        .map(|_| {
            let label = rng.below(10) as usize;
            let bools: Vec<bool> = (0..784).map(|_| rng.bern(0.1)).collect();
            (label, bools)
        })
        .collect();

    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    // publish cadence far beyond the event count: every event lives
    // only in the WAL when the process dies
    let serve_args = |a: &str| {
        vec![
            "serve".to_string(),
            "--registry".into(),
            reg_dir.to_str().unwrap().into(),
            "--feedback".into(),
            "--publish-every".into(),
            "1000000".into(),
            "--publish-interval".into(),
            "0".into(),
            "--listen".into(),
            a.to_string(),
        ]
    };
    let mut server = tmi().args(serve_args(&addr)).spawn().unwrap();
    let probe = format!("infer cpu {}\n", bits_string(&events[0].1));
    wait_ready(&addr, &probe);

    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;
    for (label, bools) in &events {
        let fb = request(
            &mut stream,
            &mut reader,
            &format!("feedback cpu {label} {}\n", bits_string(bools)),
        );
        assert_eq!(fb.trim_end(), "ok applied=1", "feedback reply: {fb}");
    }
    // no drain, no final publish: everything since v1 is only in the WAL
    server.kill().unwrap();
    server.wait().unwrap();

    // offline replay of the restart discipline: recover v1, reseed to
    // its RNG epoch, apply the logged events in order
    let expected = {
        let mut reg = Registry::open(&reg_dir, 4).unwrap();
        let rec = reg.load_published("cpu").unwrap();
        assert_eq!(rec.version, 1, "no durable publish may have happened");
        let mut offline = Trainer::from_machine(rec.tm, Backend::Indexed);
        let base_seed = offline.tm.params.seed;
        offline.reseed_streams(reseed_seed(base_seed, rec.version));
        for (label, bools) in &events {
            offline.train_sample(&Dataset::literals_from_bools(bools), *label);
        }
        io::model_digest(&offline.tm)
    };

    let port = free_port();
    let addr = format!("127.0.0.1:{port}");
    let mut server = tmi().args(serve_args(&addr)).spawn().unwrap();
    wait_ready(&addr, &probe);
    let stats = request_once(&addr, "stats cpu\n").unwrap();
    assert_eq!(
        stat_get(&stats, "digest"),
        Some(expected.to_string()),
        "WAL replay must restore the exact pre-crash machine: {}",
        stats.trim_end()
    );
    // the replayed state was republished durably as v2 and the WAL
    // truncated (its updates are owned by the published snapshot)
    assert_eq!(stat_get(&stats, "version"), Some("2".into()));
    let wal = reg_dir.join("cpu/feedback.wal");
    assert!(wal.exists(), "WAL file must exist next to the snapshots");
    assert_eq!(std::fs::metadata(&wal).unwrap().len(), 0, "WAL must be truncated");

    // learning resumes after recovery
    let (label, bools) = &events[0];
    let fb = request_once(&addr, &format!("feedback cpu {label} {}\n", bits_string(bools)));
    assert_eq!(fb.unwrap().trim_end(), "ok applied=1");

    server.kill().unwrap();
    server.wait().unwrap();

    // the registry itself still verifies clean after crash + replay
    let out = tmi()
        .args(["registry", "verify", "--registry", reg_dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "registry verify failed: {}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
