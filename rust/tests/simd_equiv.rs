//! Differential suite: wide (4-lane) vs scalar SIMD paths.
//!
//! `--simd` selects a dispatch width, not a representation: every wide
//! kernel must reproduce the scalar reference loops bit-for-bit. This
//! suite holds the machine, the data, and the RNG seeds fixed and
//! varies *only* the lane selector, asserting identical
//!
//! * TA states, include counts, and clause weights,
//! * [`FlipSink`] event streams (order, counts, weights) — the
//!   contract the O(1) index maintenance hangs off,
//! * inference scores from both batch engines (dense fused walk and
//!   sparse-delta walk), and
//! * RNG stream positions (the wide Bernoulli fill must consume
//!   exactly the draws the scalar fill would).
//!
//! over random-machine feedback storms, full sequential and parallel
//! training runs on `data/synth::noisy_xor`, and batch inference.

use tsetlin_index::data::synth::noisy_xor;
use tsetlin_index::engine::{BatchScorer, FusedEngine, Maintenance, SparseEngine};
use tsetlin_index::eval::traits::FlipSink;
use tsetlin_index::eval::Backend;
use tsetlin_index::parallel::ParallelTrainer;
use tsetlin_index::tm::bank::{ClauseBank, TaLayout};
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::feedback::{update_clause_range, FeedbackCtx, FeedbackScratch};
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::{BitVec, Rng, SimdLanes, SimdMode};

/// Every observable feedback event, in order.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Ev {
    Inc { j: u32, k: u32, count: u32, weight: u32 },
    Exc { j: u32, k: u32, count: u32, weight: u32 },
    Weight { j: u32, delta: i32, nonempty: bool },
}

#[derive(Default)]
struct Recorder {
    events: Vec<Ev>,
}

impl FlipSink for Recorder {
    fn on_include(&mut self, j: u32, k: u32, count: u32, weight: u32) {
        self.events.push(Ev::Inc { j, k, count, weight });
    }
    fn on_exclude(&mut self, j: u32, k: u32, count: u32, weight: u32) {
        self.events.push(Ev::Exc { j, k, count, weight });
    }
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.events.push(Ev::Weight { j, delta, nonempty });
    }
}

/// A random mid-training bank in `layout` (states include the
/// saturation extremes), duplicated with scalar and wide lane
/// selectors.
fn lane_pair(
    rng: &mut Rng,
    clauses: usize,
    n_lit: usize,
    layout: TaLayout,
    weighted: bool,
) -> (ClauseBank, ClauseBank) {
    let mut bank = ClauseBank::new_with_layout(clauses, n_lit, layout);
    for j in 0..clauses {
        for k in 0..n_lit {
            if rng.bern(0.3) {
                let v = match rng.below(12) {
                    0 => i8::MAX,
                    1 => i8::MIN,
                    _ => (rng.below(21) as i8) - 10,
                };
                bank.set_state(j, k, v);
            }
        }
        if weighted && rng.bern(0.5) {
            bank.set_weight(j, 1 + rng.below(6));
        }
    }
    let mut wide = bank.clone();
    bank.set_simd(SimdLanes::Scalar);
    wide.set_simd(SimdLanes::Wide);
    (bank, wide)
}

fn random_lits(rng: &mut Rng, n: usize, p: f64) -> BitVec {
    BitVec::from_bools(&(0..n).map(|_| rng.bern(p)).collect::<Vec<_>>())
}

/// Training-mode clause outputs straight off the documented semantics
/// (empty clauses output 1 during learning).
fn reference_outputs(bank: &ClauseBank, lits: &BitVec) -> BitVec {
    let mut out = BitVec::zeros(bank.clauses());
    for j in 0..bank.clauses() {
        let o = bank.count(j) == 0 || bank.included_literals(j).all(|k| lits.get(k));
        out.assign(j, o);
    }
    out
}

/// One differential feedback step across the lane pair: same RNG seed
/// in, states + counts + weights + event stream + RNG position
/// compared out. The scalar side uses a scalar-lane scratch, the wide
/// side a wide-lane scratch, so both the mask *fill* and the mask
/// *apply* run their respective kernels.
#[allow(clippy::too_many_arguments)]
fn step_lanes(
    scalar: &mut ClauseBank,
    wide: &mut ClauseBank,
    ctx: &FeedbackCtx,
    lits: &BitVec,
    p_update: u32,
    is_target: bool,
    seed: u64,
    tag: &str,
) {
    let outputs = reference_outputs(scalar, lits);
    let mut rec_a = Recorder::default();
    let mut rec_b = Recorder::default();
    let mut rng_a = Rng::new(seed);
    let mut rng_b = Rng::new(seed);
    let mut scratch_a = FeedbackScratch::with_simd(scalar.n_literals(), SimdLanes::Scalar);
    let mut scratch_b = FeedbackScratch::with_simd(wide.n_literals(), SimdLanes::Wide);
    let ua = update_clause_range(
        scalar, &mut rec_a, &mut rng_a, ctx, &outputs, lits, p_update, is_target,
        &mut scratch_a,
    );
    let ub = update_clause_range(
        wide, &mut rec_b, &mut rng_b, ctx, &outputs, lits, p_update, is_target,
        &mut scratch_b,
    );
    assert_eq!(ua, ub, "{tag}: update counts diverge");
    assert_eq!(rec_a.events, rec_b.events, "{tag}: FlipSink streams diverge");
    assert_eq!(scalar.states(), wide.states(), "{tag}: states diverge");
    assert_eq!(scalar.weights(), wide.weights(), "{tag}: weights diverge");
    for j in 0..scalar.clauses() {
        assert_eq!(scalar.count(j), wide.count(j), "{tag}: count({j}) diverges");
    }
    // and the two RNG streams consumed the same number of draws
    assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{tag}: RNG positions diverge");
}

#[test]
fn feedback_storms_are_bit_identical_across_lanes() {
    let mut rng = Rng::new(0x51d3_ca57);
    let mut seed = 1u64;
    // 6x70 exercises a masked tail word; 4x200 spans several wide
    // groups; 8x256 is group-aligned end to end
    for &(clauses, n_lit) in &[(4usize, 6usize), (8, 64), (6, 70), (4, 200), (8, 256)] {
        for &layout in &[TaLayout::Sliced, TaLayout::Scalar] {
            for &weighted in &[false, true] {
                let (mut scalar, mut wide) =
                    lane_pair(&mut rng, clauses, n_lit, layout, weighted);
                for trial in 0..60 {
                    let s = [1.0, 2.0, 4.0, 27.0][trial % 4];
                    let ctx = FeedbackCtx::new(s, trial % 3 != 0, weighted);
                    let lits = random_lits(&mut rng, n_lit, 0.5);
                    let p_update = match trial % 3 {
                        0 => u32::MAX,
                        1 => rng.next_u32(),
                        _ => u32::MAX / 2,
                    };
                    seed += 1;
                    step_lanes(
                        &mut scalar,
                        &mut wide,
                        &ctx,
                        &lits,
                        p_update,
                        trial % 2 == 0,
                        seed,
                        &format!(
                            "{clauses}x{n_lit} {layout:?} weighted={weighted} trial={trial}"
                        ),
                    );
                }
                assert!(scalar.check_counts() && wide.check_counts());
            }
        }
    }
}

#[test]
fn saturation_storms_stay_bit_identical_across_lanes() {
    // s = 1 makes every forget mask full; hammering the same bank
    // drives states into both saturation rails and back while the
    // lane widths must agree at every step (tail word exercised: 70).
    let mut rng = Rng::new(0x5a7a_51d3);
    let (mut scalar, mut wide) = lane_pair(&mut rng, 6, 70, TaLayout::Sliced, false);
    for step in 0..400 {
        let s = if step % 2 == 0 { 1.0 } else { 1e9 };
        let ctx = FeedbackCtx::new(s, step % 5 == 0, false);
        let lits = match step % 4 {
            0 => BitVec::ones(70),
            1 => BitVec::zeros(70),
            _ => random_lits(&mut rng, 70, 0.5),
        };
        step_lanes(
            &mut scalar,
            &mut wide,
            &ctx,
            &lits,
            u32::MAX,
            step % 2 == 0,
            9000 + step as u64,
            &format!("storm step {step}"),
        );
    }
    assert!(scalar.check_counts() && wide.check_counts());
}

fn xor_params(weighted: bool, layout: TaLayout, simd: SimdMode) -> TMParams {
    TMParams::new(2, 20, 8)
        .with_threshold(12)
        .with_s(4.0)
        .with_seed(77)
        .with_weighted(weighted)
        .with_ta_layout(layout)
        .with_simd(simd)
}

#[test]
fn full_training_runs_are_bit_identical_across_modes() {
    let train = noisy_xor(8, 800, 0.05, 11);
    let test = noisy_xor(8, 200, 0.0, 12);
    for weighted in [false, true] {
        for backend in Backend::ALL {
            for layout in [TaLayout::Sliced, TaLayout::Scalar] {
                let mut machines = vec![];
                for simd in [SimdMode::Scalar, SimdMode::Wide] {
                    let mut tr = Trainer::new(xor_params(weighted, layout, simd), backend);
                    for _ in 0..4 {
                        tr.train_epoch(train.iter());
                    }
                    tr.check_invariants().unwrap();
                    machines.push(tr);
                }
                let [a, b] = &mut machines[..] else { unreachable!() };
                for c in 0..2 {
                    assert_eq!(
                        a.tm.bank(c).states(),
                        b.tm.bank(c).states(),
                        "{} {layout:?} weighted={weighted} class {c}: states diverge",
                        backend.name()
                    );
                    assert_eq!(a.tm.bank(c).weights(), b.tm.bank(c).weights());
                }
                for (lits, _) in test.iter() {
                    assert_eq!(a.scores(lits), b.scores(lits));
                }
            }
        }
    }
}

#[test]
fn parallel_training_is_bit_identical_across_modes() {
    let train = noisy_xor(8, 200, 0.05, 21);
    for threads in [1usize, 2, 3] {
        let mut machines = vec![];
        for simd in [SimdMode::Scalar, SimdMode::Wide] {
            let mut tr =
                ParallelTrainer::new(xor_params(false, TaLayout::Sliced, simd), threads)
                    .with_stale_window(4);
            for _ in 0..3 {
                tr.train_epoch(train.iter());
            }
            tr.check_invariants().unwrap();
            machines.push(tr);
        }
        let [a, b] = &mut machines[..] else { unreachable!() };
        for c in 0..2 {
            assert_eq!(
                a.tm().bank(c).states(),
                b.tm().bank(c).states(),
                "{threads} threads class {c}: states diverge"
            );
        }
    }
}

/// A random mid-training multi-class machine big enough that the wide
/// walk's clause bitmap spans several words per literal row.
fn random_tm(rng: &mut Rng, classes: usize, cpc: usize, features: usize, weighted: bool) -> MultiClassTM {
    let mut params = TMParams::new(classes, cpc, features);
    params.weighted = weighted;
    let mut tm = MultiClassTM::new(params);
    for c in 0..classes {
        let bank = tm.bank_mut(c);
        for j in 0..cpc {
            for k in 0..2 * features {
                if rng.bern(0.1) {
                    bank.set_state(j, k, (rng.below(11) as i8) - 5);
                }
            }
            if weighted && rng.bern(0.4) {
                bank.set_weight(j, 1 + rng.below(5));
            }
        }
    }
    tm
}

#[test]
fn batch_engines_score_identically_across_modes() {
    let mut rng = Rng::new(0xba7c_4e97);
    for weighted in [false, true] {
        // 3 * 50 = 150 global clauses -> 3-word bitmap rows
        let mut tm = random_tm(&mut rng, 3, 50, 40, weighted);
        let batch: Vec<BitVec> = (0..64).map(|_| random_lits(&mut rng, 80, 0.35)).collect();
        let mut scored = vec![];
        for mode in [SimdMode::Scalar, SimdMode::Wide] {
            tm.set_simd(mode);
            let mut fused = FusedEngine::with_maintenance(&tm, 2, Maintenance::Frozen);
            let mut out = vec![0i32; batch.len() * 3];
            fused.score_batch_into(&batch, &mut out);
            scored.push(out);
        }
        assert_eq!(scored[0], scored[1], "fused engine diverges (weighted={weighted})");
        // sparse engine on complement-structured k-hot literals
        let khot: Vec<BitVec> = (0..64)
            .map(|_| {
                let x = random_lits(&mut rng, 40, 0.15);
                let mut full = BitVec::zeros(80);
                for k in 0..40 {
                    full.assign(k, x.get(k));
                    full.assign(40 + k, !x.get(k));
                }
                full
            })
            .collect();
        let mut scored = vec![];
        for mode in [SimdMode::Scalar, SimdMode::Wide] {
            tm.set_simd(mode);
            let mut sparse = SparseEngine::with_maintenance(&tm, 2, Maintenance::Frozen);
            let mut out = vec![0i32; khot.len() * 3];
            sparse.score_batch_into(&khot, &mut out);
            scored.push(out);
        }
        assert_eq!(scored[0], scored[1], "sparse engine diverges (weighted={weighted})");
    }
}

#[test]
fn maintained_wide_engines_track_training_flips() {
    // Train with wide lanes and a maintained dense index, verifying
    // the plane mirror stays a bijection of the lists through real
    // insert/delete/weight traffic; scores must match a scalar train
    // of the same machine at every epoch.
    let train = noisy_xor(8, 400, 0.05, 31);
    let test = noisy_xor(8, 100, 0.0, 32);
    let mut wide = Trainer::new(
        xor_params(true, TaLayout::Sliced, SimdMode::Wide),
        Backend::Indexed,
    );
    let mut scalar = Trainer::new(
        xor_params(true, TaLayout::Sliced, SimdMode::Scalar),
        Backend::Indexed,
    );
    for _ in 0..5 {
        wide.train_epoch(train.iter());
        scalar.train_epoch(train.iter());
        wide.check_invariants().unwrap();
        for (lits, _) in test.iter() {
            assert_eq!(wide.scores(lits), scalar.scores(lits));
        }
    }
}
