//! Micro-bench: index maintenance — the paper's O(1) insert/delete —
//! under dense and sparse position stores, plus full rebuild cost.
//!
//! The training tables hinge on maintenance staying negligible next to
//! feedback; this bench pins the per-flip cost in nanoseconds.
//!
//! ```bash
//! cargo bench --bench index_ops
//! ```

mod bench_util;

use bench_util::{bench, rate};
use tsetlin_index::index::ClassIndex;
use tsetlin_index::tm::bank::ClauseBank;
use tsetlin_index::util::Rng;

fn bench_store(label: &str, mut index: ClassIndex, clauses: usize, n_lit: usize) {
    let mut rng = Rng::new(7);
    // steady-state flip churn: random alternating insert/delete pairs
    let flips: Vec<(u32, u32)> = (0..10_000)
        .map(|_| (rng.below(clauses as u32), rng.below(n_lit as u32)))
        .collect();
    let (min, _) = bench(1, 5, || {
        for &(j, k) in &flips {
            index.insert(j, k, 2, 1); // count>1: vote baseline untouched
            index.delete(j, k, 1, 1);
        }
    });
    println!(
        "{label:<42} {:>14} per insert+delete pair",
        rate(flips.len(), min)
    );
}

fn main() {
    println!("index_ops: inclusion-list maintenance (min over 5 reps)\n");
    // MNIST-shaped (dense position matrix fits easily)
    bench_store(
        "dense  o=784  n=2000 (MNIST-shaped)",
        ClassIndex::new(2000, 1568),
        2000,
        1568,
    );
    // IMDb-shaped — dense store at 1000 clauses (160 MB matrix)...
    let n_lit = 40_000;
    let mut dense = ClassIndex::new(1000, n_lit);
    assert!(dense.position_store().is_dense());
    bench_store("dense  o=20000 n=1000 (IMDb-shaped)", dense.clone(), 1000, n_lit);
    // ...and the sparse store past the dense budget (paper-full scale)
    let mut sparse = ClassIndex::new(10_000, n_lit);
    assert!(!sparse.position_store().is_dense());
    bench_store("sparse o=20000 n=10000 (paper-full IMDb)", sparse.clone(), 10_000, n_lit);

    // rebuild cost (model load path)
    let mut rng = Rng::new(9);
    let mut bank = ClauseBank::new(2000, 1568);
    for j in 0..2000 {
        for _ in 0..58 {
            let k = rng.below(1568) as usize;
            bank.set_state(j, k, 1);
        }
    }
    let (min, _) = bench(1, 3, || {
        dense.rebuild(&bank);
    });
    println!("\nrebuild o=784 n=2000 len~58: {:.2} ms", min * 1e3);
    let (min, _) = bench(1, 3, || {
        sparse.rebuild(&bank);
    });
    println!("rebuild same bank, sparse store: {:.2} ms", min * 1e3);
}
