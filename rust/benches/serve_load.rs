//! End-to-end serving bench: coordinator + TCP front end + loadgen,
//! all in-process. Writes `BENCH_serve.json`.
//!
//! Measures the full production path — admission queue, batcher
//! workers, snapshot scoring, line protocol — in both load-generator
//! disciplines, plus a hot-swap phase that republishes the model
//! mid-load to show swap cost is invisible to the client.
//!
//! ```bash
//! cargo bench --bench serve_load
//! TMI_BENCH_SECS=5 cargo bench --bench serve_load   # longer phases
//! ```

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tsetlin_index::coordinator::server::serve_tcp;
use tsetlin_index::coordinator::{loadgen, BatchPolicy, Coordinator, LoadgenConfig, RouteConfig};
use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Json;

const FEATURES: usize = 784;
const CLASSES: usize = 4;
const CLAUSES_TOTAL: usize = 256;

fn main() {
    let phase_secs: f64 = std::env::var("TMI_BENCH_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2.0);

    eprintln!("training a {CLASSES}-class, {CLAUSES_TOTAL}-clause model on synthetic MNIST...");
    let all = image_dataset(ImageStyle::Digits, CLASSES, 600, 1, 91);
    let train = all.slice(0, 500);
    let params = TMParams::from_total_clauses(CLASSES, CLAUSES_TOTAL, train.features)
        .with_threshold(20)
        .with_s(5.0);
    let features = train.features;
    assert_eq!(features, FEATURES, "synthetic MNIST shape drifted");
    let mut trainer = Trainer::new(params, Backend::Indexed);
    let mut order_rng = tsetlin_index::util::Rng::new(7);
    for _ in 0..3 {
        let order = train.epoch_order(&mut order_rng);
        trainer.train_epoch(train.iter_order(&order));
    }

    let workers = std::thread::available_parallelism().map_or(2, |n| n.get().min(4));
    let mut coord = Coordinator::new();
    coord.register_model(
        "cpu",
        trainer.publish(),
        RouteConfig {
            workers,
            queue_cap: 1024,
            policy: BatchPolicy::default(),
            ..RouteConfig::default()
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let handle = coord.handle();
    let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));
    let swap_handle = coord.handle();
    eprintln!("serving on {addr} with {workers} workers; {phase_secs:.1}s per phase");

    // (label, connections, total offered rate; 0 = closed loop)
    let phases: &[(&str, usize, f64)] = &[
        ("closed_2conn", 2, 0.0),
        ("closed_8conn", 8, 0.0),
        ("open_2000rps", 4, 2000.0),
    ];
    let mut results: Vec<Json> = Vec::new();
    println!(
        "{:<22} {:>12} {:>10} {:>10} {:>10} {:>10}",
        "phase", "ok/s", "p50_us", "p99_us", "shed_rate", "sent"
    );
    for &(label, connections, rate) in phases {
        let cfg = LoadgenConfig {
            addr: addr.to_string(),
            model: "cpu".into(),
            connections,
            rate,
            duration: Duration::from_secs_f64(phase_secs),
            features: FEATURES,
            seed: 42,
        };
        let report = loadgen::run(&cfg).expect("loadgen phase failed");
        println!(
            "{:<22} {:>12.0} {:>10} {:>10} {:>10.4} {:>10}",
            label, report.throughput_rps, report.p50_us, report.p99_us, report.shed_rate,
            report.sent
        );
        assert_eq!(report.errors, 0, "{label}: non-overload errors");
        let mut row = report.to_json(&cfg);
        if let Json::Obj(o) = &mut row {
            o.insert("phase".into(), Json::str(label));
        }
        results.push(row);
    }

    // hot-swap phase: republish every ~200ms while a closed loop runs —
    // the client must see zero errors and full throughput
    let swapping = Arc::new(AtomicBool::new(true));
    let swapping2 = Arc::clone(&swapping);
    let mut swap_trainer = trainer;
    let swapper = std::thread::spawn(move || {
        let mut swaps = 0u64;
        while swapping2.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(200));
            swap_handle
                .swap("cpu", swap_trainer.publish())
                .expect("swap failed");
            swaps += 1;
        }
        swaps
    });
    let cfg = LoadgenConfig {
        addr: addr.to_string(),
        model: "cpu".into(),
        connections: 4,
        rate: 0.0,
        duration: Duration::from_secs_f64(phase_secs),
        features: FEATURES,
        seed: 43,
    };
    let report = loadgen::run(&cfg).expect("swap phase failed");
    swapping.store(false, Ordering::Relaxed);
    let swaps = swapper.join().unwrap();
    println!(
        "{:<22} {:>12.0} {:>10} {:>10} {:>10.4} {:>10}   ({swaps} hot swaps)",
        "closed_4conn_swapping",
        report.throughput_rps,
        report.p50_us,
        report.p99_us,
        report.shed_rate,
        report.sent
    );
    assert_eq!(report.errors, 0, "hot swaps must be invisible to clients");
    assert!(report.ok > 0, "swap phase served nothing");
    let mut row = report.to_json(&cfg);
    if let Json::Obj(o) = &mut row {
        o.insert("phase".into(), Json::str("closed_4conn_swapping"));
        o.insert("hot_swaps".into(), Json::num(swaps as f64));
    }
    results.push(row);

    stop.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
    coord.shutdown();

    let report = Json::obj([
        ("bench", Json::str("serve_load")),
        (
            "workload",
            Json::obj([
                ("shape", Json::str("mnist-synthetic")),
                ("classes", Json::num(CLASSES as f64)),
                ("clauses_total", Json::num(CLAUSES_TOTAL as f64)),
                ("features", Json::num(FEATURES as f64)),
                ("route_workers", Json::num(workers as f64)),
                ("queue_cap", Json::num(1024.0)),
            ]),
        ),
        ("phase_secs", Json::num(phase_secs)),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json");
    tsetlin_index::bench_harness::report::write_json(&path, &report)
        .expect("writing JSON report");
    println!("\nwrote {}", path.display());
}
