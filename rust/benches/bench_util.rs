//! Shared helpers for the hand-rolled bench binaries (the offline build
//! has no criterion; see DESIGN.md §Substitutions). Methodology:
//! warmup + N timed repetitions, report min/mean — min is the
//! low-noise statistic for CPU-bound kernels.

use std::time::Instant;

/// Time `f` over `reps` repetitions after `warmup` untimed runs.
/// Returns (min_secs, mean_secs).
#[allow(dead_code)]
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    (min, mean)
}

/// ops/sec formatting.
#[allow(dead_code)]
pub fn rate(ops: usize, secs: f64) -> String {
    let r = ops as f64 / secs;
    if r > 1e9 {
        format!("{:.2} Gop/s", r / 1e9)
    } else if r > 1e6 {
        format!("{:.2} Mop/s", r / 1e6)
    } else if r > 1e3 {
        format!("{:.2} Kop/s", r / 1e3)
    } else {
        format!("{r:.1} op/s")
    }
}
