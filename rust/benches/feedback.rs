//! Learning-side feedback bench: bit-sliced TA banks (word-parallel
//! Type I/II feedback, geometric-skip Bernoulli masks) vs the scalar
//! per-byte layout, swept over clauses × literals × specificity `s`,
//! with a lanes dimension comparing the sliced layout's scalar
//! word-at-a-time loops against the 4-wide SIMD group kernels
//! (`--simd wide`: ripple-carry over 32 plane words at a time plus the
//! lane-folded Bernoulli fill). Nightly CI can export
//! `TMI_ASSERT_MIN_SIMD_FEEDBACK_SPEEDUP` to gate the wide/scalar-lane
//! ratio at 1024 literals.
//!
//! Both layouts consume the *same* skip-sampled mask stream (the shared
//! RNG contract `rust/tests/feedback_equiv.rs` proves bit-exact), so
//! this isolates exactly the representation cost: per-lane `i8` bumps
//! vs ~8 words of ripple-carry bitplane arithmetic per 64 automata. A
//! quick differential pass re-checks bit-identity on every config
//! before anything is timed.
//!
//! Emits a machine-readable report to `BENCH_feedback.json` at the
//! repository root via `bench_harness::report::write_json` — the first
//! entry of the learning-side perf trajectory (inference already has
//! `BENCH_batch_infer.json` / `BENCH_sparse_infer.json`).
//!
//! ```bash
//! cargo bench --bench feedback
//! ```

mod bench_util;

use bench_util::bench;
use tsetlin_index::bench_harness::report::write_json;
use tsetlin_index::eval::traits::NoopSink;
use tsetlin_index::tm::bank::{ClauseBank, TaLayout};
use tsetlin_index::tm::feedback::{update_clause_range, FeedbackCtx, FeedbackScratch};
use tsetlin_index::util::{BitVec, Json, Rng, SimdLanes};

/// (clauses, n_literals, s) sweep. 1024 literals × s >= 4 is the
/// acceptance config (>= 3x single-thread feedback throughput).
const CONFIGS: &[(usize, usize, f64)] = &[
    (256, 256, 4.0),
    (256, 1024, 4.0),
    (256, 1024, 10.0),
    (64, 4096, 4.0),
];

const SAMPLES: usize = 24;
const WARMUP: usize = 2;
const REPS: usize = 8;

/// Mid-training bank in the given layout (~30% touched automata).
fn make_bank(layout: TaLayout, clauses: usize, n_lit: usize, seed: u64) -> ClauseBank {
    let mut rng = Rng::new(seed);
    let mut bank = ClauseBank::new_with_layout(clauses, n_lit, layout);
    for j in 0..clauses {
        for k in 0..n_lit {
            if rng.bern(0.3) {
                bank.set_state(j, k, (rng.below(21) as i8) - 10);
            }
        }
    }
    bank
}

/// Fixed per-sample (literals, outputs) pairs. Outputs are synthetic
/// (~70% firing): feedback dispatch only branches on the bit, and a
/// fixed stream keeps the measured work identical across layouts.
fn make_samples(clauses: usize, n_lit: usize, seed: u64) -> Vec<(BitVec, BitVec)> {
    let mut rng = Rng::new(seed);
    (0..SAMPLES)
        .map(|_| {
            let lits =
                BitVec::from_bools(&(0..n_lit).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
            let outs =
                BitVec::from_bools(&(0..clauses).map(|_| rng.bern(0.7)).collect::<Vec<_>>());
            (lits, outs)
        })
        .collect()
}

/// One measured pass: every clause updated (p_update = 1) against every
/// sample, alternating target/negative so Type I and Type II both run.
/// Returns total clause updates applied.
fn feedback_pass(
    bank: &mut ClauseBank,
    rng: &mut Rng,
    ctx: &FeedbackCtx,
    samples: &[(BitVec, BitVec)],
    scratch: &mut FeedbackScratch,
) -> u64 {
    let mut updates = 0;
    for (i, (lits, outs)) in samples.iter().enumerate() {
        updates += update_clause_range(
            bank,
            &mut NoopSink,
            rng,
            ctx,
            outs,
            lits,
            u32::MAX,
            i % 2 == 0,
            scratch,
        );
    }
    updates
}

fn main() {
    let mut results = Vec::new();
    let mut acceptance: Option<f64> = None;
    let mut lane_acceptance: Option<f64> = None;

    println!(
        "{:>8} {:>10} {:>6} {:>16} {:>16} {:>9} {:>16} {:>9}",
        "clauses", "literals", "s", "scalar upd/s", "sliced upd/s", "speedup", "wide upd/s", "lanes"
    );
    for &(clauses, n_lit, s) in CONFIGS {
        let ctx = FeedbackCtx::new(s, true, false);
        let samples = make_samples(clauses, n_lit, 0xbeef);

        // differential pre-check: one pass, shared RNG seed, states must
        // agree bit-exactly across layouts AND lane widths before we
        // trust the timings
        let mut scratch = FeedbackScratch::new(n_lit);
        let mut wide_scratch = FeedbackScratch::with_simd(n_lit, SimdLanes::Wide);
        let mut check_scalar = make_bank(TaLayout::Scalar, clauses, n_lit, 7);
        let mut check_sliced = make_bank(TaLayout::Sliced, clauses, n_lit, 7);
        let mut check_wide = make_bank(TaLayout::Sliced, clauses, n_lit, 7);
        check_wide.set_simd(SimdLanes::Wide);
        let ua = feedback_pass(&mut check_scalar, &mut Rng::new(99), &ctx, &samples, &mut scratch);
        let ub = feedback_pass(&mut check_sliced, &mut Rng::new(99), &ctx, &samples, &mut scratch);
        let uc = feedback_pass(&mut check_wide, &mut Rng::new(99), &ctx, &samples, &mut wide_scratch);
        assert_eq!(ua, ub);
        assert_eq!(ua, uc);
        assert_eq!(
            check_scalar.states(),
            check_sliced.states(),
            "layouts diverged at {clauses}x{n_lit} s={s}"
        );
        assert_eq!(
            check_sliced.states(),
            check_wide.states(),
            "lane widths diverged at {clauses}x{n_lit} s={s}"
        );

        // timed: same seeds per variant => identical update
        // trajectories, so every variant does the same logical work
        let variants: [(TaLayout, SimdLanes); 3] = [
            (TaLayout::Scalar, SimdLanes::Scalar),
            (TaLayout::Sliced, SimdLanes::Scalar),
            (TaLayout::Sliced, SimdLanes::Wide),
        ];
        let mut rates = [0f64; 3];
        for (slot, &(layout, lanes)) in variants.iter().enumerate() {
            let mut bank = make_bank(layout, clauses, n_lit, 7);
            bank.set_simd(lanes);
            let mut scratch = FeedbackScratch::with_simd(n_lit, lanes);
            let mut rng = Rng::new(1234);
            let updates_per_pass = clauses as u64 * SAMPLES as u64;
            let (min_s, _mean_s) = bench(WARMUP, REPS, || {
                std::hint::black_box(feedback_pass(
                    &mut bank,
                    &mut rng,
                    &ctx,
                    &samples,
                    &mut scratch,
                ))
            });
            rates[slot] = updates_per_pass as f64 / min_s;
        }
        let speedup = rates[1] / rates[0];
        let lane_speedup = rates[2] / rates[1];
        println!(
            "{:>8} {:>10} {:>6.1} {:>16.0} {:>16.0} {:>8.2}x {:>16.0} {:>8.2}x",
            clauses, n_lit, s, rates[0], rates[1], speedup, rates[2], lane_speedup
        );
        if n_lit == 1024 && s >= 4.0 {
            acceptance = Some(acceptance.map_or(speedup, |a: f64| a.min(speedup)));
            lane_acceptance =
                Some(lane_acceptance.map_or(lane_speedup, |a: f64| a.min(lane_speedup)));
        }
        results.push(Json::obj([
            ("clauses", Json::num(clauses as f64)),
            ("n_literals", Json::num(n_lit as f64)),
            ("s", Json::num(s)),
            ("scalar_updates_per_s", Json::num(rates[0])),
            ("sliced_updates_per_s", Json::num(rates[1])),
            ("sliced_wide_updates_per_s", Json::num(rates[2])),
            ("speedup_sliced_vs_scalar", Json::num(speedup)),
            ("speedup_wide_vs_scalar_lanes", Json::num(lane_speedup)),
        ]));
    }

    if let Some(s) = acceptance {
        println!("worst speedup at 1024 literals, s >= 4: {s:.2}x");
        assert!(
            s >= 3.0,
            "acceptance: expected >= 3x sliced feedback throughput at 1024 literals, got {s:.2}x"
        );
    }
    if let Some(ls) = lane_acceptance {
        println!("worst wide-lane speedup at 1024 literals, s >= 4: {ls:.2}x");
        if let Ok(raw) = std::env::var("TMI_ASSERT_MIN_SIMD_FEEDBACK_SPEEDUP") {
            let floor: f64 = raw
                .parse()
                .expect("TMI_ASSERT_MIN_SIMD_FEEDBACK_SPEEDUP must be a float");
            assert!(
                ls >= floor,
                "simd feedback gate: wide/scalar-lane {ls:.2}x < floor {floor:.2}x"
            );
            println!("simd feedback gate passed (floor {floor:.2}x)");
        }
    }

    let report = Json::obj([
        ("bench", Json::str("feedback")),
        (
            "workload",
            Json::obj([
                ("samples_per_pass", Json::num(SAMPLES as f64)),
                ("p_update", Json::num(1.0)),
                ("boost_true_positive", Json::Bool(true)),
                ("touched_automata_fraction", Json::num(0.3)),
                ("sink", Json::str("noop")),
            ]),
        ),
        ("bit_identical_across_layouts", Json::Bool(true)),
        (
            "min_speedup_at_1024_literals",
            match acceptance {
                Some(s) => Json::num(s),
                None => Json::Null,
            },
        ),
        (
            "min_wide_lane_speedup_at_1024_literals",
            match lane_acceptance {
                Some(s) => Json::num(s),
                None => Json::Null,
            },
        ),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_feedback.json");
    write_json(&path, &report).expect("writing JSON report");
    println!("wrote {}", path.display());
}
