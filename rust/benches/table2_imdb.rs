//! Regenerates **Table 2** (IMDb indexing speedups) and the data for
//! **Figures 5–6**.
//!
//! The paper's qualitative claims for this workload: inference speedup
//! is the largest of the three datasets (13–15x at 20k clauses), while
//! *training* is slightly SLOWER with indexing (~0.85–1.0x) — index
//! maintenance outweighs the eval savings on very sparse BoW data.
//!
//! ```bash
//! TMI_SCALE=standard cargo bench --bench table2_imdb
//! ```

use std::path::Path;

use tsetlin_index::bench_harness::figures::write_figures;
use tsetlin_index::bench_harness::report::{write_csv, write_json};
use tsetlin_index::bench_harness::tables::{run_table, Scale, TableId};

fn main() {
    let scale = Scale::from_env();
    eprintln!(
        "table2_imdb: clauses {:?} x features {:?}, {} train / {} test samples",
        scale.clause_grid, scale.bow_features, scale.train_samples, scale.test_samples
    );
    let table = run_table(TableId::Imdb, &scale, None, |cell| eprintln!("  {cell}"));
    println!("{}", table.render_markdown());
    let out = Path::new("results");
    let (headers, rows) = table.csv_rows();
    write_csv(&out.join("table2.csv"), &headers, &rows).unwrap();
    let figs = write_figures(&table, out).unwrap();
    eprintln!("wrote results/table2.csv + {}", figs.join(", "));
    let bench_path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_table2.json");
    write_json(&bench_path, &table.to_json()).unwrap();
    eprintln!("wrote {}", bench_path.display());
    // nightly CI exports TMI_ASSERT_MIN_TEST_SPEEDUP: fail on regression
    table.assert_speedup_floor_from_env();
}
