//! Regenerates **Table 1** (MNIST indexing speedups) and the data for
//! **Figures 3–4** (epoch time vs clause count).
//!
//! Scale via `TMI_SCALE=quick|standard|paper` (default quick). Output:
//! paper-layout markdown table + CSVs under `results/`.
//!
//! ```bash
//! TMI_SCALE=standard cargo bench --bench table1_mnist
//! ```

use std::path::Path;

use tsetlin_index::bench_harness::figures::write_figures;
use tsetlin_index::bench_harness::report::{write_csv, write_json};
use tsetlin_index::bench_harness::tables::{run_table, Scale, TableId};

fn main() {
    let scale = Scale::from_env();
    eprintln!(
        "table1_mnist: clauses {:?} x levels {:?}, {} train / {} test samples",
        scale.clause_grid, scale.image_levels, scale.train_samples, scale.test_samples
    );
    let data_dir = std::env::var("TMI_DATA_DIR").ok();
    let table = run_table(
        TableId::Mnist,
        &scale,
        data_dir.as_deref().map(Path::new),
        |cell| eprintln!("  {cell}"),
    );
    println!("{}", table.render_markdown());
    let out = Path::new("results");
    let (headers, rows) = table.csv_rows();
    write_csv(&out.join("table1.csv"), &headers, &rows).unwrap();
    let figs = write_figures(&table, out).unwrap();
    eprintln!("wrote results/table1.csv + {}", figs.join(", "));
    let bench_path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_table1.json");
    write_json(&bench_path, &table.to_json()).unwrap();
    eprintln!("wrote {}", bench_path.display());
    // nightly CI exports TMI_ASSERT_MIN_TEST_SPEEDUP: fail on regression
    table.assert_speedup_floor_from_env();
}
