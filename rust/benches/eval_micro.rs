//! Micro-bench: single-class clause evaluation throughput across the
//! three CPU backends, over clause-density and clause-count sweeps.
//!
//! This isolates the quantity the paper's §3 Remarks reason about —
//! evaluation work per sample — from training noise. Expect: naive ∝
//! clauses × literals (early-exit helps at high density), bitpacked ∝
//! clauses × literals/64, indexed ∝ falsified-literal list mass.
//!
//! ```bash
//! cargo bench --bench eval_micro
//! ```

mod bench_util;

use bench_util::{bench, rate};
use tsetlin_index::eval::Backend;
use tsetlin_index::tm::bank::ClauseBank;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::util::{BitVec, Rng};

/// Build a bank with `clauses` clauses of ~`clause_len` random literals.
fn make_bank(rng: &mut Rng, clauses: usize, n_lit: usize, clause_len: usize) -> ClauseBank {
    let mut bank = ClauseBank::new(clauses, n_lit);
    for j in 0..clauses {
        let mut placed = 0;
        while placed < clause_len {
            let k = rng.below(n_lit as u32) as usize;
            if !bank.include(j, k) {
                bank.set_state(j, k, 1);
                placed += 1;
            }
        }
    }
    bank
}

fn main() {
    let mut rng = Rng::new(42);
    println!("eval_micro: single-class score() throughput (min over 5 reps)\n");
    println!(
        "{:<30} {:>14} {:>14} {:>14}",
        "config", "naive", "bitpacked", "indexed"
    );

    for &(features, clauses, clause_len) in &[
        (784usize, 200usize, 58usize), // MNIST-shaped
        (784, 2000, 58),
        (5000, 200, 116), // IMDb-shaped
        (5000, 1000, 116),
        (784, 2000, 8), // short clauses: indexing's best case
    ] {
        let n_lit = 2 * features;
        let bank = make_bank(&mut rng, clauses, n_lit, clause_len);
        let params = TMParams::new(2, clauses, features);
        // realistic input: half the literals false
        let samples: Vec<BitVec> = (0..64)
            .map(|_| {
                let bits: Vec<bool> = (0..features).map(|_| rng.bern(0.5)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                BitVec::from_bools(&lits)
            })
            .collect();

        let mut row = format!("{:<30}", format!("o={features} n={clauses} len={clause_len}"));
        for backend in [Backend::Naive, Backend::BitPacked, Backend::Indexed] {
            let mut ev = backend.make(&params);
            ev.rebuild(&bank);
            let (min, _) = bench(2, 5, || {
                let mut acc = 0i32;
                for s in &samples {
                    acc = acc.wrapping_add(ev.score(&bank, s));
                }
                acc
            });
            row += &format!(" {:>14}", rate(samples.len(), min));
        }
        println!("{row}");
    }
}
