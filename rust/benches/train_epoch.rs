//! Training-epoch bench: the clause-sharded asynchronous parallel
//! trainer vs the sequential trainer, swept over thread count on two
//! workloads — noisy XOR (small, feedback-dominated) and an
//! MNIST-subset-shaped synthetic image problem (10 classes, 784
//! features, the regime the paper's training tables measure).
//!
//! Emits a machine-readable report to `BENCH_train_epoch.json` at the
//! repository root via `bench_harness::report::write_json`. The
//! sequential `Trainer` baseline is recorded in the same file
//! (`threads = 0` rows), starting the training-side perf trajectory.
//!
//! ```bash
//! cargo bench --bench train_epoch
//! ```

mod bench_util;

use bench_util::bench;
use tsetlin_index::bench_harness::report::write_json;
use tsetlin_index::data::synth::{image_dataset, noisy_xor, ImageStyle};
use tsetlin_index::data::Dataset;
use tsetlin_index::eval::Backend;
use tsetlin_index::parallel::ParallelTrainer;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Json;

const THREAD_SWEEP: &[usize] = &[1, 2, 4];
const STALE_WINDOW: usize = 8;

struct Workload {
    name: &'static str,
    data: Dataset,
    params: TMParams,
}

fn workloads() -> Vec<Workload> {
    vec![
        Workload {
            name: "noisy-xor",
            data: noisy_xor(12, 4000, 0.15, 1),
            params: TMParams::new(2, 200, 12).with_threshold(15).with_s(3.9),
        },
        Workload {
            name: "synth-mnist-subset",
            data: image_dataset(ImageStyle::Digits, 10, 1000, 1, 2),
            params: TMParams::new(10, 100, 784).with_threshold(25).with_s(6.0),
        },
    ]
}

fn main() {
    let mut results: Vec<Json> = Vec::new();
    for w in workloads() {
        let samples = w.data.len();
        println!(
            "\n== {} ({} samples, {} classes, {} clauses/class) ==",
            w.name, samples, w.params.classes, w.params.clauses_per_class
        );

        // -- sequential baseline (threads = 0 row) ----------------------
        let mut seq = Trainer::new(w.params.clone(), Backend::Indexed);
        seq.train_epoch(w.data.iter()); // warm the banks off the cold start
        let (seq_min, _) = bench(1, 3, || seq.train_epoch(w.data.iter()).clause_updates);
        let seq_rate = samples as f64 / seq_min;
        println!(
            "{:<26} {:>12.0} samples/s  ({:.1} ms/epoch)",
            "sequential Trainer",
            seq_rate,
            seq_min * 1e3
        );
        results.push(Json::obj([
            ("workload", Json::str(w.name)),
            ("threads", Json::num(0.0)), // 0 = the sequential baseline
            ("samples", Json::num(samples as f64)),
            ("epoch_secs", Json::num(seq_min)),
            ("samples_per_s", Json::num(seq_rate)),
            ("speedup_vs_sequential", Json::num(1.0)),
        ]));

        // -- parallel sweep --------------------------------------------
        for &threads in THREAD_SWEEP {
            let mut par =
                ParallelTrainer::new(w.params.clone(), threads).with_stale_window(STALE_WINDOW);
            par.train_epoch(w.data.iter());
            let (min_s, _) = bench(1, 3, || par.train_epoch(w.data.iter()).clause_updates);
            let rate = samples as f64 / min_s;
            let speedup = seq_min / min_s;
            println!(
                "{:<26} {:>12.0} samples/s  ({:.1} ms/epoch, {:.2}x vs sequential)",
                format!("parallel threads={threads}"),
                rate,
                min_s * 1e3,
                speedup
            );
            par.check_invariants().expect("post-bench invariants");
            results.push(Json::obj([
                ("workload", Json::str(w.name)),
                ("threads", Json::num(threads as f64)),
                ("stale_window", Json::num(STALE_WINDOW as f64)),
                ("samples", Json::num(samples as f64)),
                ("epoch_secs", Json::num(min_s)),
                ("samples_per_s", Json::num(rate)),
                ("speedup_vs_sequential", Json::num(speedup)),
            ]));
        }
    }

    let report = Json::obj([
        ("bench", Json::str("train_epoch")),
        (
            "scheme",
            Json::str("clause-sharded async (stale vote tally, per-shard falsification index)"),
        ),
        ("stale_window", Json::num(STALE_WINDOW as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_train_epoch.json");
    write_json(&path, &report).expect("writing JSON report");
    println!("\nwrote {}", path.display());
}
