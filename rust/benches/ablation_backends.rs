//! Ablation (not in the paper): what part of the indexed speedup is
//! "skip the work" vs "the baseline is scalar"?
//!
//! Compares inference cost on one trained machine across:
//!   naive      — the paper's baseline (scalar TA-state scan)
//!   bitpacked  — 64-way bit-parallel scan (a stronger baseline)
//!   indexed    — the paper's contribution
//!   xla        — the dense AOT kernel via PJRT (Layers 1/2), if
//!                `artifacts/` is built and a variant matches
//!
//! ```bash
//! make artifacts && cargo bench --bench ablation_backends
//! ```

mod bench_util;

use bench_util::bench;
use tsetlin_index::data::synth::{image_dataset, ImageStyle};
use tsetlin_index::eval::Backend;
use tsetlin_index::runtime::{Manifest, Runtime};
use tsetlin_index::tm::io::DenseModel;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::Trainer;
use tsetlin_index::util::Rng;

const FEATURES: usize = 784;
const CLAUSES_TOTAL: usize = 1280;
const CLASSES: usize = 10;

fn main() {
    // Train one machine at the artifact shape.
    let all = image_dataset(ImageStyle::Digits, CLASSES, 1200, 1, 42);
    let train = all.slice(0, 1000);
    let test = all.slice(1000, 1200);
    let params = TMParams::from_total_clauses(CLASSES, CLAUSES_TOTAL, FEATURES)
        .with_threshold(25)
        .with_s(5.0);
    let mut trainer = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(1);
    for _ in 0..3 {
        let order = train.epoch_order(&mut order_rng);
        trainer.train_epoch(train.iter_order(&order));
    }
    println!(
        "ablation_backends: o={FEATURES} total-clauses={CLAUSES_TOTAL} m={CLASSES}, mean clause len {:.1}, {} test samples\n",
        trainer.tm.mean_clause_length(),
        test.len()
    );

    let mut naive_s = 0.0;
    for backend in [Backend::Naive, Backend::BitPacked, Backend::Indexed] {
        let mut clf = Trainer::from_machine(trainer.tm.clone(), backend);
        let (min, _) = bench(1, 5, || clf.accuracy(test.iter()));
        if backend == Backend::Naive {
            naive_s = min;
        }
        println!(
            "{:<10} {:>8.2} ms / pass   {:>8.1} samples/ms   speedup vs naive {:>5.2}x",
            backend.name(),
            min * 1e3,
            test.len() as f64 / (min * 1e3),
            naive_s / min
        );
    }

    // XLA route (batched) if artifacts exist.
    match Manifest::load("artifacts") {
        Err(_) => println!("\nxla        (skipped: run `make artifacts` first)"),
        Ok(manifest) => {
            let dense = DenseModel::from_tm(&trainer.tm);
            let Some(meta) = manifest
                .pick(32, FEATURES, CLAUSES_TOTAL, CLASSES)
                .cloned()
            else {
                println!("\nxla        (skipped: no matching artifact variant)");
                return;
            };
            let rt = Runtime::cpu().expect("PJRT CPU client");
            let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta).unwrap();
            let prepared = rt.prepare_model(&exe, &dense).unwrap();
            let batch = exe.meta.batch;
            // pre-pack the literal batches
            let n_lit = 2 * FEATURES;
            let batches: Vec<(Vec<f32>, usize)> = (0..test.len())
                .step_by(batch)
                .map(|start| {
                    let rows = batch.min(test.len() - start);
                    let mut lits = vec![0f32; rows * n_lit];
                    for b in 0..rows {
                        for k in test.literals(start + b).iter_ones() {
                            lits[b * n_lit + k] = 1.0;
                        }
                    }
                    (lits, rows)
                })
                .collect();
            let (min, _) = bench(1, 5, || {
                let mut correct = 0usize;
                for (i, (lits, rows)) in batches.iter().enumerate() {
                    let fwd = exe.run(&rt, &prepared, lits, *rows).unwrap();
                    for b in 0..*rows {
                        if fwd.predictions[b] as usize == test.label(i * batch + b) {
                            correct += 1;
                        }
                    }
                }
                correct
            });
            println!(
                "xla        {:>8.2} ms / pass   {:>8.1} samples/ms   speedup vs naive {:>5.2}x   (batch={batch}, dense f32 matmul)",
                min * 1e3,
                test.len() as f64 / (min * 1e3),
                naive_s / min
            );
        }
    }
}
