//! Batch inference bench: class-fused engine vs the per-sample,
//! per-class indexed path, swept over batch size × thread count ×
//! SIMD lane width on an MNIST-shaped synthetic workload (10 classes,
//! 784 features, 200 clauses/class, learned-length-58 clauses — the
//! §3 Remarks regime).
//!
//! The lanes dimension compares `--simd scalar` (reference walk) with
//! `--simd wide` (clause-plane OR + popcount walk); nightly CI exports
//! `TMI_ASSERT_MIN_SIMD_SPEEDUP` to fail the run when the
//! single-thread wide/scalar ratio drops below the floor.
//!
//! Emits a machine-readable report to `BENCH_batch_infer.json` at the
//! repository root via `bench_harness::report::write_json`, so the
//! repo's perf trajectory can be tracked PR over PR. Scores are
//! asserted bit-identical across every path before anything is timed.
//!
//! ```bash
//! cargo bench --bench batch_infer
//! ```

mod bench_util;

use bench_util::bench;
use tsetlin_index::bench_harness::report::write_json;
use tsetlin_index::engine::{BatchScorer, FusedEngine};
use tsetlin_index::eval::Evaluator;
use tsetlin_index::index::IndexedEval;
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::util::{BitVec, Json, Rng, SimdMode};

const CLASSES: usize = 10;
const CLAUSES_PER_CLASS: usize = 200;
const FEATURES: usize = 784;
const CLAUSE_LEN: usize = 58;
const SAMPLES: usize = 256;

/// MNIST-shaped machine: every clause gets `CLAUSE_LEN` random literals.
fn make_machine(rng: &mut Rng) -> MultiClassTM {
    let params = TMParams::new(CLASSES, CLAUSES_PER_CLASS, FEATURES);
    let n_lit = params.n_literals();
    let mut tm = MultiClassTM::new(params);
    for c in 0..CLASSES {
        let bank = tm.bank_mut(c);
        for j in 0..CLAUSES_PER_CLASS {
            let mut placed = 0;
            while placed < CLAUSE_LEN {
                let k = rng.below(n_lit as u32) as usize;
                if !bank.include(j, k) {
                    bank.set_state(j, k, 1);
                    placed += 1;
                }
            }
        }
    }
    tm
}

/// Realistic inputs: `[x, ¬x]` literal vectors (exactly half false).
fn make_samples(rng: &mut Rng) -> Vec<BitVec> {
    (0..SAMPLES)
        .map(|_| {
            let bits: Vec<bool> = (0..FEATURES).map(|_| rng.bern(0.5)).collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            BitVec::from_bools(&lits)
        })
        .collect()
}

/// The pre-engine serving path: one falsification walk per class per
/// sample through `IndexedEval::score`.
fn score_all_per_class(evals: &mut [IndexedEval], tm: &MultiClassTM, samples: &[BitVec]) -> i64 {
    let mut acc = 0i64;
    for lits in samples {
        for (c, ev) in evals.iter_mut().enumerate() {
            acc = acc.wrapping_add(ev.score(tm.bank(c), lits) as i64);
        }
    }
    acc
}

fn main() {
    let mut rng = Rng::new(0x2004_3188);
    let mut tm = make_machine(&mut rng);
    let samples = make_samples(&mut rng);
    let params = tm.params.clone();

    // -- correctness gate: every path must be bit-identical -------------
    let mut evals: Vec<IndexedEval> = (0..CLASSES).map(|_| IndexedEval::new(&params)).collect();
    for (c, ev) in evals.iter_mut().enumerate() {
        ev.rebuild(tm.bank(c));
    }
    let mut engine = FusedEngine::from_machine(&tm, 1);
    let fused = engine.score_batch(&samples);
    for (i, lits) in samples.iter().enumerate() {
        for (c, ev) in evals.iter_mut().enumerate() {
            assert_eq!(
                fused[i][c],
                ev.score(tm.bank(c), lits),
                "fused != per-class indexed at sample {i} class {c}"
            );
        }
    }
    let mut engine4 = FusedEngine::from_machine(&tm, 4);
    assert_eq!(engine4.score_batch(&samples), fused, "sharding changed scores");
    tm.set_simd(SimdMode::Scalar);
    let mut scalar_engine = FusedEngine::from_machine(&tm, 1);
    assert_eq!(
        scalar_engine.score_batch(&samples),
        fused,
        "simd=scalar changed scores"
    );
    tm.set_simd(SimdMode::Wide);
    let mut wide_engine = FusedEngine::from_machine(&tm, 1);
    assert_eq!(
        wide_engine.score_batch(&samples),
        fused,
        "simd=wide changed scores"
    );
    println!(
        "bit-identity: fused/sharded/scalar-lane/wide-lane == per-class indexed on {} samples x {} classes\n",
        SAMPLES, CLASSES
    );

    // -- baseline: single-sample, per-class indexed ----------------------
    let (base_min, _) = bench(2, 5, || score_all_per_class(&mut evals, &tm, &samples));
    let base_rate = SAMPLES as f64 / base_min;
    println!(
        "baseline per-class indexed: {:>10.0} samples/s  ({:.2} ms / {} samples)",
        base_rate,
        base_min * 1e3,
        SAMPLES
    );

    // -- sweep: simd lanes x thread count x batch size -------------------
    let mut results: Vec<Json> = Vec::new();
    // single-thread full-batch rate per lane width, for the simd gate
    let mut lane_rates: Vec<(SimdMode, f64)> = Vec::new();
    println!("\n{:<36} {:>14} {:>10}", "config", "samples/s", "speedup");
    for &simd in &[SimdMode::Scalar, SimdMode::Wide] {
        tm.set_simd(simd);
        for &threads in &[1usize, 2, 4] {
            let mut eng = FusedEngine::from_machine(&tm, threads);
            for &batch in &[1usize, 16, 64, 256] {
                let mut out = vec![0i32; batch.min(SAMPLES) * CLASSES];
                let (min_s, _) = bench(2, 5, || {
                    let mut acc = 0i64;
                    for chunk in samples.chunks(batch) {
                        let flat = &mut out[..chunk.len() * CLASSES];
                        eng.score_batch_into(chunk, flat);
                        acc = acc.wrapping_add(flat[0] as i64);
                    }
                    acc
                });
                let rate = SAMPLES as f64 / min_s;
                let speedup = rate / base_rate;
                println!(
                    "{:<36} {:>14.0} {:>9.2}x",
                    format!("fused simd={} threads={threads} batch={batch}", simd.name()),
                    rate,
                    speedup
                );
                if threads == 1 && batch == 256 {
                    lane_rates.push((simd, rate));
                }
                results.push(Json::obj([
                    ("simd", Json::str(simd.name())),
                    ("threads", Json::num(threads as f64)),
                    ("batch", Json::num(batch as f64)),
                    ("samples_per_s", Json::num(rate)),
                    ("speedup_vs_single_sample_indexed", Json::num(speedup)),
                ]));
            }
        }
    }

    // -- simd gate: single-thread wide vs scalar -------------------------
    let scalar_rate = lane_rates
        .iter()
        .find(|(m, _)| *m == SimdMode::Scalar)
        .map(|&(_, r)| r)
        .unwrap();
    let wide_rate = lane_rates
        .iter()
        .find(|(m, _)| *m == SimdMode::Wide)
        .map(|&(_, r)| r)
        .unwrap();
    let simd_speedup = wide_rate / scalar_rate;
    println!(
        "\nwide vs scalar (1 thread, batch 256, {} literals): {:.2}x",
        2 * FEATURES,
        simd_speedup
    );
    if let Ok(raw) = std::env::var("TMI_ASSERT_MIN_SIMD_SPEEDUP") {
        let floor: f64 = raw
            .parse()
            .expect("TMI_ASSERT_MIN_SIMD_SPEEDUP must be a float");
        assert!(
            simd_speedup >= floor,
            "simd speedup gate: wide/scalar {simd_speedup:.2}x < floor {floor:.2}x"
        );
        println!("simd speedup gate passed (floor {floor:.2}x)");
    }

    let report = Json::obj([
        ("bench", Json::str("batch_infer")),
        (
            "workload",
            Json::obj([
                ("shape", Json::str("mnist-synthetic")),
                ("classes", Json::num(CLASSES as f64)),
                ("clauses_per_class", Json::num(CLAUSES_PER_CLASS as f64)),
                ("features", Json::num(FEATURES as f64)),
                ("clause_len", Json::num(CLAUSE_LEN as f64)),
                ("samples", Json::num(SAMPLES as f64)),
            ]),
        ),
        (
            "baseline_single_sample_indexed_samples_per_s",
            Json::num(base_rate),
        ),
        ("bit_identical_to_indexed_eval", Json::Bool(true)),
        (
            "wide_vs_scalar_single_thread_speedup",
            Json::num(simd_speedup),
        ),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_batch_infer.json");
    write_json(&path, &report).expect("writing JSON report");
    println!("\nwrote {}", path.display());
}
