//! Sparse-delta inference bench: the O(nnz) sparse walk vs the dense
//! class-fused falsification walk, swept over input density × batch
//! size × thread count on an IMDb-shaped synthetic workload (2 classes,
//! learned-length-116 clauses over a k-hot BoW — the §3 Remarks regime
//! where the paper reports its largest speedups).
//!
//! Emits a machine-readable report to `BENCH_sparse_infer.json` at the
//! repository root via `bench_harness::report::write_json`. Scores are
//! asserted bit-identical between both engines (and the native-sparse
//! entry point) before anything is timed.
//!
//! ```bash
//! cargo bench --bench sparse_infer
//! ```

mod bench_util;

use bench_util::bench;
use tsetlin_index::bench_harness::report::write_json;
use tsetlin_index::data::SparseSample;
use tsetlin_index::engine::{BatchScorer, FusedEngine, SparseEngine};
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::util::{BitVec, Json, Rng};

const CLASSES: usize = 2;
const CLAUSES_PER_CLASS: usize = 200;
const FEATURES: usize = 4000;
const CLAUSE_LEN: usize = 116;
const SAMPLES: usize = 256;

/// IMDb-shaped machine: every clause gets `CLAUSE_LEN` random literals,
/// ~90% of them negated — what TMs actually learn on k-hot BoW data
/// (most evidence is *absence* of tokens).
fn make_machine(rng: &mut Rng) -> MultiClassTM {
    let params = TMParams::new(CLASSES, CLAUSES_PER_CLASS, FEATURES);
    let mut tm = MultiClassTM::new(params);
    for c in 0..CLASSES {
        let bank = tm.bank_mut(c);
        for j in 0..CLAUSES_PER_CLASS {
            let mut placed = 0;
            while placed < CLAUSE_LEN {
                let feature = rng.below(FEATURES as u32) as usize;
                let k = if rng.bern(0.9) { FEATURES + feature } else { feature };
                if !bank.include(j, k) {
                    bank.set_state(j, k, 1);
                    placed += 1;
                }
            }
        }
    }
    tm
}

/// k-hot samples at a fixed density.
fn make_samples(rng: &mut Rng, density: f64) -> Vec<SparseSample> {
    (0..SAMPLES)
        .map(|_| {
            let set: Vec<u32> = (0..FEATURES as u32).filter(|_| rng.bern(density)).collect();
            SparseSample::new(FEATURES, set)
        })
        .collect()
}

fn main() {
    let mut rng = Rng::new(0x1911_2607);
    let tm = make_machine(&mut rng);

    let mut results: Vec<Json> = Vec::new();
    let mut speedup_at_5pct_1t: Option<f64> = None;

    println!(
        "workload: {} classes x {} clauses/class, {} features, clause len {}\n",
        CLASSES, CLAUSES_PER_CLASS, FEATURES, CLAUSE_LEN
    );
    for &density in &[0.01f64, 0.02, 0.05, 0.10, 0.30] {
        let samples = make_samples(&mut rng, density);
        let lits: Vec<BitVec> = samples.iter().map(SparseSample::to_literals).collect();
        let measured: f64 = samples.iter().map(SparseSample::density).sum::<f64>()
            / samples.len() as f64;

        // -- correctness gate: bit-identical before timing ---------------
        let mut dense_eng = FusedEngine::from_machine(&tm, 1);
        let mut sparse_eng = SparseEngine::from_machine(&tm, 1);
        let mut want = vec![0i32; SAMPLES * CLASSES];
        dense_eng.score_batch_into(&lits, &mut want);
        let mut got = vec![0i32; SAMPLES * CLASSES];
        sparse_eng.score_batch_into(&lits, &mut got);
        assert_eq!(want, got, "sparse (dense-literal entry) != dense");
        sparse_eng.score_sparse_batch_into(&samples, &mut got);
        assert_eq!(want, got, "sparse (native entry) != dense");

        println!(
            "density {:.2} (measured {:.3}): bit-identical on {} samples",
            density, measured, SAMPLES
        );
        println!(
            "{:<34} {:>14} {:>14} {:>9}",
            "config", "dense sm/s", "sparse sm/s", "speedup"
        );
        for &threads in &[1usize, 4] {
            let mut dense_eng = FusedEngine::from_machine(&tm, threads);
            let mut sparse_eng = SparseEngine::from_machine(&tm, threads);
            for &batch in &[1usize, 64, 256] {
                let mut out = vec![0i32; batch.min(SAMPLES) * CLASSES];
                let (dense_min, _) = bench(2, 5, || {
                    let mut acc = 0i64;
                    for chunk in lits.chunks(batch) {
                        let flat = &mut out[..chunk.len() * CLASSES];
                        dense_eng.score_batch_into(chunk, flat);
                        acc = acc.wrapping_add(flat[0] as i64);
                    }
                    acc
                });
                let (sparse_min, _) = bench(2, 5, || {
                    let mut acc = 0i64;
                    for chunk in samples.chunks(batch) {
                        let flat = &mut out[..chunk.len() * CLASSES];
                        sparse_eng.score_sparse_batch_into(chunk, flat);
                        acc = acc.wrapping_add(flat[0] as i64);
                    }
                    acc
                });
                let dense_rate = SAMPLES as f64 / dense_min;
                let sparse_rate = SAMPLES as f64 / sparse_min;
                let speedup = sparse_rate / dense_rate;
                if threads == 1 && batch == 256 && (density - 0.05).abs() < 1e-9 {
                    speedup_at_5pct_1t = Some(speedup);
                }
                println!(
                    "{:<34} {:>14.0} {:>14.0} {:>8.2}x",
                    format!("density={density:.2} threads={threads} batch={batch}"),
                    dense_rate,
                    sparse_rate,
                    speedup
                );
                results.push(Json::obj([
                    ("density", Json::num(density)),
                    ("measured_density", Json::num(measured)),
                    ("threads", Json::num(threads as f64)),
                    ("batch", Json::num(batch as f64)),
                    ("dense_samples_per_s", Json::num(dense_rate)),
                    ("sparse_samples_per_s", Json::num(sparse_rate)),
                    ("speedup_sparse_vs_dense", Json::num(speedup)),
                ]));
            }
        }
        println!();
    }

    if let Some(s) = speedup_at_5pct_1t {
        println!("single-thread speedup at 5% density (batch 256): {s:.2}x");
        assert!(
            s >= 3.0,
            "acceptance: expected >= 3x single-thread sparse speedup at 5% density, got {s:.2}x"
        );
    }

    let report = Json::obj([
        ("bench", Json::str("sparse_infer")),
        (
            "workload",
            Json::obj([
                ("shape", Json::str("imdb-synthetic-khot")),
                ("classes", Json::num(CLASSES as f64)),
                ("clauses_per_class", Json::num(CLAUSES_PER_CLASS as f64)),
                ("features", Json::num(FEATURES as f64)),
                ("clause_len", Json::num(CLAUSE_LEN as f64)),
                ("negated_literal_fraction", Json::num(0.9)),
                ("samples", Json::num(SAMPLES as f64)),
            ]),
        ),
        ("bit_identical_to_dense_fused", Json::Bool(true)),
        (
            "single_thread_speedup_at_5pct_density",
            match speedup_at_5pct_1t {
                Some(s) => Json::num(s),
                None => Json::Null,
            },
        ),
        ("results", Json::Arr(results)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sparse_infer.json");
    write_json(&path, &report).expect("writing JSON report");
    println!("wrote {}", path.display());
}
