//! Inclusion-list storage.
//!
//! Perf-pass note (EXPERIMENTS.md §Perf): the first implementation used
//! `Vec<Vec<u32>>`. The falsification walk visits ~`o` lists per sample,
//! and most of them are *empty* for sparse machines — yet each visit
//! loaded a scattered 24-byte Vec header, and every non-empty walk
//! chased a separate heap allocation. The paper's own layout (Fig. 2
//! left: fixed-capacity rows of one matrix, `n_k` sizes alongside) is
//! the cache-friendly answer:
//!
//! * [`ListStore::Flat`] — one `2o x n` u32 matrix; row `k` holds
//!   `L_k` in `entries[k*cap .. k*cap+lens[k]]`. The `lens` array is a
//!   contiguous u32 vector, so "skip empty list" costs a sequential
//!   4-byte read instead of a header miss.
//! * [`ListStore::Nested`] — `Vec<Vec<u32>>` fallback (plus the same
//!   fast `lens` array) for shapes where the flat matrix would exceed
//!   the memory budget (paper-full IMDb: 40k literals x 10k clauses).
//!
//! Both preserve the paper's O(1) append / swap-delete exactly.

/// Budget above which the flat matrix gives way to nested vectors.
pub const FLAT_BUDGET_BYTES: usize = 256 << 20;

/// Per-literal inclusion lists with O(1) append and swap-delete.
#[derive(Clone, Debug)]
pub enum ListStore {
    /// Paper-faithful fixed-capacity rows (`cap` = clauses per class).
    Flat {
        /// Row capacity (clauses per class).
        cap: usize,
        /// Live length of each literal's row.
        lens: Vec<u32>,
        /// Row-major `n_literals x cap` clause-id arena.
        entries: Vec<u32>,
    },
    /// Heap-per-list fallback for very large shapes.
    Nested {
        /// Live length of each literal's row.
        lens: Vec<u32>,
        /// One clause-id vector per literal.
        lists: Vec<Vec<u32>>,
    },
}

impl ListStore {
    /// Pick flat when `n_literals * clauses * 4` fits the budget.
    pub fn auto(clauses: usize, n_literals: usize) -> Self {
        if n_literals * clauses * 4 <= FLAT_BUDGET_BYTES {
            ListStore::Flat {
                cap: clauses,
                lens: vec![0; n_literals],
                entries: vec![0; n_literals * clauses],
            }
        } else {
            ListStore::Nested {
                lens: vec![0; n_literals],
                lists: vec![Vec::new(); n_literals],
            }
        }
    }

    #[inline]
    /// Number of literal rows in the store.
    pub fn n_literals(&self) -> usize {
        match self {
            ListStore::Flat { lens, .. } | ListStore::Nested { lens, .. } => lens.len(),
        }
    }

    /// Contiguous list lengths — the walk's skip-empty fast path.
    #[inline]
    pub fn lens(&self) -> &[u32] {
        match self {
            ListStore::Flat { lens, .. } | ListStore::Nested { lens, .. } => lens,
        }
    }

    /// The clause ids of `L_k`.
    #[inline]
    pub fn row(&self, k: usize) -> &[u32] {
        match self {
            ListStore::Flat { cap, lens, entries } => {
                &entries[k * cap..k * cap + lens[k] as usize]
            }
            ListStore::Nested { lists, .. } => &lists[k],
        }
    }

    /// Address of row `k`'s first entry (software prefetch only).
    #[inline]
    pub fn row_ptr(&self, k: usize) -> *const u32 {
        match self {
            ListStore::Flat { cap, entries, .. } => unsafe { entries.as_ptr().add(k * cap) },
            ListStore::Nested { lists, .. } => lists[k].as_ptr(),
        }
    }

    /// Append clause `j` to `L_k`; returns its position.
    #[inline]
    pub fn push(&mut self, k: usize, j: u32) -> u32 {
        match self {
            ListStore::Flat { cap, lens, entries } => {
                let len = lens[k] as usize;
                debug_assert!(len < *cap, "list {k} overflow");
                entries[k * *cap + len] = j;
                lens[k] += 1;
                len as u32
            }
            ListStore::Nested { lens, lists } => {
                lists[k].push(j);
                lens[k] += 1;
                (lists[k].len() - 1) as u32
            }
        }
    }

    /// Swap-delete position `p` of `L_k`; returns the clause id that was
    /// moved into `p` (None if `p` was the last slot).
    #[inline]
    pub fn swap_remove(&mut self, k: usize, p: u32) -> Option<u32> {
        match self {
            ListStore::Flat { cap, lens, entries } => {
                let len = lens[k] as usize;
                debug_assert!((p as usize) < len);
                let row = &mut entries[k * *cap..k * *cap + len];
                let last = row[len - 1];
                lens[k] -= 1;
                if p as usize != len - 1 {
                    row[p as usize] = last;
                    Some(last)
                } else {
                    None
                }
            }
            ListStore::Nested { lens, lists } => {
                let list = &mut lists[k];
                let last = *list.last().expect("swap_remove on empty list");
                let was_last = p as usize == list.len() - 1;
                list.swap_remove(p as usize);
                lens[k] -= 1;
                if was_last {
                    None
                } else {
                    Some(last)
                }
            }
        }
    }

    /// True while every row still lives in the flat arena (no spills).
    pub fn is_flat(&self) -> bool {
        matches!(self, ListStore::Flat { .. })
    }

    /// Approximate heap footprint of the store, in bytes.
    pub fn footprint_bytes(&self) -> usize {
        match self {
            ListStore::Flat { entries, lens, .. } => (entries.len() + lens.len()) * 4,
            ListStore::Nested { lists, lens } => {
                lens.len() * 4 + lists.iter().map(|l| l.capacity() * 4 + 24).sum::<usize>()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn exercise(mut s: ListStore) {
        assert_eq!(s.row(3), &[] as &[u32]);
        assert_eq!(s.push(3, 10), 0);
        assert_eq!(s.push(3, 11), 1);
        assert_eq!(s.push(3, 12), 2);
        assert_eq!(s.row(3), &[10, 11, 12]);
        assert_eq!(s.lens()[3], 3);
        // delete middle: last moves in
        assert_eq!(s.swap_remove(3, 0), Some(12));
        assert_eq!(s.row(3), &[12, 11]);
        // delete last: nothing moves
        assert_eq!(s.swap_remove(3, 1), None);
        assert_eq!(s.row(3), &[12]);
        assert_eq!(s.lens()[3], 1);
        // other rows untouched
        assert_eq!(s.lens()[2], 0);
    }

    #[test]
    fn flat_semantics() {
        let s = ListStore::auto(8, 16);
        assert!(s.is_flat());
        exercise(s);
    }

    #[test]
    fn nested_semantics() {
        let s = ListStore::auto(100_000, 100_000);
        assert!(!s.is_flat());
        exercise(s);
    }

    #[test]
    fn flat_and_nested_agree_under_fuzz() {
        let mut rng = Rng::new(55);
        let mut flat = ListStore::auto(32, 20);
        let mut nested = ListStore::Nested {
            lens: vec![0; 20],
            lists: vec![Vec::new(); 20],
        };
        assert!(flat.is_flat() && !nested.is_flat());
        for _ in 0..20_000 {
            let k = rng.below(20) as usize;
            if rng.bern(0.55) {
                if flat.lens()[k] < 32 {
                    let j = rng.below(32);
                    assert_eq!(flat.push(k, j), nested.push(k, j));
                }
            } else if flat.lens()[k] > 0 {
                let p = rng.below(flat.lens()[k]);
                assert_eq!(flat.swap_remove(k, p), nested.swap_remove(k, p));
            }
            assert_eq!(flat.row(k), nested.row(k));
        }
    }
}
