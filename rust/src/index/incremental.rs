//! Incremental evaluation — the paper's §5 further work, implemented.
//!
//! > "we intend to investigate how clause indexing can speed up Monte
//! > Carlo tree search for board games, by exploiting the incremental
//! > changes of the board position from parent to child node."
//!
//! The index makes this natural: keep a per-clause **falsified-literal
//! counter**. Flipping one literal `k` touches exactly the clauses in
//! `L_k` — falsified count ±1, and only 0↔1 transitions move the score.
//! Scoring a child position after `d` literal flips costs
//! `O(Σ |L_k| over the d flipped literals)` instead of a full
//! re-evaluation — for board games `d` is 1–4 per move while `2o` is
//! the whole board encoding.
//!
//! The evaluator tracks one class; a game engine keeps `m` of them (see
//! `examples/mcts_search.rs`).

use crate::index::class_index::ClassIndex;
use crate::tm::bank::ClauseBank;
use crate::util::BitVec;

/// Incremental single-class scorer positioned at a concrete literal
/// assignment. Created from a [`ClassIndex`] + bank; moves via
/// [`IncrementalEval::flip`] / [`IncrementalEval::set_literal`].
#[derive(Clone, Debug)]
pub struct IncrementalEval {
    /// Falsified-literal count per clause.
    fals: Vec<u32>,
    /// Signed weighted vote per clause, snapshotted at construction
    /// (weights do not change during search).
    votes: Vec<i32>,
    /// Current literal assignment.
    literals: BitVec,
    /// Current inference score (empty clauses vote 0).
    score: i32,
    flips_applied: u64,
}

impl IncrementalEval {
    /// Initialize at `literals` (one full evaluation via the index).
    pub fn new(index: &ClassIndex, bank: &ClauseBank, literals: &BitVec) -> Self {
        assert_eq!(literals.len(), bank.n_literals());
        let votes: Vec<i32> = (0..bank.clauses()).map(|j| bank.vote(j)).collect();
        let mut fals = vec![0u32; bank.clauses()];
        let mut score = index.vote_alive();
        for k in index.walk_false_nonempty(literals) {
            for &j in index.list(k) {
                let f = &mut fals[j as usize];
                *f += 1;
                if *f == 1 {
                    score -= votes[j as usize];
                }
            }
        }
        IncrementalEval {
            fals,
            votes,
            literals: literals.clone(),
            score,
            flips_applied: 0,
        }
    }

    /// Current inference score.
    #[inline]
    pub fn score(&self) -> i32 {
        self.score
    }

    /// Current literal assignment.
    pub fn literals(&self) -> &BitVec {
        &self.literals
    }

    /// Total include/exclude flips applied through the maintenance hook.
    pub fn flips_applied(&self) -> u64 {
        self.flips_applied
    }

    /// Toggle literal `k`. Cost: `O(|L_k|)`.
    pub fn flip(&mut self, index: &ClassIndex, k: usize) {
        let now_true = !self.literals.get(k);
        self.literals.assign(k, now_true);
        self.flips_applied += 1;
        if now_true {
            // literal became true: clauses in L_k lose one falsifier
            for &j in index.list(k) {
                let f = &mut self.fals[j as usize];
                *f -= 1;
                if *f == 0 {
                    self.score += self.votes[j as usize];
                }
            }
        } else {
            for &j in index.list(k) {
                let f = &mut self.fals[j as usize];
                *f += 1;
                if *f == 1 {
                    self.score -= self.votes[j as usize];
                }
            }
        }
    }

    /// Set literal `k` to `value` (no-op if already there).
    pub fn set_literal(&mut self, index: &ClassIndex, k: usize, value: bool) {
        if self.literals.get(k) != value {
            self.flip(index, k);
        }
    }

    /// Set *feature* `f` (of `o`) to `value`, updating both the feature
    /// literal `f` and its negation `o + f` consistently.
    pub fn set_feature(&mut self, index: &ClassIndex, o: usize, f: usize, value: bool) {
        self.set_literal(index, f, value);
        self.set_literal(index, o + f, !value);
    }

    /// Verify against a from-scratch evaluation (tests).
    #[doc(hidden)]
    pub fn check(&self, index: &ClassIndex, bank: &ClauseBank) -> Result<(), String> {
        let fresh = IncrementalEval::new(index, bank, &self.literals);
        if fresh.score != self.score {
            return Err(format!("score drift: {} vs fresh {}", self.score, fresh.score));
        }
        if fresh.fals != self.fals {
            return Err("falsified-count drift".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Evaluator;
    use crate::index::IndexedEval;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn random_machine(
        rng: &mut Rng,
        clauses: usize,
        n_lit: usize,
        density: f64,
    ) -> (ClauseBank, IndexedEval) {
        let mut bank = ClauseBank::new(clauses, n_lit);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    bank.set_state(j, k, 1);
                }
            }
        }
        let params = TMParams::new(2, clauses, n_lit / 2);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        (bank, ev)
    }

    #[test]
    fn initial_score_matches_full_eval() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let (bank, mut ev) = random_machine(&mut rng, 12, 30, 0.15);
            let lits =
                BitVec::from_bools(&(0..30).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
            let inc = IncrementalEval::new(ev.index(), &bank, &lits);
            assert_eq!(inc.score(), ev.score(&bank, &lits));
        }
    }

    #[test]
    fn flips_track_full_eval() {
        let mut rng = Rng::new(4);
        let (bank, mut ev) = random_machine(&mut rng, 16, 40, 0.12);
        let lits = BitVec::from_bools(&(0..40).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
        let mut inc = IncrementalEval::new(ev.index(), &bank, &lits);
        for step in 0..500 {
            let k = rng.below(40) as usize;
            inc.flip(ev.index(), k);
            assert_eq!(
                inc.score(),
                ev.score(&bank, inc.literals()),
                "step {step} flip {k}"
            );
        }
        inc.check(ev.index(), &bank).unwrap();
        assert_eq!(inc.flips_applied(), 500);
    }

    #[test]
    fn set_feature_keeps_literal_pair_consistent() {
        let mut rng = Rng::new(5);
        let (bank, mut ev) = random_machine(&mut rng, 8, 20, 0.2);
        let o = 10;
        // start from all-features-false: x=0, ¬x=1
        let mut bools = vec![false; 20];
        for f in 0..o {
            bools[o + f] = true;
        }
        let lits = BitVec::from_bools(&bools);
        let mut inc = IncrementalEval::new(ev.index(), &bank, &lits);
        inc.set_feature(ev.index(), o, 3, true);
        assert!(inc.literals().get(3));
        assert!(!inc.literals().get(13));
        assert_eq!(inc.score(), ev.score(&bank, inc.literals()));
        // idempotent
        let before = inc.flips_applied();
        inc.set_feature(ev.index(), o, 3, true);
        assert_eq!(inc.flips_applied(), before);
        inc.check(ev.index(), &bank).unwrap();
    }

    #[test]
    fn incremental_is_cheap_for_small_diffs() {
        // structural check: a flip touches exactly |L_k| clauses
        let mut rng = Rng::new(6);
        let (bank, ev) = random_machine(&mut rng, 10, 24, 0.3);
        let lits = BitVec::ones(24);
        let inc = IncrementalEval::new(ev.index(), &bank, &lits);
        // all literals true -> nothing falsified -> score == vote_alive
        assert_eq!(inc.score(), ev.index().vote_alive());
    }
}
