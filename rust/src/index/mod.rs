//! Clause indexing — the paper's contribution (§3).
//!
//! * [`position`] — the position matrix `M` with a dense and a sparse
//!   (hash) representation behind one interface.
//! * [`class_index`] — per-class inclusion lists `L_k` + `M`, O(1)
//!   insert/delete, and the falsification-driven evaluator.
//! * [`stats`] — occupancy statistics backing the §3 "Remarks"
//!   work-ratio analysis.

pub mod class_index;
pub mod incremental;
pub mod liststore;
pub mod position;
pub mod stats;

pub use class_index::{ClassIndex, IndexedEval};
pub use incremental::IncrementalEval;
pub use liststore::ListStore;
pub use position::PositionStore;
pub use stats::IndexStats;
