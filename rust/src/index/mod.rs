//! Clause indexing — the paper's contribution (§3).
//!
//! * [`position`] — the position matrix `M` with a dense and a sparse
//!   (hash) representation behind one interface.
//! * [`class_index`] — per-class inclusion lists `L_k` + `M`, O(1)
//!   insert/delete, and the falsification-driven evaluator.
//! * [`stats`] — occupancy statistics backing the §3 "Remarks"
//!   work-ratio analysis.
//!
//! The [`liststore`]/[`position`] pair is also the storage substrate of
//! the class-fused serving indexes in [`crate::engine`] — both the
//! dense fused walk and the O(nnz) sparse-delta walk run the same O(1)
//! insert/delete algebra over global clause ids.

pub mod class_index;
pub mod incremental;
pub mod liststore;
pub mod position;
pub mod stats;

pub use class_index::{ClassIndex, IndexedEval};
pub use incremental::IncrementalEval;
pub use liststore::ListStore;
pub use position::PositionStore;
pub use stats::IndexStats;
