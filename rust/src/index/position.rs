//! The position matrix `M` (paper Fig. 2, right).
//!
//! `M[j][k]` stores the position of clause `j` inside the inclusion list
//! `L_k`, so deletion can swap-with-last in constant time. The paper
//! uses a dense `n x 2o` matrix and accepts ~3x total memory; that is
//! faithful for MNIST-scale machines, but a dense matrix for IMDb-scale
//! ones (20k clauses x 40k literals) costs gigabytes while holding only
//! ~clause-length live entries per clause. We therefore keep the dense
//! layout as the default *and* provide a sparse open-addressing variant
//! with identical semantics; the constructor picks by footprint.
//! DESIGN.md documents this as an engineering refinement — both variants
//! preserve the paper's O(1) maintenance.

use crate::util::U64Map;

/// Sentinel for "clause not present in this literal's list" (dense).
const NA: u32 = u32::MAX;

/// Budget above which the dense matrix gives way to the sparse map.
pub const DENSE_BUDGET_BYTES: usize = 256 << 20;

/// Position store: `(clause j, literal k) -> index into L_k`.
#[derive(Clone, Debug)]
pub enum PositionStore {
    /// Dense `clauses x n_literals` u32 matrix (paper-faithful).
    Dense {
        /// `pos[j * n_literals + k]` = index of clause `j` in `L_k`.
        pos: Vec<u32>,
        /// Row stride of `pos`.
        n_literals: usize,
    },
    /// Open-addressing map keyed by `(j << 32) | k`.
    Sparse(U64Map),
}

#[inline]
fn key(j: u32, k: u32) -> u64 {
    ((j as u64) << 32) | k as u64
}

impl PositionStore {
    /// Pick dense when the matrix fits `DENSE_BUDGET_BYTES`, else sparse.
    pub fn auto(clauses: usize, n_literals: usize) -> Self {
        if clauses * n_literals * 4 <= DENSE_BUDGET_BYTES {
            PositionStore::new_dense(clauses, n_literals)
        } else {
            PositionStore::new_sparse()
        }
    }

    /// Dense position matrix for `clauses` × `n_literals` slots.
    pub fn new_dense(clauses: usize, n_literals: usize) -> Self {
        PositionStore::Dense {
            pos: vec![NA; clauses * n_literals],
            n_literals,
        }
    }

    /// Hash-map-backed position store for sparse occupancy.
    pub fn new_sparse() -> Self {
        PositionStore::Sparse(U64Map::new())
    }

    /// Record that clause `j` sits at `p` in `L_k`.
    #[inline]
    pub fn set(&mut self, j: u32, k: u32, p: u32) {
        match self {
            PositionStore::Dense { pos, n_literals } => {
                pos[j as usize * *n_literals + k as usize] = p;
            }
            PositionStore::Sparse(map) => map.insert(key(j, k), p),
        }
    }

    /// Position of clause `j` in `L_k`, if present.
    #[inline]
    pub fn get(&self, j: u32, k: u32) -> Option<u32> {
        match self {
            PositionStore::Dense { pos, n_literals } => {
                let v = pos[j as usize * *n_literals + k as usize];
                (v != NA).then_some(v)
            }
            PositionStore::Sparse(map) => map.get(key(j, k)),
        }
    }

    /// Remove and return the position (the paper's `M[j][k] <- NA`).
    #[inline]
    pub fn remove(&mut self, j: u32, k: u32) -> Option<u32> {
        match self {
            PositionStore::Dense { pos, n_literals } => {
                let slot = &mut pos[j as usize * *n_literals + k as usize];
                let v = *slot;
                *slot = NA;
                (v != NA).then_some(v)
            }
            PositionStore::Sparse(map) => map.remove(key(j, k)),
        }
    }

    /// True if backed by the dense matrix rather than the hash map.
    pub fn is_dense(&self) -> bool {
        matches!(self, PositionStore::Dense { .. })
    }

    /// Approximate resident bytes (diagnostics / memory-footprint bench).
    pub fn footprint_bytes(&self) -> usize {
        match self {
            PositionStore::Dense { pos, .. } => pos.len() * 4,
            PositionStore::Sparse(map) => map.len() * 12 + 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn exercise(store: &mut PositionStore) {
        assert_eq!(store.get(3, 7), None);
        store.set(3, 7, 0);
        store.set(3, 9, 4);
        store.set(5, 7, 1);
        assert_eq!(store.get(3, 7), Some(0));
        assert_eq!(store.get(5, 7), Some(1));
        assert_eq!(store.get(3, 9), Some(4));
        store.set(3, 7, 2); // move
        assert_eq!(store.get(3, 7), Some(2));
        assert_eq!(store.remove(3, 7), Some(2));
        assert_eq!(store.get(3, 7), None);
        assert_eq!(store.remove(3, 7), None);
    }

    #[test]
    fn dense_semantics() {
        let mut s = PositionStore::new_dense(8, 16);
        assert!(s.is_dense());
        exercise(&mut s);
    }

    #[test]
    fn sparse_semantics() {
        let mut s = PositionStore::new_sparse();
        assert!(!s.is_dense());
        exercise(&mut s);
    }

    #[test]
    fn auto_picks_by_footprint() {
        assert!(PositionStore::auto(100, 100).is_dense());
        assert!(!PositionStore::auto(100_000, 100_000).is_dense());
    }

    #[test]
    fn dense_and_sparse_agree_under_fuzz() {
        let mut rng = Rng::new(77);
        let mut d = PositionStore::new_dense(32, 64);
        let mut s = PositionStore::new_sparse();
        for _ in 0..10_000 {
            let j = rng.below(32);
            let k = rng.below(64);
            match rng.below(3) {
                0 => {
                    let p = rng.below(1000);
                    d.set(j, k, p);
                    s.set(j, k, p);
                }
                1 => assert_eq!(d.remove(j, k), s.remove(j, k)),
                _ => assert_eq!(d.get(j, k), s.get(j, k)),
            }
        }
    }
}
