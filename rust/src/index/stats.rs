//! Index occupancy statistics — backs the §3 "Remarks" work-ratio
//! analysis (MNIST ≈ 0.02, IMDb ≈ 0.006 of the unindexed work).

use crate::index::class_index::ClassIndex;
use crate::tm::bank::ClauseBank;

/// Aggregate statistics over one class's index.
#[derive(Clone, Debug)]
pub struct IndexStats {
    /// Clauses in the bank.
    pub clauses: usize,
    /// Literals (2o).
    pub n_literals: usize,
    /// Mean included-literal count over non-empty clauses.
    pub mean_clause_length: f64,
    /// Mean inclusion-list length over all literals.
    pub mean_list_length: f64,
    /// Max inclusion-list length.
    pub max_list_length: usize,
    /// Total inclusions (Σ|L_k| = Σ clause counts).
    pub total_inclusions: usize,
    /// Non-empty clauses.
    pub nonempty_clauses: usize,
    /// Paper §3 work model — indexed inference touches the lists of the
    /// false literals; with ~half the literals false that is
    /// `0.5 * 2o * mean_list_length` id reads per class...
    pub indexed_work: f64,
    /// ...versus the naive scan's `clauses * 2o` state reads.
    pub naive_work: f64,
    /// `indexed_work / naive_work` — the paper reports ≈0.02 (MNIST)
    /// and ≈0.006 (IMDb).
    pub work_ratio: f64,
}

impl IndexStats {
    /// Measure index shape (list lengths, memory) for a bank's index.
    pub fn collect(index: &ClassIndex, bank: &ClauseBank) -> Self {
        let n_literals = index.n_literals();
        let clauses = bank.clauses();
        let lens: Vec<usize> = (0..n_literals).map(|k| index.list(k).len()).collect();
        let total_inclusions: usize = lens.iter().sum();
        let max_list_length = lens.iter().copied().max().unwrap_or(0);
        let mean_list_length = if n_literals == 0 {
            0.0
        } else {
            total_inclusions as f64 / n_literals as f64
        };
        let nonempty = (0..clauses).filter(|&j| bank.count(j) > 0).count();
        // half the literals are false on a typical Boolean sample
        // (x and ¬x complement each other feature-wise)
        let indexed_work = 0.5 * n_literals as f64 * mean_list_length;
        let naive_work = (clauses * n_literals) as f64;
        IndexStats {
            clauses,
            n_literals,
            mean_clause_length: bank.mean_clause_length(),
            mean_list_length,
            max_list_length,
            total_inclusions,
            nonempty_clauses: nonempty,
            indexed_work,
            naive_work,
            work_ratio: if naive_work > 0.0 {
                indexed_work / naive_work
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::IndexedEval;
    use crate::tm::params::TMParams;
    use crate::eval::Evaluator;

    #[test]
    fn stats_on_known_machine() {
        let mut bank = ClauseBank::new(4, 8);
        // clause 0: 2 literals, clause 1: 1, clauses 2-3 empty
        bank.set_state(0, 0, 0);
        bank.set_state(0, 3, 0);
        bank.set_state(1, 3, 0);
        let params = TMParams::new(2, 4, 4);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        let st = IndexStats::collect(ev.index(), &bank);
        assert_eq!(st.total_inclusions, 3);
        assert_eq!(st.max_list_length, 2); // L_3 = {0, 1}
        assert_eq!(st.nonempty_clauses, 2);
        assert!((st.mean_clause_length - 1.5).abs() < 1e-12);
        assert!((st.mean_list_length - 3.0 / 8.0).abs() < 1e-12);
        // work model: 0.5 * 8 * 0.375 = 1.5 vs 32
        assert!((st.work_ratio - 1.5 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn paper_remarks_mnist_shaped_ratio() {
        // §3 Remarks: 20 000 clauses, 1568 literals, mean clause length
        // ~58 -> work ratio ~0.02. Reconstruct the arithmetic: mean list
        // length = total_inclusions / 2o = 20000*58/1568 ≈ 740.
        let clauses = 200usize; // scaled down 100x, ratio is scale-free
        let n_lit = 1568usize;
        let target_len = 58usize;
        let mut bank = ClauseBank::new(clauses, n_lit);
        let mut rng = crate::util::Rng::new(1);
        for j in 0..clauses {
            let mut placed = 0;
            while placed < target_len {
                let k = rng.below(n_lit as u32) as usize;
                if !bank.include(j, k) {
                    bank.set_state(j, k, 0);
                    placed += 1;
                }
            }
        }
        let params = TMParams::new(2, clauses, n_lit / 2);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        let st = IndexStats::collect(ev.index(), &bank);
        // ratio = 0.5 * mean_list_len * 2o / (n * 2o) = 0.5*58/1568*... =
        // 0.5 * clause_len / clauses... = 29/1568*... — just compare to
        // the closed form: 0.5 * total_inc / (clauses * n_lit) * ... :
        let expect = 0.5 * (clauses * target_len) as f64 / (clauses * n_lit) as f64;
        assert!((st.work_ratio - expect).abs() < 1e-9);
        // paper's headline: about 0.02
        assert!(st.work_ratio > 0.01 && st.work_ratio < 0.03, "{}", st.work_ratio);
    }
}
