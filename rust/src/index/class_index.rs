//! Inclusion lists + position matrix: the paper's index (§3), and the
//! falsification evaluator built on it.
//!
//! For each literal `k` the list `L_k` holds the clause ids that
//! *include* `k`. Evaluation walks only the input's **false** literals
//! and knocks out the clauses in their lists; everything never touched
//! stays true. Maintenance (paper's insertion/deletion algebra):
//!
//! ```text
//! insert:  n_k += 1;  L_k[n_k] = j;  M[j][k] = n_k
//! delete:  p = M[j][k];  L_k[p] = L_k[n_k];  M[L_k[p]][k] = p;
//!          n_k -= 1;  M[j][k] = NA
//! ```
//!
//! Both are O(1); `Vec::push`/swap-remove realize exactly this.

use crate::eval::traits::{Evaluator, FlipSink};
use crate::index::liststore::ListStore;
use crate::index::position::PositionStore;
use crate::tm::bank::ClauseBank;
use crate::tm::params::TMParams;
use crate::util::BitVec;

/// The index for one class: `2o` inclusion lists, the position matrix,
/// and the incrementally-maintained inference vote baseline.
#[derive(Clone, Debug)]
pub struct ClassIndex {
    /// `L_k` for every literal `k` (flat matrix or nested fallback).
    lists: ListStore,
    /// `M[j][k]` — position of clause `j` in `L_k`.
    pos: PositionStore,
    /// Literals whose inclusion list is non-empty. The falsification
    /// walk intersects this with the input's false-literal words, so
    /// empty lists are skipped 64 at a time (perf pass, §Perf — the big
    /// lever for sparse machines, where most lists are empty).
    nonempty: BitVec,
    /// Weighted vote sum over *non-empty* clauses: the all-true
    /// inference score before any falsification.
    vote_alive: i32,
    /// Weighted vote sum over all clauses (training baseline; constant
    /// for plain TMs, weight-maintained for weighted TMs).
    vote_all: i32,
}

impl ClassIndex {
    /// Empty index for `clauses` clauses over `n_literals` literals.
    pub fn new(clauses: usize, n_literals: usize) -> Self {
        ClassIndex {
            lists: ListStore::auto(clauses, n_literals),
            pos: PositionStore::auto(clauses, n_literals),
            nonempty: BitVec::zeros(n_literals),
            vote_alive: 0,
            vote_all: (0..clauses).map(ClauseBank::polarity).sum(),
        }
    }

    /// O(1) insertion (TA flipped exclude -> include).
    #[inline]
    pub fn insert(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        debug_assert!(self.pos.get(j, k).is_none(), "duplicate insert ({j},{k})");
        let p = self.lists.push(k as usize, j);
        self.pos.set(j, k, p);
        if p == 0 {
            self.nonempty.set(k as usize);
        }
        if new_count == 1 {
            self.vote_alive += ClauseBank::polarity(j as usize) * weight as i32;
        }
    }

    /// O(1) deletion by swap-with-last (TA flipped include -> exclude).
    #[inline]
    pub fn delete(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        let p = self
            .pos
            .remove(j, k)
            .expect("delete of unindexed (clause, literal)");
        if let Some(moved) = self.lists.swap_remove(k as usize, p) {
            self.pos.set(moved, k, p);
        }
        if self.lists.lens()[k as usize] == 0 {
            self.nonempty.clear(k as usize);
        }
        if new_count == 0 {
            self.vote_alive -= ClauseBank::polarity(j as usize) * weight as i32;
        }
    }

    /// Weight change of clause `j` (weighted TMs): adjust the vote
    /// baselines without touching any list.
    #[inline]
    pub fn weight_changed(&mut self, j: u32, delta: i32, nonempty: bool) {
        let d = ClauseBank::polarity(j as usize) * delta;
        self.vote_all += d;
        if nonempty {
            self.vote_alive += d;
        }
    }

    /// Iterate the indices of FALSE literals whose list is non-empty:
    /// `(!literals & nonempty)`, word-parallel.
    #[inline]
    pub fn walk_false_nonempty<'a>(
        &'a self,
        literals: &'a BitVec,
    ) -> impl Iterator<Item = usize> + 'a {
        literals
            .words()
            .iter()
            .zip(self.nonempty.words())
            .enumerate()
            .flat_map(|(wi, (&lw, &ne))| {
                // nonempty's tail bits are 0, masking !lw's padding.
                let mut w = !lw & ne;
                std::iter::from_fn(move || {
                    if w == 0 {
                        return None;
                    }
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + bit)
                })
            })
    }

    #[inline]
    /// The inclusion list for literal `k` (clause ids, unordered).
    pub fn list(&self, k: usize) -> &[u32] {
        self.lists.row(k)
    }

    /// Contiguous list lengths (the walk's skip-empty fast path).
    #[inline]
    pub fn list_lens(&self) -> &[u32] {
        self.lists.lens()
    }

    /// Number of literals (2 × features) this index was built for.
    pub fn n_literals(&self) -> usize {
        self.lists.n_literals()
    }

    /// Vote sum contributed by clauses with at least one included literal.
    pub fn vote_alive(&self) -> i32 {
        self.vote_alive
    }

    /// Vote sum over every clause, including empty ones.
    pub fn vote_all(&self) -> i32 {
        self.vote_all
    }

    /// The position matrix backing O(1) insert/delete.
    pub fn position_store(&self) -> &PositionStore {
        &self.pos
    }

    /// Rebuild from a bank (model load / backend switch).
    pub fn rebuild(&mut self, bank: &ClauseBank) {
        let clauses = bank.clauses();
        let n_lit = bank.n_literals();
        self.lists = ListStore::auto(clauses, n_lit);
        self.pos = PositionStore::auto(clauses, n_lit);
        self.nonempty = BitVec::zeros(n_lit);
        self.vote_all = (0..clauses).map(|j| bank.vote(j)).sum();
        self.vote_alive = 0;
        for j in 0..clauses {
            if bank.count(j) > 0 {
                self.vote_alive += bank.vote(j);
            }
            for k in bank.included_literals(j) {
                let p = self.lists.push(k, j as u32);
                self.pos.set(j as u32, k as u32, p);
                if p == 0 {
                    self.nonempty.set(k);
                }
            }
        }
    }

    /// Full structural invariant check (tests & debug builds):
    /// the lists/matrix pair is a bijection consistent with the bank.
    #[doc(hidden)]
    pub fn check_invariants(&self, bank: &ClauseBank) -> Result<(), String> {
        // 1. every list entry has a matching position
        for k in 0..self.lists.n_literals() {
            let list = self.lists.row(k);
            for (p, &j) in list.iter().enumerate() {
                if self.pos.get(j, k as u32) != Some(p as u32) {
                    return Err(format!("M[{j}][{k}] != {p}"));
                }
                if !bank.include(j as usize, k) {
                    return Err(format!("list {k} holds non-included clause {j}"));
                }
            }
        }
        // 2. every inclusion in the bank is listed exactly once
        for j in 0..bank.clauses() {
            for k in bank.included_literals(j) {
                match self.pos.get(j as u32, k as u32) {
                    Some(p) => {
                        if self.lists.row(k).get(p as usize) != Some(&(j as u32)) {
                            return Err(format!("L_{k}[{p}] != {j}"));
                        }
                    }
                    None => return Err(format!("missing index entry ({j},{k})")),
                }
            }
        }
        // 3. list sizes sum to total inclusions
        let listed: usize = self.lists.lens().iter().map(|&l| l as usize).sum();
        let included: usize = (0..bank.clauses()).map(|j| bank.count(j) as usize).sum();
        if listed != included {
            return Err(format!("listed {listed} != included {included}"));
        }
        // 4. vote baselines
        if self.vote_alive != bank.vote_alive() {
            return Err(format!(
                "vote_alive {} != bank {}",
                self.vote_alive,
                bank.vote_alive()
            ));
        }
        Ok(())
    }
}

/// The paper's evaluator: index + falsification walk.
///
/// Scratch (`gen`, `cur_gen`) deduplicates knock-outs without clearing an
/// n-bit array per evaluation: a clause is "already falsified in this
/// evaluation" iff its stamp equals the current generation.
pub struct IndexedEval {
    index: ClassIndex,
    gen: Vec<u32>,
    cur_gen: u32,
    /// Reusable buffer of walk targets (enables prefetch lookahead).
    walk_buf: Vec<u32>,
}

/// Prefetch the cache line at `p` (no-op off x86_64).
#[inline(always)]
fn prefetch(p: *const u32) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_prefetch(p as *const i8, core::arch::x86_64::_MM_HINT_T0);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

impl IndexedEval {
    /// Indexed evaluator for one class, sized for `params`.
    pub fn new(params: &TMParams) -> Self {
        Self::with_shape(params.clauses_per_class, params.n_literals())
    }

    /// Build for an explicit `(clauses, literals)` shape — clause shards
    /// ([`crate::parallel`]) index fewer clauses than a full class bank.
    pub fn with_shape(clauses: usize, n_literals: usize) -> Self {
        IndexedEval {
            index: ClassIndex::new(clauses, n_literals),
            gen: vec![0; clauses],
            cur_gen: 0,
            walk_buf: Vec::new(),
        }
    }

    /// The underlying falsification index.
    pub fn index(&self) -> &ClassIndex {
        &self.index
    }

    #[inline]
    fn next_gen(&mut self) -> u32 {
        self.cur_gen = self.cur_gen.wrapping_add(1);
        if self.cur_gen == 0 {
            // wrapped: stamps from 4 billion evals ago could collide
            self.gen.fill(0);
            self.cur_gen = 1;
        }
        self.cur_gen
    }
}

impl FlipSink for IndexedEval {
    #[inline]
    fn on_include(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.index.insert(j, k, new_count, weight);
    }
    #[inline]
    fn on_exclude(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.index.delete(j, k, new_count, weight);
    }
    #[inline]
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.index.weight_changed(j, delta, nonempty);
    }
}

impl Evaluator for IndexedEval {
    fn score(&mut self, bank: &ClauseBank, literals: &BitVec) -> i32 {
        let gen = self.next_gen();
        let mut score = self.index.vote_alive;
        // Word-parallel walk (only FALSE literals with NON-EMPTY lists)
        // + software prefetch 8 rows ahead: the row reads are the
        // walk's cache-miss budget (perf pass, §Perf).
        self.walk_buf.clear();
        self.walk_buf
            .extend(self.index.walk_false_nonempty(literals).map(|k| k as u32));
        const LOOKAHEAD: usize = 8;
        for (i, &k) in self.walk_buf.iter().enumerate() {
            if let Some(&kn) = self.walk_buf.get(i + LOOKAHEAD) {
                prefetch(self.index.lists.row_ptr(kn as usize));
            }
            for &j in self.index.lists.row(k as usize) {
                let stamp = &mut self.gen[j as usize];
                if *stamp != gen {
                    *stamp = gen;
                    score -= bank.vote(j as usize);
                }
            }
        }
        score
    }

    fn eval_train(&mut self, bank: &ClauseBank, literals: &BitVec, out: &mut BitVec) -> i32 {
        debug_assert_eq!(out.len(), bank.clauses());
        // all clauses start true (empty ones output 1 during training and
        // appear in no list, so they survive the walk — correct).
        out.set_all();
        let mut score = self.index.vote_all;
        for k in self.index.walk_false_nonempty(literals) {
            for &j in self.index.lists.row(k) {
                let j = j as usize;
                if out.get(j) {
                    out.clear(j);
                    score -= bank.vote(j);
                }
            }
        }
        score
    }

    fn rebuild(&mut self, bank: &ClauseBank) {
        self.index.rebuild(bank);
        self.gen = vec![0; bank.clauses()];
        self.cur_gen = 0;
    }

    fn name(&self) -> &'static str {
        "indexed"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::util::Rng;

    fn random_machine(
        rng: &mut Rng,
        clauses: usize,
        n_lit: usize,
        density: f64,
    ) -> (ClauseBank, IndexedEval) {
        let mut bank = ClauseBank::new(clauses, n_lit);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    bank.set_state(j, k, 1);
                }
            }
        }
        let params = TMParams::new(2, clauses, n_lit / 2);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        (bank, ev)
    }

    #[test]
    fn paper_step_by_step_example() {
        // Fig. 2 walk-through: class 1 with clauses C1+, C1-, C2+, C2-
        // over features x1, x2 (literals: x1=0, x2=1, ¬x1=2, ¬x2=3).
        // Our ids: C1+ = 0 (+), C1- = 1 (-), C2+ = 2 (+), C2- = 3 (-).
        let mut bank = ClauseBank::new(4, 4);
        // From Fig. 2 left, class 1 lists:
        //  x1: C1+, C1-, C2+     x2: C1-, C2-    ¬x1: C2-, C1-    ¬x2: C2+
        let inclusions: &[(usize, usize)] = &[
            (0, 0), (1, 0), (2, 0), // x1
            (1, 1), (3, 1),         // x2
            (3, 2), (1, 2),         // ¬x1
            (2, 3),                 // ¬x2
        ];
        for &(j, k) in inclusions {
            bank.set_state(j, k, 0);
        }
        let params = TMParams::new(2, 4, 2);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        ev.index.check_invariants(&bank).unwrap();

        // x = (1, 0): literals x1=1, x2=0, ¬x1=0, ¬x2=1.
        let lits = BitVec::from_bools(&[true, false, false, true]);
        // Paper: final class score = 2 (C1-, C2- falsified; C1+, C2+ true).
        assert_eq!(ev.score(&bank, &lits), 2);
        assert_eq!(ev.score(&bank, &lits), 2); // scratch reuse is clean
    }

    #[test]
    fn paper_deletion_example() {
        // Continue Fig. 2: delete C1+ (id 0) from L_{x1}; C2+ (id 2,
        // last in the list) must take its slot and M must be updated.
        let mut bank = ClauseBank::new(4, 4);
        for &(j, k) in &[(0usize, 0usize), (1, 0), (2, 0)] {
            bank.set_state(j, k, 0);
        }
        let params = TMParams::new(2, 4, 2);
        let mut ev = IndexedEval::new(&params);
        ev.rebuild(&bank);
        assert_eq!(ev.index.list(0), &[0, 1, 2]);

        bank.set_state(0, 0, -1);
        ev.on_exclude(0, 0, bank.count(0), 1);
        assert_eq!(ev.index.list(0), &[2, 1]); // last element moved to front
        ev.index.check_invariants(&bank).unwrap();

        // and insertion appends at the end
        bank.set_state(0, 1, 0);
        ev.on_include(0, 1, bank.count(0), 1);
        assert_eq!(ev.index.list(1), &[0]);
        ev.index.check_invariants(&bank).unwrap();
    }

    #[test]
    fn score_matches_reference_on_random_machines() {
        let mut rng = Rng::new(13);
        for trial in 0..60 {
            let (bank, mut ev) = random_machine(&mut rng, 16, 40, 0.15);
            let lits =
                BitVec::from_bools(&(0..40).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
            assert_eq!(
                ev.score(&bank, &lits),
                reference_score(&bank, &lits, false),
                "trial {trial}"
            );
            let mut out = BitVec::zeros(16);
            assert_eq!(
                ev.eval_train(&bank, &lits, &mut out),
                reference_score(&bank, &lits, true),
                "train {trial}"
            );
            // outputs themselves must match the semantics
            for j in 0..16 {
                let want = if bank.count(j) == 0 {
                    true
                } else {
                    bank.included_literals(j).all(|k| lits.get(k))
                };
                assert_eq!(out.get(j), want, "clause {j} trial {trial}");
            }
        }
    }

    #[test]
    fn maintenance_tracks_random_flips() {
        let mut rng = Rng::new(14);
        let (mut bank, mut ev) = random_machine(&mut rng, 10, 24, 0.1);
        for _ in 0..5000 {
            let j = rng.below(10) as usize;
            let k = rng.below(24) as usize;
            if rng.bern(0.5) {
                if bank.bump_up(j, k) == crate::tm::bank::Flip::Included {
                    ev.on_include(j as u32, k as u32, bank.count(j), bank.weight(j));
                }
            } else if bank.bump_down(j, k) == crate::tm::bank::Flip::Excluded {
                ev.on_exclude(j as u32, k as u32, bank.count(j), bank.weight(j));
            }
        }
        ev.index.check_invariants(&bank).unwrap();
        // and evaluation still agrees with the reference
        let lits = BitVec::from_bools(&(0..24).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
        assert_eq!(ev.score(&bank, &lits), reference_score(&bank, &lits, false));
    }

    #[test]
    fn generation_wraparound_is_safe() {
        let mut rng = Rng::new(15);
        let (bank, mut ev) = random_machine(&mut rng, 8, 16, 0.2);
        ev.cur_gen = u32::MAX - 2;
        let lits = BitVec::from_bools(&(0..16).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
        let want = reference_score(&bank, &lits, false);
        for _ in 0..6 {
            assert_eq!(ev.score(&bank, &lits), want);
        }
    }

    #[test]
    fn all_true_input_gives_vote_alive() {
        let mut rng = Rng::new(16);
        let (bank, mut ev) = random_machine(&mut rng, 12, 20, 0.2);
        let lits = BitVec::ones(20);
        assert_eq!(ev.score(&bank, &lits), ev.index.vote_alive());
        assert_eq!(ev.index.vote_alive(), bank.vote_alive());
    }
}
