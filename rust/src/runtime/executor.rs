//! Compile + execute HLO artifacts on the PJRT CPU client.
//!
//! Follows the load_hlo reference pattern: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. The
//! lowered model takes `(literals, include, count, polarity)` and returns
//! the 2-tuple `(scores, predictions)` (see `python/compile/model.py`).
//!
//! For serving, the three model arrays are uploaded to device once
//! ([`PreparedModel`]) and only the literal batch moves per request
//! (`execute_b` over PJRT buffers).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::runtime::artifact::VariantMeta;
use crate::tm::io::DenseModel;

/// Shared PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment; real-TPU
    /// use would swap in `PjRtClient::tpu`).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// The PJRT platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load_artifact(&self, hlo_path: &Path, meta: VariantMeta) -> Result<TmExecutable> {
        let proto = xla::HloModuleProto::from_text_file(hlo_path)
            .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", meta.name))?;
        Ok(TmExecutable { exe, meta })
    }

    /// Upload a dense model to device-resident buffers for `exe`.
    pub fn prepare_model(
        &self,
        exe: &TmExecutable,
        model: &DenseModel,
    ) -> Result<PreparedModel> {
        let m = &exe.meta;
        ensure!(
            model.n_literals == m.n_literals()
                && model.clauses_total == m.clauses
                && model.classes == m.classes,
            "model shape ({}, {}, {}) does not match artifact {} ({}, {}, {})",
            model.n_literals,
            model.clauses_total,
            model.classes,
            m.name,
            m.n_literals(),
            m.clauses,
            m.classes,
        );
        let include = self.client.buffer_from_host_buffer(
            &model.include,
            &[model.n_literals, model.clauses_total],
            None,
        )?;
        let count =
            self.client
                .buffer_from_host_buffer(&model.count, &[model.clauses_total], None)?;
        let polarity = self.client.buffer_from_host_buffer(
            &model.polarity,
            &[model.clauses_total, model.classes],
            None,
        )?;
        Ok(PreparedModel {
            include,
            count,
            polarity,
        })
    }
}

/// One compiled model variant.
pub struct TmExecutable {
    exe: xla::PjRtLoadedExecutable,
    /// Shape metadata of the loaded variant.
    pub meta: VariantMeta,
}

/// Device-resident model arrays (uploaded once per model refresh).
pub struct PreparedModel {
    include: xla::PjRtBuffer,
    count: xla::PjRtBuffer,
    polarity: xla::PjRtBuffer,
}

/// Result of one batched forward pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Forward {
    /// Row-major `(batch, classes)` vote scores.
    pub scores: Vec<f32>,
    /// Argmax predictions, length `batch`.
    pub predictions: Vec<i32>,
    /// Batch size scored.
    pub batch: usize,
    /// Number of classes.
    pub classes: usize,
}

impl TmExecutable {
    /// Run a literal batch against a prepared (device-resident) model.
    ///
    /// `literals` is row-major `(rows, 2o)` with `rows <= meta.batch`;
    /// short batches are padded with all-true rows and truncated on
    /// return (an all-true row satisfies every clause — harmless).
    pub fn run(
        &self,
        rt: &Runtime,
        prepared: &PreparedModel,
        literals: &[f32],
        rows: usize,
    ) -> Result<Forward> {
        let m = &self.meta;
        let n_lit = m.n_literals();
        ensure!(rows > 0, "empty batch");
        ensure!(rows <= m.batch, "batch {rows} exceeds artifact batch {}", m.batch);
        ensure!(
            literals.len() == rows * n_lit,
            "literal buffer {} != rows {rows} x {n_lit}",
            literals.len()
        );
        let mut padded;
        let data = if rows == m.batch {
            literals
        } else {
            padded = vec![1.0f32; m.batch * n_lit];
            padded[..literals.len()].copy_from_slice(literals);
            &padded[..]
        };
        let lit_buf = rt
            .client
            .buffer_from_host_buffer(data, &[m.batch, n_lit], None)?;
        let result = self.exe.execute_b(&[
            &lit_buf,
            &prepared.include,
            &prepared.count,
            &prepared.polarity,
        ])?;
        let out = result[0][0].to_literal_sync()?;
        let (scores_lit, preds_lit) = out.to_tuple2()?;
        let mut scores = scores_lit.to_vec::<f32>()?;
        let mut predictions = preds_lit.to_vec::<i32>()?;
        scores.truncate(rows * m.classes);
        predictions.truncate(rows);
        Ok(Forward {
            scores,
            predictions,
            batch: rows,
            classes: m.classes,
        })
    }

    /// Convenience: upload model arrays per call (tests, one-shot runs).
    pub fn run_unprepared(
        &self,
        rt: &Runtime,
        model: &DenseModel,
        literals: &[f32],
        rows: usize,
    ) -> Result<Forward> {
        let prepared = rt.prepare_model(self, model)?;
        self.run(rt, &prepared, literals, rows)
    }
}

// Runtime round-trip tests live in rust/tests/runtime_roundtrip.rs (they
// need artifacts/ built by `make artifacts`); unit tests here cover the
// padding/validation logic that doesn't touch PJRT.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape_contract() {
        let f = Forward {
            scores: vec![0.0; 6],
            predictions: vec![0; 2],
            batch: 2,
            classes: 3,
        };
        assert_eq!(f.scores.len(), f.batch * f.classes);
    }
}
