//! PJRT runtime: load and execute AOT-compiled XLA artifacts.
//!
//! `python/compile/aot.py` lowers the Layer-2 JAX model (which embeds
//! the Layer-1 Pallas kernel) to HLO *text*; this module loads those
//! artifacts through the `xla` crate (PJRT C API, CPU plugin) and runs
//! them from Rust — Python is never on the request path.
//!
//! * [`artifact`] — `manifest.json` parsing + variant selection.
//! * [`executor`] — compile + execute with device-resident model
//!   buffers (`include` / `count` / `polarity` uploaded once, literal
//!   batches streamed per request).

pub mod artifact;
pub mod executor;

pub use artifact::{Manifest, VariantMeta};
pub use executor::{PreparedModel, Runtime, TmExecutable};
