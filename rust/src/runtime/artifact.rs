//! Artifact manifest: what `python/compile/aot.py` produced.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// One AOT-lowered model variant.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantMeta {
    /// Variant name (`serve_b1`, `serve_b64`, …).
    pub name: String,
    /// HLO file name inside the artifact directory.
    pub file: String,
    /// Batch size the variant was lowered for.
    pub batch: usize,
    /// Raw boolean features.
    pub features: usize,
    /// Total clauses across every class.
    pub clauses: usize,
    /// Number of classes.
    pub classes: usize,
    /// True if lowered in the class-fused form.
    pub fused: bool,
}

impl VariantMeta {
    /// Number of literals (2 × features).
    pub fn n_literals(&self) -> usize {
        2 * self.features
    }

    fn from_json(v: &Json) -> Result<Self> {
        let str_field = |name: &str| -> Result<String> {
            Ok(v.get(name)
                .and_then(Json::as_str)
                .with_context(|| format!("variant missing string '{name}'"))?
                .to_string())
        };
        let num_field = |name: &str| -> Result<usize> {
            v.get(name)
                .and_then(Json::as_usize)
                .with_context(|| format!("variant missing uint '{name}'"))
        };
        Ok(VariantMeta {
            name: str_field("name")?,
            file: str_field("file")?,
            batch: num_field("batch")?,
            features: num_field("features")?,
            clauses: num_field("clauses")?,
            classes: num_field("classes")?,
            fused: v.get("fused").and_then(Json::as_bool).unwrap_or(true),
        })
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was read from.
    pub dir: PathBuf,
    /// Every lowered variant.
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    /// Parse `manifest.json` text produced by the AOT compiler.
    pub fn parse(dir: &Path, text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        match v.get("format").and_then(Json::as_str) {
            Some("hlo-text") => {}
            other => bail!("unsupported artifact format {other:?}"),
        }
        let variants = v
            .get("variants")
            .and_then(Json::as_arr)
            .context("manifest missing 'variants'")?
            .iter()
            .map(VariantMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        Self::parse(dir, &text)
    }

    /// The variant named `name`, if present.
    pub fn by_name(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Pick the smallest-batch fused variant that fits a model shape and
    /// can hold `batch` rows.
    pub fn pick(
        &self,
        batch: usize,
        features: usize,
        clauses: usize,
        classes: usize,
    ) -> Option<&VariantMeta> {
        self.variants
            .iter()
            .filter(|v| {
                v.fused
                    && v.features == features
                    && v.clauses == clauses
                    && v.classes == classes
                    && v.batch >= batch
            })
            .min_by_key(|v| v.batch)
    }

    /// Absolute path of the variant's HLO file.
    pub fn hlo_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "variants": [
        {"name": "a", "file": "a.hlo.txt", "batch": 32, "features": 784,
         "clauses": 1280, "classes": 10, "fused": true, "sha256": "x"},
        {"name": "b", "file": "b.hlo.txt", "batch": 1, "features": 784,
         "clauses": 1280, "classes": 10, "fused": true, "sha256": "y"},
        {"name": "c", "file": "c.hlo.txt", "batch": 32, "features": 784,
         "clauses": 1280, "classes": 10, "fused": false, "sha256": "z"}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 3);
        assert_eq!(m.by_name("a").unwrap().batch, 32);
        assert_eq!(m.by_name("a").unwrap().n_literals(), 1568);
        assert!(m.by_name("missing").is_none());
    }

    #[test]
    fn pick_prefers_smallest_sufficient_batch() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.pick(1, 784, 1280, 10).unwrap().name, "b");
        assert_eq!(m.pick(2, 784, 1280, 10).unwrap().name, "a");
        assert_eq!(m.pick(32, 784, 1280, 10).unwrap().name, "a");
        assert!(m.pick(64, 784, 1280, 10).is_none());
        assert!(m.pick(1, 100, 1280, 10).is_none());
    }

    #[test]
    fn pick_skips_unfused() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_ne!(m.pick(32, 784, 1280, 10).unwrap().name, "c");
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(Path::new("/tmp"), r#"{"format":"proto"}"#).is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "not json").is_err());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = Manifest::parse(Path::new("/art"), SAMPLE).unwrap();
        let v = m.by_name("a").unwrap();
        assert_eq!(m.hlo_path(v), PathBuf::from("/art/a.hlo.txt"));
    }
}
