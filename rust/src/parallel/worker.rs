//! The per-thread training loop over one clause shard.
//!
//! An epoch is processed in windows of `stale_window` samples, each in
//! two phases:
//!
//! 1. **evaluate** — for every sample in the window the worker draws the
//!    negative class (from its clone of the shared sample stream, so all
//!    workers agree without communicating), walks its shard's
//!    falsification indexes for the target and negative class, records
//!    the shard-local clause outputs, and adds its partial vote sums to
//!    the shared [`VoteTally`].
//! 2. **feedback** — after the window barrier the tally holds complete
//!    window-start vote sums; the worker replays the window, computing
//!    the clause-update probability from the (now slightly stale) sums
//!    and applying Type I/II feedback to its own clauses only, through
//!    [`update_clause_range`] — the exact body the sequential trainer
//!    runs. Index maintenance rides the same O(1) flip hooks.
//!
//! Workers never touch each other's TA state, so the only shared writes
//! are the tally's relaxed atomic adds, ordered by the window barrier.
//! A fast worker may start evaluating window `k+1` while a slow one is
//! still feeding back window `k` — harmless, because evaluation reads
//! only the worker's *own* shard and window `k+1`'s tally slots are
//! disjoint from window `k`'s.

use std::ops::Range;

use crate::parallel::shard::ClauseShard;
use crate::parallel::tally::{Slot, VoteTally, WindowBarrier};
use crate::tm::classifier::MultiClassTM;
use crate::tm::feedback::{
    clause_update_threshold, update_clause_range, FeedbackCtx, FeedbackScratch,
};
use crate::tm::trainer::train_streams;
use crate::util::rng::Rng;
use crate::util::BitVec;

/// One worker's persistent training state: its clause shard (private
/// banks + per-shard indexes), its RNG streams, and the window-sized
/// clause-output buffers carried from the evaluate phase to the
/// feedback phase.
pub struct WorkerState {
    shard: ClauseShard,
    sample_rng: Rng,
    feedback_rng: Rng,
    ctx: FeedbackCtx,
    threshold: i32,
    classes: usize,
    /// Clause outputs per window position: `[2b]` = target class,
    /// `[2b + 1]` = negative class, each shard-clauses bits wide.
    out_bufs: Vec<BitVec>,
    /// Negative class drawn per window position.
    negs: Vec<usize>,
    /// Reusable feedback mask buffers (hot path allocates nothing).
    scratch: FeedbackScratch,
    clause_updates: u64,
}

impl WorkerState {
    /// Build worker `worker` owning the clause range `range`, with RNG
    /// streams from the [`train_streams`] contract (worker 0 ==
    /// the sequential trainer's streams).
    pub fn new(tm: &MultiClassTM, range: Range<usize>, worker: u64, window: usize) -> Self {
        let params = &tm.params;
        let (sample_rng, feedback_rng) = train_streams(params.seed, worker);
        let shard = ClauseShard::extract(tm, range);
        let len = shard.clauses();
        WorkerState {
            out_bufs: (0..2 * window.max(1)).map(|_| BitVec::zeros(len)).collect(),
            negs: vec![0; window.max(1)],
            scratch: FeedbackScratch::with_simd(params.n_literals(), params.simd.resolve()),
            ctx: FeedbackCtx::new(params.s, params.boost_true_positive, params.weighted),
            threshold: params.threshold as i32,
            classes: params.classes,
            sample_rng,
            feedback_rng,
            shard,
            clause_updates: 0,
        }
    }

    /// The worker's clause shard.
    pub fn shard(&self) -> &ClauseShard {
        &self.shard
    }

    /// Resize the window-sized buffers (staleness-window change).
    pub fn set_window(&mut self, window: usize) {
        let window = window.max(1);
        let len = self.shard.clauses();
        self.out_bufs.resize_with(2 * window, || BitVec::zeros(len));
        self.negs.resize(window, 0);
    }

    /// Clause updates applied since the last call, resetting the count.
    pub fn take_updates(&mut self) -> u64 {
        std::mem::take(&mut self.clause_updates)
    }

    /// Run one epoch over `samples` (shared order across workers),
    /// synchronizing on `barrier` every `window` samples.
    ///
    /// If this worker panics mid-epoch, the drop guard aborts the
    /// barrier so peers bail out instead of deadlocking, and the panic
    /// propagates through the scoped-thread join.
    pub fn run_epoch(
        &mut self,
        samples: &[(&BitVec, usize)],
        window: usize,
        tally: &VoteTally,
        barrier: &WindowBarrier,
    ) {
        let _guard = AbortOnPanic(barrier);
        let window = window.max(1);
        debug_assert!(self.negs.len() >= window, "set_window before run_epoch");
        debug_assert_eq!(tally.samples(), samples.len());
        let m = self.classes;
        let mut block_start = 0;
        while block_start < samples.len() {
            let block_end = (block_start + window).min(samples.len());
            let block = &samples[block_start..block_end];

            // phase 1: evaluate the shard, publish partial vote sums
            for (b, &(lits, label)) in block.iter().enumerate() {
                debug_assert!(label < m);
                let mut neg = self.sample_rng.below(m as u32 - 1) as usize;
                if neg >= label {
                    neg += 1;
                }
                self.negs[b] = neg;
                let pt = self.shard.eval_train(label, lits, &mut self.out_bufs[2 * b]);
                let pn = self
                    .shard
                    .eval_train(neg, lits, &mut self.out_bufs[2 * b + 1]);
                tally.add(block_start + b, Slot::Target, pt);
                tally.add(block_start + b, Slot::Negative, pn);
            }

            if !barrier.wait() {
                return; // a peer panicked: epoch aborted
            }

            // phase 2: feedback against the window-start vote sums
            for (b, &(lits, label)) in block.iter().enumerate() {
                let i = block_start + b;
                let p_t =
                    clause_update_threshold(self.threshold, tally.read(i, Slot::Target), true);
                let (bank, ev) = self.shard.feedback_parts(label);
                self.clause_updates += update_clause_range(
                    bank,
                    ev,
                    &mut self.feedback_rng,
                    &self.ctx,
                    &self.out_bufs[2 * b],
                    lits,
                    p_t,
                    true,
                    &mut self.scratch,
                );
                let p_n = clause_update_threshold(
                    self.threshold,
                    tally.read(i, Slot::Negative),
                    false,
                );
                let (bank, ev) = self.shard.feedback_parts(self.negs[b]);
                self.clause_updates += update_clause_range(
                    bank,
                    ev,
                    &mut self.feedback_rng,
                    &self.ctx,
                    &self.out_bufs[2 * b + 1],
                    lits,
                    p_n,
                    false,
                    &mut self.scratch,
                );
            }

            block_start = block_end;
        }
    }
}

/// Aborts the window barrier if the worker unwinds, so peers blocked in
/// `wait` return instead of deadlocking.
struct AbortOnPanic<'a>(&'a WindowBarrier);

impl Drop for AbortOnPanic<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.abort();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::shard::partition_clauses;
    use crate::parallel::testutil::toy_samples;
    use crate::tm::params::TMParams;

    #[test]
    fn single_worker_epoch_keeps_shard_invariants() {
        let params = TMParams::new(2, 12, 8).with_threshold(10);
        let tm = MultiClassTM::new(params);
        let data = toy_samples(60, 8, 9);
        let samples: Vec<(&BitVec, usize)> = data.iter().map(|(l, y)| (l, *y)).collect();
        let mut w = WorkerState::new(&tm, 0..12, 0, 4);
        let tally = VoteTally::new(samples.len());
        let barrier = WindowBarrier::new(1);
        w.run_epoch(&samples, 4, &tally, &barrier);
        assert!(w.take_updates() > 0);
        assert_eq!(w.take_updates(), 0);
        w.shard().check_invariants().unwrap();
    }

    #[test]
    fn single_worker_epoch_runs_on_scalar_layout() {
        // the escape-hatch layout drives the same worker loop (the
        // cross-layout bit-identity proof lives in
        // rust/tests/feedback_equiv.rs)
        use crate::tm::bank::TaLayout;
        let params = TMParams::new(2, 12, 8)
            .with_threshold(10)
            .with_ta_layout(TaLayout::Scalar);
        let tm = MultiClassTM::new(params);
        let data = toy_samples(60, 8, 9);
        let samples: Vec<(&BitVec, usize)> = data.iter().map(|(l, y)| (l, *y)).collect();
        let mut w = WorkerState::new(&tm, 0..12, 0, 4);
        assert_eq!(w.shard().bank(0).layout(), TaLayout::Scalar);
        let tally = VoteTally::new(samples.len());
        let barrier = WindowBarrier::new(1);
        w.run_epoch(&samples, 4, &tally, &barrier);
        assert!(w.take_updates() > 0);
        w.shard().check_invariants().unwrap();
    }

    #[test]
    fn two_workers_cover_disjoint_ranges_concurrently() {
        let params = TMParams::new(2, 16, 8).with_threshold(10);
        let tm = MultiClassTM::new(params);
        let data = toy_samples(80, 8, 10);
        let samples: Vec<(&BitVec, usize)> = data.iter().map(|(l, y)| (l, *y)).collect();
        let ranges = partition_clauses(16, 2);
        let mut workers: Vec<WorkerState> = ranges
            .iter()
            .enumerate()
            .map(|(w, r)| WorkerState::new(&tm, r.clone(), w as u64, 8))
            .collect();
        let mut tally = VoteTally::new(samples.len());
        let barrier = WindowBarrier::new(2);
        for _epoch in 0..2 {
            tally.reset(samples.len());
            std::thread::scope(|scope| {
                for w in workers.iter_mut() {
                    let (samples, tally, barrier) = (&samples[..], &tally, &barrier);
                    scope.spawn(move || w.run_epoch(samples, 8, tally, barrier));
                }
            });
        }
        for w in &workers {
            w.shard().check_invariants().unwrap();
        }
        // every worker saw the same negative-class stream: tallies are
        // consistent sums, and shards stayed disjoint — reassembling
        // must produce a bank whose counts are coherent
        let mut out = MultiClassTM::new(tm.params.clone());
        for w in &workers {
            w.shard().writeback(&mut out);
        }
        for c in 0..2 {
            assert!(out.bank(c).check_counts());
        }
    }
}
