//! [`ParallelTrainer`] — the user-facing facade of the clause-sharded
//! asynchronous training subsystem, mirroring the sequential
//! [`Trainer`]'s `train_epoch` / `predict` / `accuracy` surface.
//!
//! Training runs on scoped worker threads, one clause shard each; after
//! every epoch the shards are written back into the global
//! [`MultiClassTM`] (cheap state copies), and the per-class +
//! class-fused (PR 1) serving indexes resync lazily at the next
//! inference call, so inference between epochs — and the model that is
//! eventually saved — is indistinguishable from a sequentially trained
//! one while back-to-back epochs skip rebuilds nothing reads.

use crate::eval::Backend;
use crate::index::IndexStats;
use crate::parallel::resolve_threads;
use crate::parallel::shard::partition_clauses;
use crate::parallel::tally::{VoteTally, WindowBarrier};
use crate::parallel::worker::WorkerState;
use crate::tm::classifier::MultiClassTM;
use crate::tm::params::TMParams;
use crate::tm::trainer::{EpochStats, Trainer};
use crate::util::BitVec;

/// Default staleness window: the number of samples between worker
/// rendezvous. 8 amortizes the barrier well below per-sample cost at
/// paper scales while keeping vote sums at most 8 samples stale.
pub const DEFAULT_STALE_WINDOW: usize = 8;

/// Multi-threaded trainer: clause shards, per-shard falsification
/// indexes, shared stale vote tally (see [`crate::parallel`]).
pub struct ParallelTrainer {
    /// Canonical machine + serving engine (indexed backend). Only
    /// touched between epochs: shard writeback, inference, model I/O.
    inner: Trainer,
    workers: Vec<WorkerState>,
    tally: VoteTally,
    stale_window: usize,
    /// The inner trainer's per-class indexes lag the banks after an
    /// epoch's shard writeback. Serving never reads them (the indexed
    /// backend scores through the fused engine, which has its own dirty
    /// flag); they are rebuilt lazily for the diagnostic surfaces —
    /// `trainer()` / `into_trainer()` / `index_stats()` /
    /// `check_invariants()` — so training pays no rebuilds it never
    /// reads.
    evals_stale: bool,
}

impl ParallelTrainer {
    /// Fresh machine trained across `threads` workers (`0` = every
    /// available core, see [`resolve_threads`]).
    pub fn new(params: TMParams, threads: usize) -> Self {
        Self::from_machine(MultiClassTM::new(params), threads)
    }

    /// Continue training an existing machine across `threads` workers.
    pub fn from_machine(tm: MultiClassTM, threads: usize) -> Self {
        let threads = resolve_threads(threads);
        let ranges = partition_clauses(tm.params.clauses_per_class, threads);
        let workers: Vec<WorkerState> = ranges
            .into_iter()
            .enumerate()
            .map(|(w, r)| WorkerState::new(&tm, r, w as u64, DEFAULT_STALE_WINDOW))
            .collect();
        ParallelTrainer {
            inner: Trainer::from_machine(tm, Backend::Indexed),
            workers,
            tally: VoteTally::new(0),
            stale_window: DEFAULT_STALE_WINDOW,
            evals_stale: false,
        }
    }

    /// Set the staleness window (samples between worker rendezvous).
    /// `1` = sequential-consistent vote sums, one barrier per sample;
    /// larger windows amortize synchronization at the cost of staler
    /// sums. Ignored for a single worker, which always runs window 1.
    pub fn with_stale_window(mut self, window: usize) -> Self {
        self.set_stale_window(window);
        self
    }

    /// See [`ParallelTrainer::with_stale_window`].
    pub fn set_stale_window(&mut self, window: usize) {
        self.stale_window = window.max(1);
        let effective = self.effective_window();
        for w in &mut self.workers {
            w.set_window(effective);
        }
    }

    /// Worker-thread count (== clause shards).
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Configured staleness window.
    pub fn stale_window(&self) -> usize {
        self.stale_window
    }

    /// A single worker owns every clause, so its own partial *is* the
    /// full vote sum — window 1 makes it exactly the sequential
    /// schedule (and bit-identical to [`Trainer`], same RNG contract).
    fn effective_window(&self) -> usize {
        if self.workers.len() == 1 {
            1
        } else {
            self.stale_window
        }
    }

    /// The trained machine. The banks are written back eagerly at every
    /// epoch boundary (a cheap state copy), so this is always current.
    pub fn tm(&self) -> &MultiClassTM {
        &self.inner.tm
    }

    /// Rebuild the inner trainer's per-class indexes iff an epoch's
    /// writeback left them stale. Only the diagnostic surfaces need
    /// this — indexed-backend serving reads the fused engine alone,
    /// which re-snapshots itself off its own dirty flag.
    fn ensure_synced(&mut self) {
        if self.evals_stale {
            self.inner.resync_evaluators();
            self.evals_stale = false;
        }
    }

    /// Borrow the inner (inference-serving) trainer, synced to the
    /// trained banks.
    pub fn trainer(&mut self) -> &Trainer {
        self.ensure_synced();
        &self.inner
    }

    /// Unwrap into the inner sequential trainer (model save, backend
    /// switch, further sequential training), synced to the trained
    /// banks.
    pub fn into_trainer(mut self) -> Trainer {
        self.ensure_synced();
        self.inner
    }

    /// Worker threads the *inference* engine shards batches across
    /// (independent of the training worker count).
    pub fn set_infer_threads(&mut self, threads: usize) {
        self.inner.set_infer_threads(threads);
    }

    /// Dense/sparse inference-engine selection policy (see
    /// [`Trainer::set_infer_mode`]). Epoch writebacks dirty both
    /// serving engines, so a mid-training mode switch is always served
    /// from a fresh snapshot.
    pub fn set_infer_mode(&mut self, mode: crate::engine::InferMode) {
        self.inner.set_infer_mode(mode);
    }

    /// One epoch over `(literals, label)` pairs in the given order,
    /// sharded across the workers. Returns aggregate stats with
    /// wall-clock throughput.
    pub fn train_epoch<'a>(
        &mut self,
        samples: impl Iterator<Item = (&'a BitVec, usize)>,
    ) -> EpochStats {
        let samples: Vec<(&BitVec, usize)> = samples.collect();
        let t0 = std::time::Instant::now();
        self.tally.reset(samples.len());
        let window = self.effective_window();
        let barrier = WindowBarrier::new(self.workers.len());
        if self.workers.len() == 1 {
            // no spawn: the single worker runs on the calling thread
            self.workers[0].run_epoch(&samples, window, &self.tally, &barrier);
        } else {
            let tally = &self.tally;
            let barrier = &barrier;
            let shared = &samples[..];
            std::thread::scope(|scope| {
                for w in self.workers.iter_mut() {
                    scope.spawn(move || w.run_epoch(shared, window, tally, barrier));
                }
            });
        }

        // reassemble the global machine (cheap bank copies); the
        // PR-1 fused serving engine re-snapshots lazily off its dirty
        // flag at the next inference call, and the per-class diagnostic
        // indexes rebuild only if something reads them — back-to-back
        // epochs never pay an index rebuild they don't read
        let mut stats = EpochStats {
            samples: samples.len(),
            ..EpochStats::default()
        };
        for w in self.workers.iter_mut() {
            stats.clause_updates += w.take_updates();
            w.shard().writeback(&mut self.inner.tm);
        }
        self.inner.invalidate_engine();
        self.evals_stale = true;
        stats.finish(t0.elapsed())
    }

    /// Argmax prediction (class-fused indexed inference, as
    /// [`Trainer::predict`]; the fused engine re-snapshots itself if
    /// training dirtied it).
    pub fn predict(&mut self, literals: &BitVec) -> usize {
        self.inner.predict(literals)
    }

    /// Per-class scores (see [`Trainer::scores`]).
    pub fn scores(&mut self, literals: &BitVec) -> Vec<i32> {
        self.inner.scores(literals)
    }

    /// Per-class scores into a caller buffer (see
    /// [`Trainer::scores_into`]).
    pub fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]) {
        self.inner.scores_into(literals, out)
    }

    /// Batch scores into a row-major matrix (see
    /// [`Trainer::score_batch_into`]).
    pub fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        self.inner.score_batch_into(batch, out)
    }

    /// Accuracy over a labelled set (see [`Trainer::accuracy`]).
    pub fn accuracy<'a>(
        &mut self,
        samples: impl Iterator<Item = (&'a BitVec, usize)>,
    ) -> f64 {
        self.inner.accuracy(samples)
    }

    /// Index statistics per class of the *global* serving index.
    pub fn index_stats(&mut self) -> Option<Vec<IndexStats>> {
        self.ensure_synced();
        self.inner.index_stats()
    }

    /// Full structural check: the global trainer's invariants, every
    /// shard's per-class index invariants, and shard-bank/global-bank
    /// agreement over each shard's clause range.
    #[doc(hidden)]
    pub fn check_invariants(&mut self) -> Result<(), String> {
        self.ensure_synced();
        self.inner.check_invariants()?;
        for w in &self.workers {
            w.shard().check_invariants()?;
            let r = w.shard().range();
            for c in 0..self.inner.tm.classes() {
                let global = self.inner.tm.bank(c);
                let local = w.shard().bank(c);
                for j in 0..local.clauses() {
                    if global.clause_states(r.start + j) != local.clause_states(j) {
                        return Err(format!(
                            "class {c} clause {}: shard states diverge from global",
                            r.start + j
                        ));
                    }
                    if global.weight(r.start + j) != local.weight(j) {
                        return Err(format!(
                            "class {c} clause {}: shard weight diverges from global",
                            r.start + j
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::testutil::toy_samples;

    #[test]
    fn parallel_learns_toy_problem() {
        let params = TMParams::new(2, 20, 8).with_threshold(10).with_s(3.0);
        let mut tr = ParallelTrainer::new(params, 2).with_stale_window(4);
        assert_eq!(tr.threads(), 2);
        let train = toy_samples(400, 8, 1);
        for _ in 0..10 {
            let stats = tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            assert_eq!(stats.samples, 400);
            assert!(stats.updates_per_sec >= 0.0);
        }
        let test = toy_samples(200, 8, 2);
        let acc = tr.accuracy(test.iter().map(|(l, y)| (l, *y)));
        assert!(acc > 0.95, "parallel accuracy {acc}");
        tr.check_invariants().unwrap();
    }

    #[test]
    fn single_worker_forces_window_one() {
        let params = TMParams::new(2, 8, 6);
        let tr = ParallelTrainer::new(params, 1).with_stale_window(32);
        assert_eq!(tr.stale_window(), 32);
        assert_eq!(tr.effective_window(), 1);
    }

    #[test]
    fn more_workers_than_clause_pairs_still_trains() {
        let params = TMParams::new(2, 4, 6).with_threshold(6);
        let mut tr = ParallelTrainer::new(params, 8);
        assert_eq!(tr.threads(), 8); // 6 shards are empty
        let train = toy_samples(100, 6, 3);
        tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        tr.check_invariants().unwrap();
    }

    #[test]
    fn epoch_stats_report_throughput() {
        let params = TMParams::new(2, 8, 6);
        let mut tr = ParallelTrainer::new(params, 2);
        let train = toy_samples(50, 6, 4);
        let stats = tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        assert_eq!(stats.samples, 50);
        assert!(stats.clause_updates > 0);
        assert!(stats.elapsed > std::time::Duration::ZERO);
        assert!(stats.updates_per_sec > 0.0);
    }
}
