//! Clause-range partitioning and the per-worker shard state: a slice of
//! every class's clause bank plus an incremental falsification index
//! over exactly those clauses.

use std::ops::Range;

use crate::eval::Evaluator;
use crate::index::IndexedEval;
use crate::tm::bank::ClauseBank;
use crate::tm::classifier::MultiClassTM;
use crate::util::BitVec;

/// Partition `clauses` (even, per [`crate::tm::params::TMParams`]
/// validation) into `workers` contiguous ranges with **even start
/// offsets**, so a shard-local clause id has the same +/− polarity as
/// its global id. Polarity pairs are distributed as evenly as possible;
/// trailing shards may be empty when `workers > clauses / 2`.
pub fn partition_clauses(clauses: usize, workers: usize) -> Vec<Range<usize>> {
    assert!(workers > 0, "need at least one worker");
    let pairs = clauses / 2;
    let base = pairs / workers;
    let extra = pairs % workers;
    let mut ranges = Vec::with_capacity(workers);
    let mut start = 0usize;
    for w in 0..workers {
        let len = 2 * (base + usize::from(w < extra));
        ranges.push(start..start + len);
        start += len;
    }
    // an odd trailing clause (non-validated banks) goes to the last shard
    if start < clauses {
        ranges.last_mut().expect("workers > 0").end = clauses;
    }
    ranges
}

/// One worker's clause shard: for every class, a private [`ClauseBank`]
/// holding the shard's clause range (local ids `0..len`) and an
/// [`IndexedEval`] falsification index over it, maintained incrementally
/// through the same O(1) flip hooks as the sequential trainer.
pub struct ClauseShard {
    range: Range<usize>,
    banks: Vec<ClauseBank>,
    evals: Vec<IndexedEval>,
}

impl ClauseShard {
    /// Extract the shard `range` from every class bank of `tm` and build
    /// the per-class shard indexes.
    pub fn extract(tm: &MultiClassTM, range: Range<usize>) -> Self {
        let n_lit = tm.params.n_literals();
        let banks: Vec<ClauseBank> = (0..tm.classes())
            .map(|c| tm.bank(c).clone_range(range.start, range.len()))
            .collect();
        let evals = banks
            .iter()
            .map(|bank| {
                let mut ev = IndexedEval::with_shape(bank.clauses(), n_lit);
                ev.rebuild(bank);
                ev
            })
            .collect();
        ClauseShard { range, banks, evals }
    }

    /// The global clause range this shard owns.
    pub fn range(&self) -> Range<usize> {
        self.range.clone()
    }

    /// Number of clauses in the shard.
    pub fn clauses(&self) -> usize {
        self.range.len()
    }

    /// The shard's private bank for `class` (local clause ids).
    pub fn bank(&self, class: usize) -> &ClauseBank {
        &self.banks[class]
    }

    /// Training-mode evaluation of the shard's clauses for `class`:
    /// fills `out` (length = shard clauses) with clause outputs and
    /// returns the shard's **partial** vote sum — partials summed over
    /// all shards equal the full bank's training score, because votes
    /// partition over clause ranges.
    pub fn eval_train(&mut self, class: usize, literals: &BitVec, out: &mut BitVec) -> i32 {
        self.evals[class].eval_train(&self.banks[class], literals, out)
    }

    /// Split-borrow the pieces the feedback loop needs: the mutable
    /// bank, the shard index as a flip sink, for one class.
    pub fn feedback_parts(
        &mut self,
        class: usize,
    ) -> (&mut ClauseBank, &mut IndexedEval) {
        (&mut self.banks[class], &mut self.evals[class])
    }

    /// Write the shard's banks back into the global machine (epoch
    /// reassembly).
    pub fn writeback(&self, tm: &mut MultiClassTM) {
        for (c, bank) in self.banks.iter().enumerate() {
            tm.bank_mut(c).write_range(self.range.start, bank);
        }
    }

    /// Structural invariants of every per-class shard index against its
    /// private bank (tests / debug).
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        for (c, (bank, ev)) in self.banks.iter().zip(&self.evals).enumerate() {
            if !bank.check_counts() {
                return Err(format!(
                    "shard {:?} class {c}: include_count out of sync",
                    self.range
                ));
            }
            ev.index()
                .check_invariants(bank)
                .map_err(|e| format!("shard {:?} class {c}: {e}", self.range))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    #[test]
    fn partition_covers_disjointly_with_even_starts() {
        for clauses in [2usize, 4, 10, 100, 246] {
            for workers in [1usize, 2, 3, 4, 7, 64] {
                let ranges = partition_clauses(clauses, workers);
                assert_eq!(ranges.len(), workers);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next, "{clauses}c/{workers}w");
                    assert_eq!(r.start % 2, 0, "odd shard start");
                    next = r.end;
                }
                assert_eq!(next, clauses, "{clauses}c/{workers}w must cover");
            }
        }
    }

    #[test]
    fn partition_balances_within_one_pair() {
        let ranges = partition_clauses(100, 3);
        let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(lens.iter().sum::<usize>(), 100);
        assert!(lens.iter().all(|&l| l == 34 || l == 32), "{lens:?}");
    }

    fn random_tm(rng: &mut Rng, classes: usize, clauses: usize, features: usize) -> MultiClassTM {
        let mut tm = MultiClassTM::new(TMParams::new(classes, clauses, features));
        for c in 0..classes {
            let bank = tm.bank_mut(c);
            for j in 0..clauses {
                for k in 0..2 * features {
                    if rng.bern(0.15) {
                        bank.set_state(j, k, (rng.below(9) as i8) - 4);
                    }
                }
            }
        }
        tm
    }

    #[test]
    fn shard_partials_sum_to_full_training_score() {
        let mut rng = Rng::new(301);
        let tm = random_tm(&mut rng, 3, 12, 10);
        let ranges = partition_clauses(12, 3);
        let mut shards: Vec<ClauseShard> = ranges
            .iter()
            .map(|r| ClauseShard::extract(&tm, r.clone()))
            .collect();
        for s in &shards {
            s.check_invariants().unwrap();
        }
        for _ in 0..20 {
            let lits =
                BitVec::from_bools(&(0..20).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
            for c in 0..3 {
                let mut total = 0i32;
                for s in shards.iter_mut() {
                    let mut out = BitVec::zeros(s.clauses());
                    total += s.eval_train(c, &lits, &mut out);
                    // outputs agree with the global bank's semantics
                    for j in 0..s.clauses() {
                        let gj = s.range().start + j;
                        let bank = tm.bank(c);
                        let want = if bank.count(gj) == 0 {
                            true
                        } else {
                            bank.included_literals(gj).all(|k| lits.get(k))
                        };
                        assert_eq!(out.get(j), want, "class {c} clause {gj}");
                    }
                }
                assert_eq!(total, reference_score(tm.bank(c), &lits, true), "class {c}");
            }
        }
    }

    #[test]
    fn shards_inherit_bank_layout_both_ways() {
        // clone_range carries the TA layout into the shard (sliced
        // shards slice whole bitplane ranges), and writeback lands in
        // the same-layout global bank.
        use crate::tm::bank::TaLayout;
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let mut rng = Rng::new(305);
            let params = TMParams::new(2, 8, 6).with_ta_layout(layout);
            let mut tm = MultiClassTM::new(params);
            for c in 0..2 {
                let bank = tm.bank_mut(c);
                for j in 0..8 {
                    for k in 0..12 {
                        if rng.bern(0.2) {
                            bank.set_state(j, k, (rng.below(9) as i8) - 4);
                        }
                    }
                }
            }
            let shard = ClauseShard::extract(&tm, 2..6);
            assert_eq!(shard.bank(0).layout(), layout);
            shard.check_invariants().unwrap();
            let mut copy = MultiClassTM::new(tm.params.clone());
            shard.writeback(&mut copy);
            for c in 0..2 {
                for j in 2..6 {
                    assert_eq!(
                        tm.bank(c).clause_states(j),
                        copy.bank(c).clause_states(j),
                        "layout {layout:?} class {c} clause {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn writeback_roundtrips() {
        let mut rng = Rng::new(302);
        let tm = random_tm(&mut rng, 2, 8, 6);
        let mut copy = MultiClassTM::new(tm.params.clone());
        for r in partition_clauses(8, 3) {
            ClauseShard::extract(&tm, r).writeback(&mut copy);
        }
        for c in 0..2 {
            assert_eq!(tm.bank(c).states(), copy.bank(c).states());
            assert_eq!(tm.bank(c).weights(), copy.bank(c).weights());
        }
    }

    #[test]
    fn empty_shards_are_harmless() {
        let mut rng = Rng::new(303);
        let tm = random_tm(&mut rng, 2, 4, 5);
        // 8 workers over 2 polarity pairs: 6 empty shards
        let ranges = partition_clauses(4, 8);
        assert!(ranges.iter().filter(|r| r.is_empty()).count() == 6);
        for r in ranges {
            let mut s = ClauseShard::extract(&tm, r);
            s.check_invariants().unwrap();
            let lits = BitVec::ones(10);
            let mut out = BitVec::zeros(s.clauses());
            let partial = s.eval_train(0, &lits, &mut out);
            if s.clauses() == 0 {
                assert_eq!(partial, 0);
            }
        }
    }
}
