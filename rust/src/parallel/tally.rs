//! The shared vote tally: per-sample atomic class-vote sums, plus the
//! window barrier that bounds their staleness.
//!
//! Every training step of the paper's §2 loop needs two class scores —
//! the target class's and one negative class's. Workers evaluating
//! disjoint clause shards each contribute a *partial* vote sum for both;
//! the tally accumulates the partials with relaxed atomic adds (the
//! inter-thread ordering comes from the window barrier, not the
//! individual adds). A slot is complete once every worker has passed the
//! barrier that closes its window — after which the sums are already
//! going stale, because workers immediately start mutating their clauses
//! against them. That bounded staleness is the arXiv 2009.04861
//! relaxation.

use std::sync::atomic::{AtomicI32, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};

/// Which of a sample's two scored classes a partial belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Slot {
    /// The sample's labelled class.
    Target = 0,
    /// The drawn negative class.
    Negative = 1,
}

/// Per-sample atomic vote sums (two per sample: target / negative).
pub struct VoteTally {
    slots: Vec<AtomicI32>,
}

impl VoteTally {
    /// Zeroed tally for `samples` training samples.
    pub fn new(samples: usize) -> Self {
        VoteTally {
            slots: (0..2 * samples).map(|_| AtomicI32::new(0)).collect(),
        }
    }

    /// Number of samples the tally covers.
    pub fn samples(&self) -> usize {
        self.slots.len() / 2
    }

    /// Re-zero (and resize) for a new epoch. `&mut self` — an epoch
    /// starts with the tally unshared, so no atomics are needed here.
    pub fn reset(&mut self, samples: usize) {
        self.slots.clear();
        self.slots.extend((0..2 * samples).map(|_| AtomicI32::new(0)));
    }

    /// Add a shard's partial vote sum for `sample`.
    #[inline]
    pub fn add(&self, sample: usize, slot: Slot, partial: i32) {
        self.slots[2 * sample + slot as usize].fetch_add(partial, Ordering::Relaxed);
    }

    /// Read the accumulated vote sum for `sample`. Complete once every
    /// worker has passed the barrier closing the sample's window; the
    /// value is then a *window-start* snapshot that feedback reads
    /// slightly stale.
    #[inline]
    pub fn read(&self, sample: usize, slot: Slot) -> i32 {
        self.slots[2 * sample + slot as usize].load(Ordering::Relaxed)
    }
}

/// The synchronization points of a parallel epoch: one rendezvous per
/// staleness window (between shard evaluation and shard feedback), and
/// the epoch end itself (thread join in the trainer).
///
/// Unlike [`std::sync::Barrier`] this barrier is **abortable**: a
/// worker that panics mid-epoch calls [`WindowBarrier::abort`] (via the
/// worker loop's drop guard), waking every blocked peer with a `false`
/// return instead of leaving them deadlocked waiting for an arrival
/// that will never come — the panic then propagates normally through
/// the scoped-thread join.
pub struct WindowBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    workers: usize,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    aborted: bool,
}

impl WindowBarrier {
    /// Barrier rendezvousing `workers` threads once per stale window.
    pub fn new(workers: usize) -> Self {
        WindowBarrier {
            state: Mutex::new(BarrierState {
                arrived: 0,
                generation: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
            workers: workers.max(1),
        }
    }

    /// Tolerate lock poisoning: `abort` must get through even if some
    /// other worker panicked at an awkward moment.
    fn lock(&self) -> MutexGuard<'_, BarrierState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Block until every worker arrives (the lock/condvar pairing is
    /// what publishes the tally's relaxed adds to the feedback phase).
    /// Returns `false` iff the epoch was aborted — the caller must bail
    /// out of its epoch loop instead of continuing.
    #[must_use]
    pub fn wait(&self) -> bool {
        let mut s = self.lock();
        if s.aborted {
            return false;
        }
        s.arrived += 1;
        if s.arrived == self.workers {
            s.arrived = 0;
            s.generation += 1;
            self.cv.notify_all();
            return true;
        }
        let gen = s.generation;
        while s.generation == gen && !s.aborted {
            s = match self.cv.wait(s) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        !s.aborted
    }

    /// Mark the epoch aborted and wake every blocked worker.
    pub fn abort(&self) {
        let mut s = self.lock();
        s.aborted = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partials_accumulate_per_slot() {
        let t = VoteTally::new(3);
        assert_eq!(t.samples(), 3);
        t.add(1, Slot::Target, 5);
        t.add(1, Slot::Target, -2);
        t.add(1, Slot::Negative, 7);
        assert_eq!(t.read(1, Slot::Target), 3);
        assert_eq!(t.read(1, Slot::Negative), 7);
        assert_eq!(t.read(0, Slot::Target), 0);
        assert_eq!(t.read(2, Slot::Negative), 0);
    }

    #[test]
    fn reset_rezeroes_and_resizes() {
        let mut t = VoteTally::new(1);
        t.add(0, Slot::Target, 9);
        t.reset(4);
        assert_eq!(t.samples(), 4);
        for i in 0..4 {
            assert_eq!(t.read(i, Slot::Target), 0);
            assert_eq!(t.read(i, Slot::Negative), 0);
        }
    }

    #[test]
    fn concurrent_adds_are_lost_update_free() {
        let t = VoteTally::new(1);
        let workers = 4;
        let barrier = WindowBarrier::new(workers);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    for _ in 0..1000 {
                        t.add(0, Slot::Target, 1);
                    }
                    assert!(barrier.wait());
                    assert_eq!(t.read(0, Slot::Target), workers as i32 * 1000);
                });
            }
        });
    }

    #[test]
    fn barrier_reuses_across_generations() {
        let barrier = WindowBarrier::new(2);
        std::thread::scope(|scope| {
            for _ in 0..2 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        assert!(barrier.wait());
                    }
                });
            }
        });
    }

    #[test]
    fn abort_unblocks_waiters_instead_of_deadlocking() {
        let barrier = WindowBarrier::new(2);
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| barrier.wait());
            // give the waiter time to block, then abort instead of arriving
            std::thread::sleep(std::time::Duration::from_millis(20));
            barrier.abort();
            assert!(!waiter.join().unwrap());
            // late arrivals see the abort immediately
            assert!(!barrier.wait());
        });
    }
}
