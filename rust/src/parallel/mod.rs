//! Clause-sharded asynchronous parallel training.
//!
//! PR 1 made *inference* multi-core (one fused falsification walk per
//! sample, batches sharded across threads); this module does the same
//! for *learning*, following the clause-parallel architecture of
//! *Massively Parallel and Asynchronous Tsetlin Machine Architecture*
//! (arXiv 2009.04861) applied to the clause-indexed evaluator of the
//! source paper (arXiv 2004.03188):
//!
//! * **Clauses are sharded, not data.** Each worker owns a contiguous,
//!   even-aligned clause range of *every* class's bank
//!   ([`shard::partition_clauses`]), so TA state is worker-private and
//!   feedback needs no locks. Even alignment keeps the interleaved
//!   +/− polarity of local ids equal to global ids.
//! * **Each shard keeps its own falsification index.** A per-class
//!   [`crate::index::IndexedEval`] over just the shard's clauses,
//!   maintained through the same O(1) insert/delete flip hooks as the
//!   sequential trainer — the paper's index maintenance is what makes
//!   per-shard training-mode evaluation cheap enough to repeat `W`
//!   times.
//! * **Vote sums are shared, atomic, and slightly stale.** Workers
//!   accumulate per-sample class-vote partials into a [`tally::VoteTally`]
//!   and synchronize once per `stale_window` samples: feedback inside a
//!   window uses vote sums computed from window-start TA state — the
//!   2009.04861 relaxation. `stale_window = 1` is sequential-consistent;
//!   larger windows trade staleness for fewer barriers.
//!
//! With one worker the schedule degenerates to the sequential one and —
//! because the sequential [`crate::tm::trainer::Trainer`] is worker 0 of
//! the [`crate::tm::trainer::train_streams`] RNG contract — a 1-thread
//! [`ParallelTrainer`] epoch is **bit-identical** to a sequential epoch
//! (`rust/tests/parallel_train.rs` asserts this). After every epoch the
//! shards are reassembled into the global [`crate::tm::MultiClassTM`];
//! the per-class + fused (PR 1) serving indexes rebuild lazily at the
//! next inference call, so serving is byte-for-byte the same as for a
//! sequentially trained model and training never pays rebuilds it
//! doesn't read.

pub mod shard;
pub mod tally;
pub mod trainer;
pub mod worker;

pub use shard::{partition_clauses, ClauseShard};
pub use tally::VoteTally;
pub use trainer::{ParallelTrainer, DEFAULT_STALE_WINDOW};

/// Resolve a user-facing `--threads` value: `0` means "use every
/// available core", anything else is taken literally (min 1).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Shared fixtures for this module's unit tests.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::Dataset;
    use crate::util::{BitVec, Rng};

    /// Tiny two-class problem: class 0 = feature 0 set, class 1 = clear,
    /// as `[x, ¬x]` literal vectors.
    pub fn toy_samples(n: usize, features: usize, seed: u64) -> Vec<(BitVec, usize)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> = (0..features)
                    .map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) })
                    .collect();
                (Dataset::literals_from_bools(&bits), y)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_threads_zero_means_all_cores() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
    }
}
