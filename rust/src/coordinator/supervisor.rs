//! Worker supervision: panic-restart bounded by a sliding window, with
//! exponential backoff.
//!
//! A serving worker that panics — a poisoned dependency, a bug in a
//! backend, the fault-injection harness — used to take its whole route
//! down: the in-flight batch's clients got disconnects and the
//! route's worker guard closed the queue for good. Under
//! supervision the panic is caught at the top of the worker loop, the
//! restart counter ([`crate::coordinator::Metrics::restarts`], surfaced
//! as `restarts=` in the `stats` verb) is bumped, and the loop re-enters
//! after a backoff. The in-flight batch is still failed — its response
//! channels unwound with the stack — but everything queued behind it
//! survives to be served by the restarted worker.
//!
//! Restarts are *rate*-bounded, not lifetime-bounded: the budget is
//! `max_restarts` per [`RestartPolicy::window`] (default 5 per 60 s).
//! A deterministic panic on every batch still exhausts the window and
//! exits — at which point the normal last-worker-guard close-and-drain
//! takes over — but a long-lived worker that panics rarely keeps
//! recovering forever instead of being permanently killed by the
//! accumulated lifetime count.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::obs::{journal, EventKind};

/// Restart budget and backoff schedule for one worker thread.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RestartPolicy {
    /// Restarts allowed within any trailing [`RestartPolicy::window`]
    /// before the worker stays down.
    pub max_restarts: u32,
    /// Sliding window the budget applies over. Panics older than this
    /// no longer count against the worker.
    pub window: Duration,
    /// Delay before the first restart; doubles per restart currently
    /// inside the window.
    pub backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 5,
            window: Duration::from_secs(60),
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
        }
    }
}

impl RestartPolicy {
    /// Never restart: a panic kills the worker immediately (the
    /// pre-supervision behavior, used where a restart cannot help).
    pub fn none() -> Self {
        RestartPolicy {
            max_restarts: 0,
            ..Self::default()
        }
    }

    /// Backoff before restart number `attempt` (1-based): exponential
    /// doubling from [`RestartPolicy::backoff`], capped at
    /// [`RestartPolicy::max_backoff`].
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        self.backoff
            .saturating_mul(1u32 << shift)
            .min(self.max_backoff)
    }
}

/// Sliding-window restart bookkeeping shared by [`supervise`] and the
/// factory-route worker loop: remembers when each restart happened and
/// admits a new one only while fewer than `max_restarts` land inside
/// the trailing window.
#[derive(Debug, Default)]
pub(crate) struct RestartWindow {
    times: VecDeque<Instant>,
}

impl RestartWindow {
    pub(crate) fn new() -> RestartWindow {
        RestartWindow::default()
    }

    /// Try to book a restart now. `Some(backoff)` admits it — sleep
    /// that long, then re-enter the worker body; the backoff doubles
    /// with the number of restarts currently inside the window, so an
    /// isolated panic after a quiet spell restarts promptly again.
    /// `None` means the window budget is exhausted.
    pub(crate) fn admit(&mut self, policy: &RestartPolicy) -> Option<Duration> {
        let now = Instant::now();
        while let Some(&t) = self.times.front() {
            if now.duration_since(t) > policy.window {
                self.times.pop_front();
            } else {
                break;
            }
        }
        if self.times.len() >= policy.max_restarts as usize {
            return None;
        }
        self.times.push_back(now);
        Some(policy.backoff_for(self.times.len() as u32))
    }
}

/// How a supervised worker ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupervisedExit {
    /// `body` returned normally (queue closed and drained).
    Clean,
    /// `body` panicked more than `max_restarts` times within one
    /// [`RestartPolicy::window`].
    RestartsExhausted,
}

/// Run one worker "life" repeatedly: `body` returning means clean
/// shutdown; `body` panicking consumes one restart from the sliding
/// window budget (recorded in `restarts` and as a `worker_restart`
/// event in the process [`journal`] under `route`), sleeps the
/// backoff, and re-enters.
pub fn supervise(
    policy: &RestartPolicy,
    restarts: &AtomicU64,
    route: &str,
    mut body: impl FnMut(),
) -> SupervisedExit {
    let mut window = RestartWindow::new();
    loop {
        match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(()) => return SupervisedExit::Clean,
            Err(_panic) => {
                let Some(backoff) = window.admit(policy) else {
                    return SupervisedExit::RestartsExhausted;
                };
                let total = restarts.fetch_add(1, Ordering::Relaxed) + 1;
                journal().emit(EventKind::WorkerRestart {
                    route: route.to_string(),
                    restarts: total,
                });
                std::thread::sleep(backoff);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_body_runs_once() {
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&RestartPolicy::default(), &restarts, "sup-test", || runs += 1);
        assert_eq!(exit, SupervisedExit::Clean);
        assert_eq!(runs, 1);
        assert_eq!(restarts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn panics_restart_until_body_recovers() {
        let policy = RestartPolicy {
            max_restarts: 5,
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
            ..RestartPolicy::default()
        };
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&policy, &restarts, "sup-test-recovers", || {
            runs += 1;
            if runs < 3 {
                panic!("injected");
            }
        });
        assert_eq!(exit, SupervisedExit::Clean);
        assert_eq!(runs, 3);
        assert_eq!(restarts.load(Ordering::Relaxed), 2);
        // both restarts left a journal trail under this route
        let events = journal().events_for("sup-test-recovers");
        let restarts_logged = events
            .iter()
            .filter(|e| e.kind.name() == "worker_restart")
            .count();
        assert_eq!(restarts_logged, 2);
    }

    #[test]
    fn persistent_panic_exhausts_budget() {
        let policy = RestartPolicy {
            max_restarts: 2,
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
            ..RestartPolicy::default()
        };
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&policy, &restarts, "sup-test", || {
            runs += 1;
            panic!("always");
        });
        assert_eq!(exit, SupervisedExit::RestartsExhausted);
        // budget of 2 restarts = 3 lives total
        assert_eq!(runs, 3);
        assert_eq!(restarts.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn rare_panics_outlive_a_lifetime_budget() {
        // Regression for the lifetime-budget bug: 6 panics spaced
        // wider than the window must all be forgiven even though the
        // lifetime total is triple the per-window budget. Sleeps only
        // ever get longer under load, which keeps the spacing above
        // the window — the test cannot flake toward the old behavior.
        let policy = RestartPolicy {
            max_restarts: 2,
            window: Duration::from_millis(40),
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
        };
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&policy, &restarts, "sup-test-window", || {
            runs += 1;
            if runs <= 6 {
                std::thread::sleep(Duration::from_millis(45));
                panic!("rare");
            }
        });
        assert_eq!(exit, SupervisedExit::Clean);
        assert_eq!(runs, 7, "a rare-panic worker was permanently killed");
        assert_eq!(restarts.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn burst_still_exhausts_within_the_window() {
        // A tight panic loop must still die: window budget of 2, three
        // immediate panics — the third finds the window full.
        let policy = RestartPolicy {
            max_restarts: 2,
            window: Duration::from_secs(60),
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
        };
        let mut window = RestartWindow::new();
        assert!(window.admit(&policy).is_some());
        assert!(window.admit(&policy).is_some());
        assert!(window.admit(&policy).is_none());
    }

    #[test]
    fn window_drains_and_readmits() {
        let policy = RestartPolicy {
            max_restarts: 1,
            window: Duration::from_millis(20),
            backoff: Duration::from_millis(3),
            max_backoff: Duration::from_secs(1),
        };
        let mut window = RestartWindow::new();
        assert_eq!(window.admit(&policy), Some(Duration::from_millis(3)));
        assert!(window.admit(&policy).is_none());
        std::thread::sleep(Duration::from_millis(25));
        // the old entry aged out; backoff restarts from the base since
        // only one restart is inside the window again
        assert_eq!(window.admit(&policy), Some(Duration::from_millis(3)));
    }

    #[test]
    fn none_policy_never_restarts() {
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&RestartPolicy::none(), &restarts, "sup-test", || {
            runs += 1;
            panic!("fatal");
        });
        assert_eq!(exit, SupervisedExit::RestartsExhausted);
        assert_eq!(runs, 1);
        assert_eq!(restarts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy {
            max_restarts: 10,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(65),
            ..RestartPolicy::default()
        };
        assert_eq!(p.backoff_for(1), Duration::from_millis(10));
        assert_eq!(p.backoff_for(2), Duration::from_millis(20));
        assert_eq!(p.backoff_for(3), Duration::from_millis(40));
        assert_eq!(p.backoff_for(4), Duration::from_millis(65));
        assert_eq!(p.backoff_for(40), Duration::from_millis(65));
    }
}
