//! Worker supervision: bounded panic-restart with exponential backoff.
//!
//! A serving worker that panics — a poisoned dependency, a bug in a
//! backend, the fault-injection harness — used to take its whole route
//! down: the in-flight batch's clients got disconnects and the
//! route's worker guard closed the queue for good. Under
//! supervision the panic is caught at the top of the worker loop, the
//! restart counter ([`crate::coordinator::Metrics::restarts`], surfaced
//! as `restarts=` in the `stats` verb) is bumped, and the loop re-enters
//! after a backoff. The in-flight batch is still failed — its response
//! channels unwound with the stack — but everything queued behind it
//! survives to be served by the restarted worker.
//!
//! Restarts are *bounded*: a worker that keeps dying (a deterministic
//! panic on every batch would otherwise spin forever, failing one batch
//! per restart) exhausts its budget and exits, at which point the
//! normal last-worker-guard close-and-drain takes over.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::obs::{journal, EventKind};

/// Restart budget and backoff schedule for one worker thread.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RestartPolicy {
    /// Restarts allowed per worker before it stays down.
    pub max_restarts: u32,
    /// Delay before the first restart; doubles per consecutive restart.
    pub backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
}

impl Default for RestartPolicy {
    fn default() -> Self {
        RestartPolicy {
            max_restarts: 5,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
        }
    }
}

impl RestartPolicy {
    /// Never restart: a panic kills the worker immediately (the
    /// pre-supervision behavior, used where a restart cannot help).
    pub fn none() -> Self {
        RestartPolicy {
            max_restarts: 0,
            ..Self::default()
        }
    }

    /// Backoff before restart number `attempt` (1-based): exponential
    /// doubling from [`RestartPolicy::backoff`], capped at
    /// [`RestartPolicy::max_backoff`].
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        self.backoff
            .saturating_mul(1u32 << shift)
            .min(self.max_backoff)
    }
}

/// How a supervised worker ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupervisedExit {
    /// `body` returned normally (queue closed and drained).
    Clean,
    /// `body` panicked more than `max_restarts` times.
    RestartsExhausted,
}

/// Run one worker "life" repeatedly: `body` returning means clean
/// shutdown; `body` panicking consumes one restart from the budget
/// (recorded in `restarts` and as a `worker_restart` event in the
/// process [`journal`] under `route`), sleeps the backoff, and
/// re-enters.
pub fn supervise(
    policy: &RestartPolicy,
    restarts: &AtomicU64,
    route: &str,
    mut body: impl FnMut(),
) -> SupervisedExit {
    let mut attempts: u32 = 0;
    loop {
        match catch_unwind(AssertUnwindSafe(&mut body)) {
            Ok(()) => return SupervisedExit::Clean,
            Err(_panic) => {
                attempts += 1;
                if attempts > policy.max_restarts {
                    return SupervisedExit::RestartsExhausted;
                }
                let total = restarts.fetch_add(1, Ordering::Relaxed) + 1;
                journal().emit(EventKind::WorkerRestart {
                    route: route.to_string(),
                    restarts: total,
                });
                std::thread::sleep(policy.backoff_for(attempts));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_body_runs_once() {
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&RestartPolicy::default(), &restarts, "sup-test", || runs += 1);
        assert_eq!(exit, SupervisedExit::Clean);
        assert_eq!(runs, 1);
        assert_eq!(restarts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn panics_restart_until_body_recovers() {
        let policy = RestartPolicy {
            max_restarts: 5,
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
        };
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&policy, &restarts, "sup-test-recovers", || {
            runs += 1;
            if runs < 3 {
                panic!("injected");
            }
        });
        assert_eq!(exit, SupervisedExit::Clean);
        assert_eq!(runs, 3);
        assert_eq!(restarts.load(Ordering::Relaxed), 2);
        // both restarts left a journal trail under this route
        let events = journal().events_for("sup-test-recovers");
        let restarts_logged = events
            .iter()
            .filter(|e| e.kind.name() == "worker_restart")
            .count();
        assert_eq!(restarts_logged, 2);
    }

    #[test]
    fn persistent_panic_exhausts_budget() {
        let policy = RestartPolicy {
            max_restarts: 2,
            backoff: Duration::from_micros(50),
            max_backoff: Duration::from_millis(1),
        };
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&policy, &restarts, "sup-test", || {
            runs += 1;
            panic!("always");
        });
        assert_eq!(exit, SupervisedExit::RestartsExhausted);
        // budget of 2 restarts = 3 lives total
        assert_eq!(runs, 3);
        assert_eq!(restarts.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn none_policy_never_restarts() {
        let restarts = AtomicU64::new(0);
        let mut runs = 0;
        let exit = supervise(&RestartPolicy::none(), &restarts, "sup-test", || {
            runs += 1;
            panic!("fatal");
        });
        assert_eq!(exit, SupervisedExit::RestartsExhausted);
        assert_eq!(runs, 1);
        assert_eq!(restarts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy {
            max_restarts: 10,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(65),
        };
        assert_eq!(p.backoff_for(1), Duration::from_millis(10));
        assert_eq!(p.backoff_for(2), Duration::from_millis(20));
        assert_eq!(p.backoff_for(3), Duration::from_millis(40));
        assert_eq!(p.backoff_for(4), Duration::from_millis(65));
        assert_eq!(p.backoff_for(40), Duration::from_millis(65));
    }
}
