//! The coordinator: model registry, per-model worker threads, routing
//! handle, and a line-oriented TCP front end.
//!
//! Request flow: `CoordinatorHandle::infer` routes by model name to the
//! model's queue; the worker thread batches requests
//! ([`crate::coordinator::batcher`]), runs the backend, and answers each
//! request through its completion channel. Metrics are recorded per
//! route.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::backend::{Backend, Scored};
use crate::coordinator::batcher::{collect, BatchPolicy, Collected};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::util::BitVec;

/// A completed inference.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub class: usize,
    pub scores: Vec<i32>,
}

/// Why an inference failed.
#[derive(Clone, Debug, PartialEq)]
pub enum InferError {
    UnknownModel(String),
    WrongWidth { expected: usize, got: usize },
    BackendError(String),
    ShuttingDown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            InferError::WrongWidth { expected, got } => {
                write!(f, "literal width {got}, model expects {expected}")
            }
            InferError::BackendError(e) => write!(f, "backend error: {e}"),
            InferError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for InferError {}

struct Request {
    literals: BitVec,
    enqueued: Instant,
    resp: SyncSender<Result<Prediction, InferError>>,
}

/// Queue message: a request, or an explicit stop sentinel.
///
/// A sentinel (not channel disconnection) drives shutdown: routing
/// handles hold `Sender` clones with arbitrary lifetimes, so the worker
/// cannot rely on `recv()` erroring out.
enum Msg {
    Infer(Request),
    Shutdown,
}

struct Route {
    queue: Sender<Msg>,
    n_literals: usize,
    metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
}

/// The serving coordinator. Register models, then `handle()` for a
/// cloneable routing handle.
pub struct Coordinator {
    routes: HashMap<String, Route>,
}

impl Coordinator {
    pub fn new() -> Self {
        Coordinator {
            routes: HashMap::new(),
        }
    }

    /// Register a model whose backend is `Send` (CPU backends).
    pub fn register(
        &mut self,
        name: impl Into<String>,
        backend: Box<dyn Backend + Send>,
        policy: BatchPolicy,
    ) {
        self.register_with(name, move || Ok(backend as Box<dyn Backend>), policy)
            .expect("infallible factory");
    }

    /// Register a model via a factory executed *inside* the worker
    /// thread — required for PJRT-backed backends, whose handles are
    /// thread-pinned. Blocks until the factory has run; a factory error
    /// is returned here and no route is created.
    pub fn register_with(
        &mut self,
        name: impl Into<String>,
        factory: impl FnOnce() -> anyhow::Result<Box<dyn Backend>> + Send + 'static,
        policy: BatchPolicy,
    ) -> anyhow::Result<()> {
        let name = name.into();
        let metrics = Arc::new(Metrics::new());
        let metrics_worker = Arc::clone(&metrics);
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = channel();
        let (ready_tx, ready_rx) = sync_channel::<anyhow::Result<usize>>(1);
        let worker = std::thread::Builder::new()
            .name(format!("tmi-worker-{name}"))
            .spawn(move || {
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(b.n_literals()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                loop {
                    match collect(&rx, &policy) {
                        Collected::Disconnected => break,
                        Collected::Batch(msgs) => {
                            let mut stop = false;
                            let reqs: Vec<Request> = msgs
                                .into_iter()
                                .filter_map(|m| match m {
                                    Msg::Infer(r) => Some(r),
                                    Msg::Shutdown => {
                                        stop = true;
                                        None
                                    }
                                })
                                .collect();
                            if reqs.is_empty() {
                                if stop {
                                    break;
                                }
                                continue;
                            }
                            metrics_worker.record_batch(reqs.len());
                            let lits: Vec<BitVec> =
                                reqs.iter().map(|r| r.literals.clone()).collect();
                            match backend.infer_batch(&lits) {
                                Ok(scored) => {
                                    for (req, s) in reqs.into_iter().zip(scored) {
                                        let Scored { prediction, scores } = s;
                                        metrics_worker
                                            .completed
                                            .fetch_add(1, Ordering::Relaxed);
                                        metrics_worker
                                            .record_latency(req.enqueued.elapsed());
                                        let _ = req.resp.send(Ok(Prediction {
                                            class: prediction,
                                            scores,
                                        }));
                                    }
                                }
                                Err(e) => {
                                    let msg = e.to_string();
                                    for req in reqs {
                                        metrics_worker
                                            .errors
                                            .fetch_add(1, Ordering::Relaxed);
                                        let _ = req.resp.send(Err(
                                            InferError::BackendError(msg.clone()),
                                        ));
                                    }
                                }
                            }
                            if stop {
                                break;
                            }
                        }
                    }
                }
            })
            .expect("spawning worker thread");
        let n_literals = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died before reporting readiness"))??;
        self.routes.insert(
            name,
            Route {
                queue: tx,
                n_literals,
                metrics,
                worker: Some(worker),
            },
        );
        Ok(())
    }

    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn metrics(&self, model: &str) -> Option<MetricsSnapshot> {
        self.routes.get(model).map(|r| r.metrics.snapshot())
    }

    /// Cloneable request handle (cheap: Arc-backed).
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle {
            routes: Arc::new(
                self.routes
                    .iter()
                    .map(|(name, r)| {
                        (
                            name.clone(),
                            HandleRoute {
                                queue: Mutex::new(r.queue.clone()),
                                n_literals: r.n_literals,
                                metrics: Arc::clone(&r.metrics),
                            },
                        )
                    })
                    .collect(),
            ),
        }
    }

    /// Send stop sentinels and join workers. Requests already queued
    /// before the sentinel are still answered.
    pub fn shutdown(mut self) {
        for route in self.routes.values() {
            let _ = route.queue.send(Msg::Shutdown);
        }
        for (_, mut route) in self.routes.drain() {
            drop(route.queue);
            if let Some(w) = route.worker.take() {
                let _ = w.join();
            }
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

struct HandleRoute {
    queue: Mutex<Sender<Msg>>,
    n_literals: usize,
    metrics: Arc<Metrics>,
}

/// Cloneable, thread-safe routing handle.
#[derive(Clone)]
pub struct CoordinatorHandle {
    routes: Arc<HashMap<String, HandleRoute>>,
}

impl CoordinatorHandle {
    /// Blocking inference against a registered model.
    pub fn infer(&self, model: &str, literals: BitVec) -> Result<Prediction, InferError> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| InferError::UnknownModel(model.to_string()))?;
        if literals.len() != route.n_literals {
            return Err(InferError::WrongWidth {
                expected: route.n_literals,
                got: literals.len(),
            });
        }
        route.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request {
            literals,
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        route
            .queue
            .lock()
            .expect("queue lock poisoned")
            .send(Msg::Infer(req))
            .map_err(|_| InferError::ShuttingDown)?;
        resp_rx.recv().map_err(|_| InferError::ShuttingDown)?
    }

    /// Convenience: infer from a raw feature row (builds `[x, ¬x]`).
    pub fn infer_features(
        &self,
        model: &str,
        features: &[bool],
    ) -> Result<Prediction, InferError> {
        let lits = crate::data::Dataset::literals_from_bools(features);
        self.infer(model, lits)
    }
}

/// Line protocol for the TCP front end:
///
/// ```text
/// -> <model> <01-bitstring of raw features>\n
/// <- ok <class> <score_0> <score_1> ...\n   |   err <message>\n
/// ```
pub fn serve_tcp(
    listener: TcpListener,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let h = handle.clone();
                let stop_conn = Arc::clone(&stop);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, h, stop_conn);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    // Periodic read timeout so idle connections observe shutdown.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        let n = loop {
            match reader.read_line(&mut line) {
                Ok(n) => break n,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    // keep any partial line already buffered and retry
                }
                Err(e) => return Err(e),
            }
        };
        if n == 0 {
            return Ok(()); // client closed
        }
        let reply = match parse_request_line(&line) {
            Ok((model, features)) => match handle.infer_features(model, &features) {
                Ok(p) => {
                    let scores: Vec<String> =
                        p.scores.iter().map(|s| s.to_string()).collect();
                    format!("ok {} {}\n", p.class, scores.join(" "))
                }
                Err(e) => format!("err {e}\n"),
            },
            Err(e) => format!("err {e}\n"),
        };
        stream.write_all(reply.as_bytes())?;
    }
}

fn parse_request_line(line: &str) -> Result<(&str, Vec<bool>), String> {
    let line = line.trim();
    let (model, bits) = line
        .split_once(' ')
        .ok_or_else(|| "expected '<model> <bits>'".to_string())?;
    let features: Result<Vec<bool>, String> = bits
        .chars()
        .map(|c| match c {
            '0' => Ok(false),
            '1' => Ok(true),
            other => Err(format!("bad bit '{other}'")),
        })
        .collect();
    Ok((model, features?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::eval;
    use crate::tm::params::TMParams;
    use crate::tm::trainer::Trainer;
    use crate::util::Rng;

    fn toy_backend() -> Box<dyn Backend + Send> {
        let params = TMParams::new(2, 10, 8);
        let mut tr = Trainer::new(params, eval::Backend::Indexed);
        let mut rng = Rng::new(3);
        let samples: Vec<(BitVec, usize)> = (0..200)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..8).map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..5 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        Box::new(CpuBackend::new(tr.tm, eval::Backend::Indexed))
    }

    fn class0_features() -> Vec<bool> {
        let mut f = vec![false; 8];
        f[0] = true;
        f
    }

    #[test]
    fn register_infer_shutdown() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        let p = h.infer_features("toy", &class0_features()).unwrap();
        assert_eq!(p.class, 0);
        assert_eq!(p.scores.len(), 2);
        let m = coord.metrics("toy").unwrap();
        assert_eq!(m.requests, 1);
        assert_eq!(m.completed, 1);
        coord.shutdown();
    }

    #[test]
    fn unknown_model_and_wrong_width() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        assert!(matches!(
            h.infer_features("nope", &class0_features()),
            Err(InferError::UnknownModel(_))
        ));
        assert!(matches!(
            h.infer("toy", BitVec::zeros(4)),
            Err(InferError::WrongWidth { expected: 16, got: 4 })
        ));
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let mut coord = Coordinator::new();
        coord.register(
            "toy",
            toy_backend(),
            BatchPolicy {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(2),
            },
        );
        let h = coord.handle();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        let p = h.infer_features("toy", &class0_features()).unwrap();
                        assert_eq!(p.class, 0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let m = coord.metrics("toy").unwrap();
        assert_eq!(m.completed, 200);
        assert!(m.batches <= 200);
        coord.shutdown();
    }

    /// Backend that fails every batch — exercises the error path.
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn infer_batch(
            &mut self,
            _batch: &[BitVec],
        ) -> anyhow::Result<Vec<crate::coordinator::backend::Scored>> {
            anyhow::bail!("injected backend failure")
        }
        fn n_literals(&self) -> usize {
            4
        }
        fn name(&self) -> String {
            "failing".into()
        }
    }

    #[test]
    fn backend_errors_propagate_and_are_counted() {
        let mut coord = Coordinator::new();
        coord.register("bad", Box::new(FailingBackend), BatchPolicy::default());
        let h = coord.handle();
        for _ in 0..3 {
            match h.infer("bad", BitVec::zeros(4)) {
                Err(InferError::BackendError(msg)) => {
                    assert!(msg.contains("injected"), "{msg}")
                }
                other => panic!("expected backend error, got {other:?}"),
            }
        }
        let m = coord.metrics("bad").unwrap();
        assert_eq!(m.errors, 3);
        assert_eq!(m.completed, 0);
        // coordinator still serves other routes and shuts down cleanly
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        assert!(h.infer_features("toy", &class0_features()).is_ok());
        coord.shutdown();
    }

    #[test]
    fn failing_factory_creates_no_route() {
        let mut coord = Coordinator::new();
        let res = coord.register_with(
            "broken",
            || anyhow::bail!("cannot construct"),
            BatchPolicy::default(),
        );
        assert!(res.is_err());
        assert!(coord.models().is_empty());
        let h = coord.handle();
        assert!(matches!(
            h.infer("broken", BitVec::zeros(4)),
            Err(InferError::UnknownModel(_))
        ));
        coord.shutdown();
    }

    #[test]
    fn infer_after_shutdown_reports_shutting_down() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        coord.shutdown();
        // worker is gone; the stale handle must fail, not hang
        let r = h.infer_features("toy", &class0_features());
        assert!(matches!(r, Err(InferError::ShuttingDown)), "{r:?}");
    }

    #[test]
    fn parse_request_line_cases() {
        let (m, f) = parse_request_line("toy 1010\n").unwrap();
        assert_eq!(m, "toy");
        assert_eq!(f, vec![true, false, true, false]);
        assert!(parse_request_line("justmodel").is_err());
        assert!(parse_request_line("toy 10x1").is_err());
    }

    #[test]
    fn tcp_round_trip() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"toy 10000000\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok 0 "), "reply: {reply}");

        conn.write_all(b"missing 1\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err "), "reply: {reply}");

        stop.store(true, Ordering::Relaxed);
        drop(conn);
        drop(reader); // the try_clone half also holds the socket open
        server.join().unwrap().unwrap();
        coord.shutdown();
    }
}
