//! The coordinator: versioned hot-swap model registry, bounded
//! per-model request queues with admission control, N batcher workers
//! per route, a routing handle, and a line-oriented TCP front end
//! (wire protocol reference: `docs/PROTOCOL.md`).
//!
//! Request flow: `CoordinatorHandle::infer` routes by model name and
//! **admits** the request into the route's [`BoundedQueue`] — or sheds
//! it with [`InferError::Overloaded`] when the queue is full. Batcher
//! workers ([`crate::coordinator::batcher`]) collect batches from the
//! shared queue, score them, and answer each request through its
//! completion channel. Metrics are recorded per route.
//!
//! Routes come in two kinds:
//!
//! * **Snapshot routes** ([`Coordinator::register_model`]) serve an
//!   immutable [`ModelSnapshot`] behind an atomically swappable `Arc`.
//!   Any number of workers share the snapshot (each holds private
//!   scratch), and [`Coordinator::swap`] /
//!   [`CoordinatorHandle::swap`] replaces the serving version under
//!   live traffic: each batch is scored wholly by one published
//!   version, so no request is ever dropped or torn by a swap.
//! * **Factory routes** ([`Coordinator::register_with`]) build a
//!   mutable [`Backend`] inside a single worker thread — required for
//!   PJRT-backed XLA backends, whose handles are thread-pinned. These
//!   routes get the same bounded queue and shedding but no hot swap.
//!
//! Shutdown is close-then-drain: every request admitted before
//! [`Coordinator::shutdown`] is still answered. If a route's last
//! worker dies abnormally, its queue is closed *and drained* so queued
//! clients unblock with [`InferError::ShuttingDown`] instead of
//! hanging.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::{Arc, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::backend::{Backend, Scored};
use crate::coordinator::batcher::{collect, BatchPolicy, Collected};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::online::{FeedbackError, FeedbackSender};
use crate::coordinator::queue::{BoundedQueue, PushError};
use crate::coordinator::supervisor::{supervise, RestartPolicy, RestartWindow};
use crate::engine::{argmax, ModelSnapshot};
use crate::obs::prometheus::PromWriter;
use crate::obs::{self, journal, EventKind, Stage};
use crate::util::BitVec;

/// A completed inference.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    /// The argmax class.
    pub class: usize,
    /// Per-class vote sums.
    pub scores: Vec<i32>,
}

/// Why an inference failed.
#[derive(Clone, Debug, PartialEq)]
pub enum InferError {
    /// No route with that name.
    UnknownModel(String),
    /// Literal width does not match the model.
    WrongWidth {
        /// Literal width the model expects.
        expected: usize,
        /// Literal width the request carried.
        got: usize,
    },
    /// Shed at admission: the route's queue is full.
    Overloaded,
    /// The backend failed or its worker panicked.
    BackendError(String),
    /// The server is draining; no new requests accepted.
    ShuttingDown,
}

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InferError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            InferError::WrongWidth { expected, got } => {
                write!(f, "literal width {got}, model expects {expected}")
            }
            // the TCP reply is `err {self}` — keep the leading token
            // machine-matchable as `err overloaded`
            InferError::Overloaded => write!(f, "overloaded: request queue full"),
            InferError::BackendError(e) => write!(f, "backend error: {e}"),
            InferError::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

impl std::error::Error for InferError {}

/// Why a hot swap was refused.
#[derive(Clone, Debug, PartialEq)]
pub enum SwapError {
    /// No route with that name.
    UnknownModel(String),
    /// Factory (e.g. XLA) routes serve a thread-pinned backend, not a
    /// swappable snapshot.
    Unsupported(String),
    /// Snapshot shape does not match the serving route.
    WrongWidth {
        /// Literal width the model expects.
        expected: usize,
        /// Literal width the request carried.
        got: usize,
    },
}

impl std::fmt::Display for SwapError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SwapError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            SwapError::Unsupported(m) => {
                write!(f, "route '{m}' serves a factory backend; hot swap needs a snapshot route")
            }
            SwapError::WrongWidth { expected, got } => {
                write!(f, "snapshot literal width {got}, route serves {expected}")
            }
        }
    }
}

impl std::error::Error for SwapError {}

struct Request {
    literals: BitVec,
    enqueued: Instant,
    /// Process-unique trace id, assigned at admission
    /// ([`crate::obs::next_trace_id`]). Correlates the request across
    /// stage histograms and journal events.
    trace: u64,
    /// `Some` until the request is answered. `None` means a reply was
    /// sent (or the request was deliberately defused, e.g. a shed that
    /// is already counted); a `Request` dropped while still `Some` was
    /// admitted but never answered, and [`Drop`] books it as an error
    /// so `requests == completed + shed + errors` holds on every path —
    /// including worker panics and shutdown drains.
    resp: Option<SyncSender<Result<Prediction, InferError>>>,
    metrics: Arc<Metrics>,
}

impl Request {
    /// Answer the request (consumes it; the `Drop` accounting sees a
    /// defused channel and stays silent). Counter updates — completed
    /// vs errors — stay at the call sites, which know the outcome.
    fn respond(mut self, result: Result<Prediction, InferError>) {
        if let Some(tx) = self.resp.take() {
            let _ = tx.send(result);
        }
    }
}

impl Drop for Request {
    fn drop(&mut self) {
        // still armed: admitted, never answered — a panicked batch, a
        // shutdown drain, or a closed-queue rejection. The waiting
        // client unblocks with ShuttingDown when the channel drops;
        // the counter invariant needs the error booked here.
        if self.resp.is_some() {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Per-route sizing: batching policy, worker count, queue bound,
/// restart budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RouteConfig {
    /// Batch assembly policy for the route's workers.
    pub policy: BatchPolicy,
    /// Batcher workers sharing the route's queue (snapshot routes only;
    /// factory routes are pinned to 1 worker).
    pub workers: usize,
    /// Admission bound: requests beyond this are shed with
    /// [`InferError::Overloaded`].
    pub queue_cap: usize,
    /// Per-worker panic-restart budget and backoff
    /// ([`crate::coordinator::supervisor`]). Restarts performed are
    /// surfaced as `restarts=` in the `stats` verb.
    pub restarts: RestartPolicy,
}

impl Default for RouteConfig {
    fn default() -> Self {
        RouteConfig {
            policy: BatchPolicy::default(),
            workers: 1,
            queue_cap: 1024,
            restarts: RestartPolicy::default(),
        }
    }
}

/// Test-only fault injection: arm a number of worker panics against one
/// route and the next batches collected by that route's snapshot
/// workers panic mid-swap (after dequeue, before scoring). Hidden from
/// docs; used by the fault harness (`tests/registry_faults.rs`) and the
/// in-module supervision tests to exercise restart paths that healthy
/// code cannot reach. State is process-global, but targeting by route
/// name keeps concurrently running tests out of each other's way.
#[doc(hidden)]
pub mod fault {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Mutex, PoisonError};

    static ROUTE: Mutex<String> = Mutex::new(String::new());
    static BUDGET: AtomicU64 = AtomicU64::new(0);

    /// Arm `n` injected panics against route `route`'s workers.
    pub fn arm_worker_panics(route: &str, n: u64) {
        *ROUTE.lock().unwrap_or_else(PoisonError::into_inner) = route.to_string();
        BUDGET.store(n, Ordering::SeqCst);
    }

    /// Consume one armed panic if the calling worker thread belongs to
    /// the armed route (worker threads are named `tmi-worker-<route>-<n>`).
    pub(crate) fn take_worker_panic() -> bool {
        if BUDGET.load(Ordering::SeqCst) == 0 {
            return false;
        }
        let armed = format!(
            "{}-",
            ROUTE.lock().unwrap_or_else(PoisonError::into_inner)
        );
        let on_route = std::thread::current()
            .name()
            .and_then(|t| t.strip_prefix("tmi-worker-"))
            .is_some_and(|rest| rest.starts_with(&armed));
        on_route
            && BUDGET
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                .is_ok()
    }
}

/// Connections refused at the [`ServeOptions::max_conns`] cap since
/// process start. Process-wide (the accept loop rejects before any
/// route is known), surfaced as `conn_rejected=` on every `stats` line
/// and as `tmi_conn_rejected_total` — without it a cap-induced
/// brownout is invisible server-side and looks like client error.
static CONN_REJECTED: AtomicU64 = AtomicU64::new(0);

/// Book one connection-cap rejection (the `err busy` accept path —
/// also the cluster node's, [`crate::cluster::node::serve_node`]).
pub(crate) fn note_conn_rejected() {
    CONN_REJECTED.fetch_add(1, Ordering::Relaxed);
}

/// Process-wide count of connections answered `err busy` at the
/// connection cap.
pub fn conn_rejected_total() -> u64 {
    CONN_REJECTED.load(Ordering::Relaxed)
}

/// The atomically swappable serving version of a snapshot route.
struct SwapCell {
    snap: RwLock<Arc<ModelSnapshot>>,
    /// Route-level swap counter (0 = still serving the registration
    /// snapshot). Snapshot `version`s are publisher-scoped — two
    /// trainers can both publish a "v1" — so deploy checks watch this
    /// monotonic per-route generation to confirm a swap landed.
    swaps: AtomicU64,
}

impl SwapCell {
    fn new(snap: Arc<ModelSnapshot>) -> Self {
        SwapCell {
            snap: RwLock::new(snap),
            swaps: AtomicU64::new(0),
        }
    }

    /// Reads (and writes, below) *recover* from lock poisoning instead
    /// of propagating it: the cell holds a single `Arc` that is only
    /// ever wholly replaced, so its value is consistent at every
    /// unlock and a poisoned lock is safe to keep using. Panicking
    /// here would cascade one dead thread into every worker and
    /// `stats` reader sharing the route.
    fn load(&self) -> Arc<ModelSnapshot> {
        Arc::clone(&self.snap.read().unwrap_or_else(PoisonError::into_inner))
    }

    fn generation(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Install `snap`, returning the retired version number.
    fn store(&self, snap: Arc<ModelSnapshot>) -> u64 {
        let mut g = self.snap.write().unwrap_or_else(PoisonError::into_inner);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        std::mem::replace(&mut *g, snap).version()
    }
}

/// Closes (and on abnormal death, drains) the route queue when the
/// route's *last* worker exits — panic-safe via `Drop`, so a worker
/// that dies mid-batch cannot strand queued clients forever.
struct WorkerGuard {
    queue: Arc<BoundedQueue<Request>>,
    alive: Arc<AtomicUsize>,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        if self.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            // no worker will ever pop again: dropping queued requests
            // drops their response channels, unblocking the clients
            self.queue.close_and_drain();
        }
    }
}

struct Route {
    queue: Arc<BoundedQueue<Request>>,
    n_literals: usize,
    metrics: Arc<Metrics>,
    swap: Option<Arc<SwapCell>>,
    /// Online-learner submission handle, when one is attached
    /// ([`Coordinator::attach_learner`]) — the `feedback`/`train`
    /// verbs route labeled examples through it.
    feedback: Option<FeedbackSender>,
    workers: Vec<JoinHandle<()>>,
}

/// Point-in-time route statistics: counters + the serving snapshot
/// version (snapshot routes only).
#[derive(Clone, Debug, PartialEq)]
pub struct RouteStats {
    /// Counter/latency snapshot for the route.
    pub metrics: MetricsSnapshot,
    /// Publisher-scoped version of the serving snapshot.
    pub version: Option<u64>,
    /// Swaps installed on this route since registration (monotonic).
    pub generation: Option<u64>,
    /// CRC-32 state digest of the serving snapshot's machine
    /// ([`ModelSnapshot::state_digest`]) — the crash-recovery equality
    /// witness: a restarted route that WAL-replayed to the exact
    /// pre-crash machine reports the same digest.
    pub digest: Option<u32>,
}

/// The serving coordinator. Register models, then `handle()` for a
/// cloneable routing handle.
pub struct Coordinator {
    routes: HashMap<String, Route>,
}

impl Coordinator {
    /// Empty coordinator with no routes.
    pub fn new() -> Self {
        Coordinator {
            routes: HashMap::new(),
        }
    }

    /// Register a model whose backend is `Send` (CPU backends). Single
    /// worker, default queue bound; for hot swap and scale-out use
    /// [`Coordinator::register_model`]. The backend is one-shot — if
    /// its worker panics, the restart attempt finds nothing to rebuild
    /// from and the route fails closed (register via
    /// [`Coordinator::register_with`] with a real factory to make a
    /// factory route restartable).
    pub fn register(
        &mut self,
        name: impl Into<String>,
        backend: Box<dyn Backend + Send>,
        policy: BatchPolicy,
    ) {
        let slot = std::sync::Mutex::new(Some(backend));
        self.register_with(
            name,
            move || {
                slot.lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .take()
                    .map(|b| b as Box<dyn Backend>)
                    .ok_or_else(|| anyhow::anyhow!("one-shot backend already consumed"))
            },
            policy,
        )
        .expect("first factory call is infallible");
    }

    /// Register a model via a factory executed *inside* the worker
    /// thread — required for PJRT-backed backends, whose handles are
    /// thread-pinned. Blocks until the factory has run; a factory error
    /// is returned here and no route is created. If the worker later
    /// panics, the supervisor re-runs the factory to rebuild the
    /// backend (bounded by [`RouteConfig::restarts`]); a factory that
    /// fails on re-run ends the route.
    pub fn register_with(
        &mut self,
        name: impl Into<String>,
        factory: impl FnMut() -> anyhow::Result<Box<dyn Backend>> + Send + 'static,
        policy: BatchPolicy,
    ) -> anyhow::Result<()> {
        self.register_with_config(
            name,
            factory,
            RouteConfig {
                policy,
                ..RouteConfig::default()
            },
        )
    }

    /// [`Coordinator::register_with`] with explicit queue sizing.
    /// `cfg.workers` is ignored (factory backends are mutable and
    /// thread-pinned: exactly one worker).
    pub fn register_with_config(
        &mut self,
        name: impl Into<String>,
        mut factory: impl FnMut() -> anyhow::Result<Box<dyn Backend>> + Send + 'static,
        cfg: RouteConfig,
    ) -> anyhow::Result<()> {
        let name = name.into();
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let alive = Arc::new(AtomicUsize::new(1));
        let guard = WorkerGuard {
            queue: Arc::clone(&queue),
            alive,
        };
        let metrics_worker = Arc::clone(&metrics);
        let queue_worker = Arc::clone(&queue);
        let policy = cfg.policy;
        let restarts = cfg.restarts;
        let route_name = name.clone();
        let (ready_tx, ready_rx) = sync_channel::<anyhow::Result<usize>>(1);
        let worker = std::thread::Builder::new()
            .name(format!("tmi-worker-{name}"))
            .spawn(move || {
                let _guard = guard;
                let mut backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(b.n_literals()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut window = RestartWindow::new();
                loop {
                    match collect(&queue_worker, &policy) {
                        Collected::Disconnected => break,
                        Collected::Batch {
                            items: reqs,
                            assembled,
                        } => {
                            if obs::enabled() {
                                metrics_worker.record_stage(Stage::Batch, assembled);
                            }
                            // The panicking batch fails (its response
                            // channels unwind), but the route survives:
                            // rebuild the backend — the old one may be
                            // torn mid-mutation — and keep draining.
                            let survived = catch_unwind(AssertUnwindSafe(|| {
                                answer_with_backend(backend.as_mut(), reqs, &metrics_worker);
                            }))
                            .is_ok();
                            if survived {
                                continue;
                            }
                            // same sliding-window budget as supervise():
                            // rare panics age out instead of slowly
                            // consuming a lifetime allowance
                            let Some(backoff) = window.admit(&restarts) else {
                                break;
                            };
                            std::thread::sleep(backoff);
                            match catch_unwind(AssertUnwindSafe(&mut factory)) {
                                Ok(Ok(b)) => {
                                    backend = b;
                                    let total = metrics_worker
                                        .restarts
                                        .fetch_add(1, Ordering::Relaxed)
                                        + 1;
                                    journal().emit(EventKind::WorkerRestart {
                                        route: route_name.clone(),
                                        restarts: total,
                                    });
                                }
                                // factory failed or panicked: no backend
                                // to serve with — fail the route closed
                                _ => break,
                            }
                        }
                    }
                }
            })
            .expect("spawning worker thread");
        let n_literals = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died before reporting readiness"))??;
        self.routes.insert(
            name,
            Route {
                queue,
                n_literals,
                metrics,
                swap: None,
                feedback: None,
                workers: vec![worker],
            },
        );
        Ok(())
    }

    /// Register a hot-swappable snapshot route: `cfg.workers` batcher
    /// threads share one bounded queue and score against the published
    /// [`ModelSnapshot`] (each worker holds private scratch; the
    /// snapshot itself is immutable and shared).
    pub fn register_model(
        &mut self,
        name: impl Into<String>,
        snapshot: Arc<ModelSnapshot>,
        cfg: RouteConfig,
    ) {
        let name = name.into();
        let metrics = Arc::new(Metrics::new());
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let cell = Arc::new(SwapCell::new(Arc::clone(&snapshot)));
        let n_workers = cfg.workers.max(1);
        let alive = Arc::new(AtomicUsize::new(n_workers));
        let workers = (0..n_workers)
            .map(|w| {
                let guard = WorkerGuard {
                    queue: Arc::clone(&queue),
                    alive: Arc::clone(&alive),
                };
                let queue = Arc::clone(&queue);
                let cell = Arc::clone(&cell);
                let metrics = Arc::clone(&metrics);
                let policy = cfg.policy;
                let restarts = cfg.restarts;
                let route_name = name.clone();
                std::thread::Builder::new()
                    .name(format!("tmi-worker-{name}-{w}"))
                    .spawn(move || {
                        let _guard = guard;
                        // snapshot workers are stateless across lives
                        // (each re-entry reloads the cell and rebuilds
                        // scratch), so supervised restart is always safe
                        let _ = supervise(&restarts, &metrics.restarts, &route_name, || {
                            snapshot_worker(&queue, &cell, &metrics, &policy);
                        });
                    })
                    .expect("spawning worker thread")
            })
            .collect();
        self.routes.insert(
            name,
            Route {
                queue,
                n_literals: snapshot.n_literals(),
                metrics,
                swap: Some(cell),
                feedback: None,
                workers,
            },
        );
    }

    /// Attach an online-learner submission handle to route `name`
    /// ([`crate::coordinator::online::OnlineLearner::sender`]): the
    /// `feedback`/`train` protocol verbs start routing labeled
    /// examples through it. Call before [`Coordinator::handle`] —
    /// existing handles keep their route table. The learner's metrics
    /// should be the route's own (pass
    /// [`Coordinator::route_metrics`]'s Arc when spawning) so its
    /// counters land in the same `stats` line.
    pub fn attach_learner(
        &mut self,
        name: &str,
        sender: FeedbackSender,
    ) -> Result<(), FeedbackError> {
        let route = self
            .routes
            .get_mut(name)
            .ok_or_else(|| FeedbackError::UnknownModel(name.to_string()))?;
        route.feedback = Some(sender);
        Ok(())
    }

    /// The route's live metrics handle — spawn the online learner with
    /// this Arc so feedback counters share the route's `stats` line.
    pub fn route_metrics(&self, name: &str) -> Option<Arc<Metrics>> {
        self.routes.get(name).map(|r| Arc::clone(&r.metrics))
    }

    /// Atomically replace the serving snapshot of model `name`,
    /// returning the retired version. In-flight and queued requests are
    /// each scored by exactly one published version (whichever their
    /// worker holds for that batch) — never dropped, never torn.
    pub fn swap(&self, name: &str, snapshot: Arc<ModelSnapshot>) -> Result<u64, SwapError> {
        let route = self
            .routes
            .get(name)
            .ok_or_else(|| SwapError::UnknownModel(name.to_string()))?;
        swap_route(name, route.n_literals, route.swap.as_ref(), snapshot)
    }

    /// Names of every registered route.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().cloned().collect();
        names.sort();
        names
    }

    /// Metrics snapshot for `model`, if registered.
    pub fn metrics(&self, model: &str) -> Option<MetricsSnapshot> {
        self.routes
            .get(model)
            .map(|r| snapshot_with_depth(&r.metrics, &r.queue))
    }

    /// Full route statistics (metrics + serving version/generation).
    pub fn stats(&self, model: &str) -> Option<RouteStats> {
        self.routes
            .get(model)
            .map(|r| route_stats(&r.metrics, &r.queue, r.swap.as_ref()))
    }

    /// Cloneable request handle (cheap: Arc-backed).
    pub fn handle(&self) -> CoordinatorHandle {
        CoordinatorHandle {
            routes: Arc::new(
                self.routes
                    .iter()
                    .map(|(name, r)| {
                        (
                            name.clone(),
                            HandleRoute {
                                queue: Arc::clone(&r.queue),
                                n_literals: r.n_literals,
                                metrics: Arc::clone(&r.metrics),
                                swap: r.swap.as_ref().map(Arc::clone),
                                feedback: r.feedback.clone(),
                            },
                        )
                    })
                    .collect(),
            ),
        }
    }

    /// Close every route's queue and join the workers. Requests
    /// admitted before the close are still answered (close-then-drain);
    /// later pushes fail with [`InferError::ShuttingDown`].
    pub fn shutdown(mut self) {
        for route in self.routes.values() {
            route.queue.close();
        }
        for (_, mut route) in self.routes.drain() {
            for w in route.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

/// The route's metrics snapshot with the live queue-depth gauge
/// filled in ([`Metrics`] does not own the queue).
fn snapshot_with_depth(metrics: &Metrics, queue: &BoundedQueue<Request>) -> MetricsSnapshot {
    let mut snap = metrics.snapshot();
    snap.queue_depth = queue.len() as u64;
    // process-wide (the cap rejects before routing): every route's
    // snapshot reports the same server total
    snap.conn_rejected = conn_rejected_total();
    snap
}

/// Shared by [`Coordinator::stats`] and [`CoordinatorHandle::stats`].
fn route_stats(
    metrics: &Metrics,
    queue: &BoundedQueue<Request>,
    swap: Option<&Arc<SwapCell>>,
) -> RouteStats {
    RouteStats {
        metrics: snapshot_with_depth(metrics, queue),
        version: swap.map(|c| c.load().version()),
        generation: swap.map(|c| c.generation()),
        digest: swap.map(|c| c.load().state_digest()),
    }
}

/// Shared by [`Coordinator::swap`] and [`CoordinatorHandle::swap`]:
/// validate the route supports swapping and the widths agree, then
/// install the snapshot (journaled as a `swap` event).
fn swap_route(
    name: &str,
    n_literals: usize,
    cell: Option<&Arc<SwapCell>>,
    snapshot: Arc<ModelSnapshot>,
) -> Result<u64, SwapError> {
    let cell = cell.ok_or_else(|| SwapError::Unsupported(name.to_string()))?;
    if snapshot.n_literals() != n_literals {
        return Err(SwapError::WrongWidth {
            expected: n_literals,
            got: snapshot.n_literals(),
        });
    }
    let version = snapshot.version();
    let retired = cell.store(snapshot);
    journal().emit(EventKind::SnapshotSwap {
        route: name.to_string(),
        version,
        generation: cell.generation(),
    });
    Ok(retired)
}

/// One collect-score-respond round for a mutable factory backend.
fn answer_with_backend(backend: &mut dyn Backend, reqs: Vec<Request>, metrics: &Metrics) {
    metrics.record_batch(reqs.len());
    let obs_on = obs::enabled();
    if obs_on {
        for req in &reqs {
            metrics.record_stage(Stage::Queue, req.enqueued.elapsed());
        }
    }
    let lits: Vec<BitVec> = reqs.iter().map(|r| r.literals.clone()).collect();
    let t_score = if obs_on { Some(Instant::now()) } else { None };
    let result = backend.infer_batch(&lits);
    if let Some(t0) = t_score {
        // factory backends score whole batches; one Score sample per
        // batch is the honest granularity
        metrics.record_stage(Stage::Score, t0.elapsed());
    }
    match result {
        Ok(scored) => {
            // a short `scored` leaves the tail of `reqs` unanswered;
            // their Drop accounting books them as errors
            for (req, s) in reqs.into_iter().zip(scored) {
                let Scored { prediction, scores } = s;
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.record_latency(req.enqueued.elapsed());
                req.respond(Ok(Prediction {
                    class: prediction,
                    scores,
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for req in reqs {
                metrics.errors.fetch_add(1, Ordering::Relaxed);
                req.respond(Err(InferError::BackendError(msg.clone())));
            }
        }
    }
}

/// The snapshot-route worker loop: collect a batch, pick up the latest
/// published snapshot (rebuilding scratch only when the version
/// changed), score the whole batch against that one version, respond.
fn snapshot_worker(
    queue: &BoundedQueue<Request>,
    cell: &SwapCell,
    metrics: &Metrics,
    policy: &BatchPolicy,
) {
    let mut snap = cell.load();
    let mut scratch = snap.make_scratch();
    let mut out: Vec<i32> = Vec::new();
    loop {
        match collect(queue, policy) {
            Collected::Disconnected => break,
            Collected::Batch {
                items: reqs,
                assembled,
            } => {
                if fault::take_worker_panic() {
                    // injected mid-swap fault: the collected batch's
                    // response channels drop in the unwind (those
                    // clients see ShuttingDown); queued requests
                    // survive to the restarted worker
                    panic!("injected fault: worker panic mid-swap");
                }
                let cur = cell.load();
                if !Arc::ptr_eq(&cur, &snap) {
                    scratch = cur.make_scratch();
                    snap = cur;
                }
                metrics.record_batch(reqs.len());
                let obs_on = obs::enabled();
                if obs_on {
                    metrics.record_stage(Stage::Batch, assembled);
                }
                let m = snap.classes();
                out.clear();
                out.resize(m, 0);
                for req in reqs {
                    if obs_on {
                        metrics.record_stage(Stage::Queue, req.enqueued.elapsed());
                    }
                    let t_score = if obs_on { Some(Instant::now()) } else { None };
                    // engine resolution is per request: a batch mixes
                    // independent clients, so a batch-wide probe could
                    // route a non-complement request down the sparse walk
                    snap.scores_into(&mut scratch, &req.literals, &mut out);
                    if let Some(t0) = t_score {
                        metrics.record_stage(Stage::Score, t0.elapsed());
                    }
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics.record_latency(req.enqueued.elapsed());
                    req.respond(Ok(Prediction {
                        class: argmax(&out),
                        scores: out.clone(),
                    }));
                }
                // flush the engine's probe counters batch-wise: plain
                // adds on the hot path, a handful of relaxed
                // fetch_adds here
                metrics.apply_probes(&scratch.take_probes());
            }
        }
    }
}

struct HandleRoute {
    queue: Arc<BoundedQueue<Request>>,
    n_literals: usize,
    metrics: Arc<Metrics>,
    swap: Option<Arc<SwapCell>>,
    feedback: Option<FeedbackSender>,
}

/// Cloneable, thread-safe routing handle.
#[derive(Clone)]
pub struct CoordinatorHandle {
    routes: Arc<HashMap<String, HandleRoute>>,
}

impl CoordinatorHandle {
    /// Blocking inference against a registered model. Sheds with
    /// [`InferError::Overloaded`] when the route's queue is full.
    pub fn infer(&self, model: &str, literals: BitVec) -> Result<Prediction, InferError> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| InferError::UnknownModel(model.to_string()))?;
        if literals.len() != route.n_literals {
            return Err(InferError::WrongWidth {
                expected: route.n_literals,
                got: literals.len(),
            });
        }
        route.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = sync_channel(1);
        let req = Request {
            literals,
            enqueued: Instant::now(),
            trace: obs::next_trace_id(),
            resp: Some(resp_tx),
            metrics: Arc::clone(&route.metrics),
        };
        match route.queue.try_push(req) {
            Ok(()) => {
                // a successful admission after shedding closes the
                // episode — bracketed in the journal
                if let Some(shed_total) = route.metrics.note_admitted() {
                    journal().emit(EventKind::ShedEnd {
                        route: model.to_string(),
                        shed_total,
                    });
                }
            }
            Err(PushError::Full(mut req)) => {
                // defuse before dropping: a shed is booked as `shed`,
                // not as an unanswered-request error
                req.resp = None;
                let trace = req.trace;
                drop(req);
                if route.metrics.note_shed() {
                    journal().emit(EventKind::ShedStart {
                        route: model.to_string(),
                        trace,
                    });
                }
                return Err(InferError::Overloaded);
            }
            // admitted (counted) but the route is gone: the armed
            // Drop books the error so the counters still balance
            Err(PushError::Closed(_req)) => return Err(InferError::ShuttingDown),
        }
        resp_rx.recv().map_err(|_| InferError::ShuttingDown)?
    }

    /// Convenience: infer from a raw feature row (builds `[x, ¬x]`).
    pub fn infer_features(
        &self,
        model: &str,
        features: &[bool],
    ) -> Result<Prediction, InferError> {
        let lits = crate::data::Dataset::literals_from_bools(features);
        self.infer(model, lits)
    }

    /// Submit one labeled example to `model`'s online learner
    /// (`feedback` protocol verb): blocks until the learner has
    /// WAL-logged and applied it through the O(1) clause-index update
    /// path, in arrival order. Errors with
    /// [`FeedbackError::Unsupported`] on routes without a learner and
    /// sheds with [`FeedbackError::Overloaded`] when the feedback
    /// queue is full.
    pub fn feedback(&self, model: &str, label: usize, literals: BitVec) -> Result<(), FeedbackError> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| FeedbackError::UnknownModel(model.to_string()))?;
        let sender = route
            .feedback
            .as_ref()
            .ok_or_else(|| FeedbackError::Unsupported(model.to_string()))?;
        sender.submit(label, literals)
    }

    /// [`CoordinatorHandle::feedback`] from a raw feature row
    /// (builds `[x, ¬x]` like [`CoordinatorHandle::infer_features`]).
    pub fn feedback_features(
        &self,
        model: &str,
        label: usize,
        features: &[bool],
    ) -> Result<(), FeedbackError> {
        let lits = crate::data::Dataset::literals_from_bools(features);
        self.feedback(model, label, lits)
    }

    /// Route statistics for the `stats` protocol verb.
    pub fn stats(&self, model: &str) -> Option<RouteStats> {
        self.routes
            .get(model)
            .map(|r| route_stats(&r.metrics, &r.queue, r.swap.as_ref()))
    }

    /// Route names in this handle's (fixed) table, sorted.
    pub fn models(&self) -> Vec<String> {
        let mut names: Vec<String> = self.routes.keys().cloned().collect();
        names.sort();
        names
    }

    /// Hot-swap the serving snapshot of `model` (snapshot routes only)
    /// — see [`Coordinator::swap`]. Available on the handle so
    /// re-publishers (e.g. `tmi serve --watch`) don't need the
    /// coordinator itself.
    pub fn swap(&self, model: &str, snapshot: Arc<ModelSnapshot>) -> Result<u64, SwapError> {
        let route = self
            .routes
            .get(model)
            .ok_or_else(|| SwapError::UnknownModel(model.to_string()))?;
        swap_route(model, route.n_literals, route.swap.as_ref(), snapshot)
    }

    /// The route's live metrics handle — lets the TCP front end record
    /// the Write stage after the reply bytes actually hit the socket.
    fn route_metrics(&self, model: &str) -> Option<Arc<Metrics>> {
        self.routes.get(model).map(|r| Arc::clone(&r.metrics))
    }

    /// Every route's stats, sorted by route name (deterministic
    /// exposition and journal-free iteration for the scrape path).
    fn all_stats(&self) -> Vec<(String, RouteStats)> {
        let mut out: Vec<(String, RouteStats)> = self
            .routes
            .iter()
            .map(|(name, r)| {
                (
                    name.clone(),
                    route_stats(&r.metrics, &r.queue, r.swap.as_ref()),
                )
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Render every route in Prometheus text exposition format 0.0.4
    /// — the `metrics` protocol verb and the `--metrics-addr` HTTP
    /// endpoint. Ends with the `# EOF` trailer (a plain comment under
    /// 0.0.4; line-protocol clients use it as the end-of-reply mark).
    pub fn prometheus(&self) -> String {
        render_prometheus(&self.all_stats())
    }
}

/// Family-major Prometheus rendering: one `# HELP`/`# TYPE` header per
/// family, then every route's sample — the layout scrapers expect.
/// Conformance-checked by [`crate::obs::prometheus::validate_exposition`]
/// in the test suite and CI.
#[rustfmt::skip] // the family table reads best with one family per line
fn render_prometheus(routes: &[(String, RouteStats)]) -> String {
    let mut w = PromWriter::new();
    // counters: (family, help, per-route value)
    let counters: [(&str, &str, fn(&MetricsSnapshot) -> u64); 16] = [
        ("tmi_requests_total", "Requests admitted or shed at the route.", |m| m.requests),
        ("tmi_completed_total", "Requests answered with a prediction.", |m| m.completed),
        ("tmi_shed_total", "Requests shed at admission (queue full).", |m| m.shed),
        ("tmi_errors_total", "Requests answered with an error or dropped unanswered.", |m| m.errors),
        ("tmi_restarts_total", "Supervisor worker restarts after a panic.", |m| m.restarts),
        ("tmi_batches_total", "Batches collected by the route's workers.", |m| m.batches),
        ("tmi_batched_items_total", "Requests carried inside collected batches.", |m| m.batched_items),
        ("tmi_dense_requests_total", "Requests scored by the dense fused index walk.", |m| m.dense_requests),
        ("tmi_sparse_requests_total", "Requests scored by the O(nnz) sparse-delta walk.", |m| m.sparse_requests),
        ("tmi_index_clauses_falsified_total", "Clauses the falsification walk knocked out.", |m| m.clauses_falsified),
        ("tmi_index_clauses_skipped_total", "Clause evaluations the index avoided outright.", |m| m.clauses_skipped),
        ("tmi_index_features_walked_total", "Literals walked by the dense falsification pass.", |m| m.features_walked),
        ("tmi_sparse_toggles_total", "Per-literal delta-row toggles applied by the sparse walk.", |m| m.sparse_toggles),
        ("tmi_feedback_applied_total", "Labeled examples applied by the online learner.", |m| m.feedback_applied),
        ("tmi_feedback_errors_total", "Feedback submissions rejected (bad label, width, shed).", |m| m.feedback_errors),
        ("tmi_publishes_total", "Snapshots published by the online learner's cadence.", |m| m.publishes),
    ];
    for (name, help, get) in counters {
        w.header(name, help, "counter");
        for (route, st) in routes {
            w.int_sample(name, &[("route", route)], get(&st.metrics));
        }
    }
    w.header("tmi_queue_depth", "Live admission-queue depth.", "gauge");
    for (route, st) in routes {
        w.int_sample("tmi_queue_depth", &[("route", route)], st.metrics.queue_depth);
    }
    w.header("tmi_uptime_seconds", "Whole seconds since the route was registered.", "gauge");
    for (route, st) in routes {
        w.int_sample("tmi_uptime_seconds", &[("route", route)], st.metrics.uptime_s);
    }
    w.header(
        "tmi_index_efficiency",
        "Fraction of clause evaluations the index avoided (0 with no probe data).",
        "gauge",
    );
    for (route, st) in routes {
        w.sample(
            "tmi_index_efficiency",
            &[("route", route)],
            st.metrics.index_efficiency(),
        );
    }
    w.header(
        "tmi_publish_lag",
        "Feedback updates applied since the online learner's last publish.",
        "gauge",
    );
    for (route, st) in routes {
        w.int_sample("tmi_publish_lag", &[("route", route)], st.metrics.publish_lag);
    }
    w.header(
        "tmi_feedback_recent_accuracy",
        "Served-era accuracy over the learner's recent feedback window (0 with no feedback).",
        "gauge",
    );
    for (route, st) in routes {
        w.sample(
            "tmi_feedback_recent_accuracy",
            &[("route", route)],
            st.metrics.feedback_recent_accuracy(),
        );
    }
    if routes.iter().any(|(_, st)| st.version.is_some()) {
        w.header(
            "tmi_snapshot_version",
            "Publisher-scoped version of the serving snapshot (snapshot routes).",
            "gauge",
        );
        w.header(
            "tmi_snapshot_generation",
            "Swaps installed on the route since registration (snapshot routes).",
            "gauge",
        );
        w.header(
            "tmi_snapshot_digest",
            "CRC-32 state digest of the serving snapshot (snapshot routes).",
            "gauge",
        );
        for (route, st) in routes {
            if let (Some(v), Some(g)) = (st.version, st.generation) {
                w.int_sample("tmi_snapshot_version", &[("route", route)], v);
                w.int_sample("tmi_snapshot_generation", &[("route", route)], g);
            }
            if let Some(d) = st.digest {
                w.int_sample("tmi_snapshot_digest", &[("route", route)], u64::from(d));
            }
        }
    }
    w.header(
        "tmi_request_latency_us",
        "End-to-end latency, admission to scored (power-of-two buckets, microseconds).",
        "histogram",
    );
    for (route, st) in routes {
        w.histogram("tmi_request_latency_us", &[("route", route)], &st.metrics.latency);
    }
    w.header(
        "tmi_stage_latency_us",
        "Per-pipeline-stage latency: queue wait, batch assembly, engine scoring, reply write, feedback apply.",
        "histogram",
    );
    for (route, st) in routes {
        for stage in Stage::ALL {
            w.histogram(
                "tmi_stage_latency_us",
                &[("route", route), ("stage", stage.name())],
                st.metrics.stage(stage),
            );
        }
    }
    // process-level families: training-side probe counters + journal
    w.header(
        "tmi_feedback_flips_total",
        "TA state flips applied by training feedback (process-wide).",
        "counter",
    );
    w.int_sample("tmi_feedback_flips_total", &[], crate::obs::probes::feedback_flips());
    w.header(
        "tmi_feedback_clause_updates_total",
        "Clause feedback applications during training (process-wide).",
        "counter",
    );
    w.int_sample(
        "tmi_feedback_clause_updates_total",
        &[],
        crate::obs::probes::feedback_clause_updates(),
    );
    w.header(
        "tmi_conn_rejected_total",
        "Connections answered 'err busy' at the max_conns cap (process-wide).",
        "counter",
    );
    w.int_sample("tmi_conn_rejected_total", &[], conn_rejected_total());
    w.header("tmi_journal_events_total", "Events ever emitted into the journal.", "counter");
    w.int_sample("tmi_journal_events_total", &[], journal().emitted());
    w.header(
        "tmi_journal_dropped_total",
        "Journal events evicted to honor the ring capacity.",
        "counter",
    );
    w.int_sample("tmi_journal_dropped_total", &[], journal().dropped());
    w.finish()
}

/// TCP front-end limits.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeOptions {
    /// Connection cap: accepts beyond this are answered `err busy` and
    /// closed immediately (finished connection threads are reaped as
    /// the server goes, so the cap bounds *live* connections).
    pub max_conns: usize,
    /// Per-read timeout on protocol connections (`--read-timeout-ms`).
    /// Bounds how long a connection thread blocks on a silent client
    /// before re-checking the stop flag; a timeout never drops a
    /// buffered partial line.
    pub read_timeout: Duration,
    /// Read timeout for draining an HTTP scrape's request head
    /// (`--scrape-timeout-ms`): a scraper that never finishes its head
    /// still gets the exposition body after this long.
    pub scrape_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_conns: 256,
            read_timeout: Duration::from_millis(100),
            scrape_timeout: Duration::from_millis(500),
        }
    }
}

/// Line protocol for the TCP front end:
///
/// ```text
/// -> infer <model> <01-bitstring of raw features>\n   (or legacy: <model> <bits>\n)
/// <- ok <class> <score_0> <score_1> ...\n   |   err <message>\n
///
/// -> stats <model>\n
/// <- ok model=<m> version=<v|-> generation=<g|-> requests=<n> completed=<n>
///       shed=<n> errors=<n> restarts=<n> queue_depth=<n> batches=<n>
///       mean_batch=<f> p50_us=<n> p95_us=<n> p99_us=<n> uptime_s=<n>
///       dense_requests=<n> sparse_requests=<n> index_efficiency=<f>
///       queue_p50_us=<n> ... write_p99_us=<n>\n   (one line; existing
///       keys are stable, new keys append after p99_us)
///
/// -> stats events <model>\n
/// <- ok events=<n>\n        followed by n single-line journal events
///    (route-scoped + process-wide), oldest first, each
///    `seq=<n> wall_ms=<n> mono_us=<n> kind=<k> [route=<r>] [k=v ...]`
///
/// -> feedback <model> <label> <01-bitstring of raw features>\n
/// <- ok applied=1\n   |   err <message>\n
///    (blocks until the online learner has WAL-logged and applied the
///    example; routes without a learner answer err; a full feedback
///    queue sheds with `err overloaded: feedback queue full`)
///
/// -> train <model> <label>:<bits> [<label>:<bits> ...]\n
/// <- ok applied=<n>\n   |   err <message> applied=<k>\n
///    (batch form of feedback, applied left to right; on a mid-batch
///    error the reply reports how many examples were applied)
///
/// -> metrics\n
/// <- Prometheus text exposition 0.0.4 for every route, terminated by
///    the `# EOF` comment line (the end-of-reply marker)
/// ```
pub fn serve_tcp(
    listener: TcpListener,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    serve_tcp_with(listener, handle, stop, ServeOptions::default())
}

/// [`serve_tcp`] with explicit limits.
pub fn serve_tcp_with(
    listener: TcpListener,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                // reap finished connection threads before capacity-checking
                conns.retain(|c| !c.is_finished());
                if conns.len() >= opts.max_conns {
                    note_conn_rejected();
                    let mut stream = stream;
                    let _ = stream.write_all(b"err busy: connection limit reached\n");
                    continue; // drop closes the socket
                }
                let h = handle.clone();
                let stop_conn = Arc::clone(&stop);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, h, stop_conn, opts.read_timeout);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Minimal HTTP/1.1 scrape endpoint for `tmi serve --metrics-addr`:
/// every request (any method, any path) is answered with the full
/// Prometheus exposition and the connection closed. The accept loop is
/// nonblocking like [`serve_tcp`]; scrapes are served inline — a
/// scrape is one render and one write, so no thread pool is needed.
pub fn serve_metrics_http(
    listener: TcpListener,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    serve_metrics_http_with(listener, handle, stop, ServeOptions::default())
}

/// [`serve_metrics_http`] with explicit limits (only
/// [`ServeOptions::scrape_timeout`] applies to the scrape endpoint).
pub fn serve_metrics_http_with(
    listener: TcpListener,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut stream, _addr)) => {
                let _ = serve_one_scrape(&mut stream, &handle, opts.scrape_timeout);
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Drain the request head (bounded, best-effort — a scraper that
/// never finishes its head still gets the body after the timeout),
/// then reply `200 OK` with the exposition.
fn serve_one_scrape(
    stream: &mut TcpStream,
    handle: &CoordinatorHandle,
    timeout: Duration,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(timeout.max(Duration::from_millis(1))))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 16 * 1024 {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(e) => return Err(e),
        }
    }
    let body = handle.prometheus();
    let mut resp = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    resp.push_str(&body);
    stream.write_all(resp.as_bytes())
}

/// Longest accepted request line (a 20k-feature bitstring is ~20 KB;
/// 1 MiB leaves two orders of magnitude of headroom while bounding
/// per-connection memory against newline-less streams).
const MAX_LINE_BYTES: usize = 1 << 20;

/// How one protocol-line read ended — shared by [`handle_conn`] and
/// the cluster node's connection loop
/// ([`crate::cluster::node::serve_node`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum LineRead {
    /// A complete newline-terminated line is in the buffer.
    Line,
    /// Client closed (including a disconnect mid-line: the partial
    /// request is dropped, never served half a line).
    Eof,
    /// The line exceeded [`MAX_LINE_BYTES`]; the remainder has been
    /// discarded through the next newline and the connection is ready
    /// for the next request. Callers answer `err line too long`.
    TooLong,
}

/// Read one protocol line into `line` (cleared first by the caller),
/// tolerating read-timeout ticks to observe `stop`, with the
/// [`MAX_LINE_BYTES`] cap and oversized-line discard applied.
pub(crate) fn read_protocol_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    stop: &AtomicBool,
) -> std::io::Result<LineRead> {
    let n = loop {
        // cap the buffered line: one extra byte distinguishes
        // "exactly at the cap" from "over it"
        let budget = (MAX_LINE_BYTES + 1 - line.len()) as u64;
        match (&mut *reader).take(budget).read_line(line) {
            Ok(n) => break n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(LineRead::Eof);
                }
                // keep any partial line already buffered and retry
            }
            Err(e) => return Err(e),
        }
    };
    if n == 0 {
        return Ok(LineRead::Eof); // client closed
    }
    if !line.ends_with('\n') {
        if line.len() > MAX_LINE_BYTES {
            // oversized request: refuse it, discard through the next
            // newline, keep serving the connection
            return if discard_to_newline(reader, stop)? {
                Ok(LineRead::TooLong)
            } else {
                Ok(LineRead::Eof)
            };
        }
        // EOF mid-line: the client disconnected mid-write
        return Ok(LineRead::Eof);
    }
    Ok(LineRead::Line)
}

fn handle_conn(
    stream: TcpStream,
    handle: CoordinatorHandle,
    stop: Arc<AtomicBool>,
    read_timeout: Duration,
) -> std::io::Result<()> {
    // Periodic read timeout so idle connections observe shutdown
    // (zero would mean "no timeout" to the OS — clamp it).
    stream.set_read_timeout(Some(read_timeout.max(Duration::from_millis(1))))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match read_protocol_line(&mut reader, &mut line, &stop)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                stream.write_all(b"err line too long\n")?;
                continue;
            }
            LineRead::Line => {}
        }
        let (reply, write_metrics) = respond_line(&line, &handle);
        let t_write = if obs::enabled() && write_metrics.is_some() {
            Some(Instant::now())
        } else {
            None
        };
        stream.write_all(reply.as_bytes())?;
        if let (Some(t0), Some(m)) = (t_write, write_metrics) {
            m.record_stage(Stage::Write, t0.elapsed());
        }
    }
}

/// Stream-discard input until (and including) the next newline without
/// buffering it. Returns false on EOF/shutdown (caller closes).
fn discard_to_newline(
    reader: &mut BufReader<TcpStream>,
    stop: &AtomicBool,
) -> std::io::Result<bool> {
    loop {
        // scan into owned values first: `consume` needs the buffer
        // borrow from `fill_buf` to have ended
        let scanned = reader
            .fill_buf()
            .map(|data| (data.len(), data.iter().position(|&b| b == b'\n')));
        match scanned {
            Ok((0, _)) => return Ok(false), // EOF
            Ok((_, Some(pos))) => {
                reader.consume(pos + 1);
                return Ok(true);
            }
            Ok((len, None)) => reader.consume(len),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(false);
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Dispatch one protocol line (`infer`/`feedback`/`train`/`stats`/
/// `stats events`/`metrics` verbs; a bare `<model> <bits>` is legacy
/// shorthand for `infer`). Returns the reply plus, for infer replies,
/// the route's metrics handle so the caller can attribute the Write
/// stage to the route. Crate-visible so the cluster node's connection
/// loop ([`crate::cluster::node`]) serves the identical base protocol.
pub(crate) fn respond_line(
    line: &str,
    handle: &CoordinatorHandle,
) -> (String, Option<Arc<Metrics>>) {
    let trimmed = line.trim();
    if trimmed == "metrics" {
        return (handle.prometheus(), None);
    }
    if let Some(rest) = trimmed.strip_prefix("feedback ") {
        return (respond_feedback(rest, handle), None);
    }
    if let Some(rest) = trimmed.strip_prefix("train ") {
        return (respond_train(rest, handle), None);
    }
    if let Some(rest) = trimmed.strip_prefix("stats ") {
        let rest = rest.trim();
        if let Some(model) = rest.strip_prefix("events ") {
            let model = model.trim();
            if handle.stats(model).is_none() {
                return (format!("err unknown model '{model}'\n"), None);
            }
            let events = journal().events_for(model);
            let mut out = format!("ok events={}\n", events.len());
            for e in &events {
                out.push_str(&e.to_line());
                out.push('\n');
            }
            return (out, None);
        }
        let model = rest;
        return match handle.stats(model) {
            Some(st) => (stats_line(model, &st), None),
            None => (format!("err unknown model '{model}'\n"), None),
        };
    }
    let body = trimmed.strip_prefix("infer ").unwrap_or(trimmed);
    match parse_request_line(body) {
        Ok((model, features)) => match handle.infer_features(model, &features) {
            Ok(p) => {
                let scores: Vec<String> = p.scores.iter().map(|s| s.to_string()).collect();
                (
                    format!("ok {} {}\n", p.class, scores.join(" ")),
                    handle.route_metrics(model),
                )
            }
            Err(e) => (format!("err {e}\n"), None),
        },
        Err(e) => (format!("err {e}\n"), None),
    }
}

/// `feedback <model> <label> <bits>`: one labeled example through the
/// route's online learner (applied-then-ack).
fn respond_feedback(body: &str, handle: &CoordinatorHandle) -> String {
    let mut parts = body.trim().splitn(3, ' ');
    let (model, label, bits) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(l), Some(b)) => (m, l, b.trim()),
        _ => return "err expected 'feedback <model> <label> <bits>'\n".to_string(),
    };
    match parse_labeled_example(label, bits) {
        Ok((label, features)) => match handle.feedback_features(model, label, &features) {
            Ok(()) => "ok applied=1\n".to_string(),
            Err(e) => format!("err {e}\n"),
        },
        Err(e) => format!("err {e}\n"),
    }
}

/// `train <model> <label>:<bits> [...]`: the batch form — parse every
/// item up front (a malformed item rejects the whole line unapplied),
/// then apply left to right, reporting progress on a mid-batch error.
fn respond_train(body: &str, handle: &CoordinatorHandle) -> String {
    let mut parts = body.trim().split_whitespace();
    let Some(model) = parts.next() else {
        return "err expected 'train <model> <label>:<bits> [...]'\n".to_string();
    };
    let mut examples = Vec::new();
    for item in parts {
        let Some((label, bits)) = item.split_once(':') else {
            return format!("err bad item '{item}': expected <label>:<bits>\n");
        };
        match parse_labeled_example(label, bits) {
            Ok(ex) => examples.push(ex),
            Err(e) => return format!("err bad item '{item}': {e}\n"),
        }
    }
    if examples.is_empty() {
        return "err expected 'train <model> <label>:<bits> [...]'\n".to_string();
    }
    let mut applied = 0usize;
    for (label, features) in &examples {
        if let Err(e) = handle.feedback_features(model, *label, features) {
            return format!("err {e} applied={applied}\n");
        }
        applied += 1;
    }
    format!("ok applied={applied}\n")
}

/// Parse a `<label>` token and a 01-bitstring of raw features.
fn parse_labeled_example(label: &str, bits: &str) -> Result<(usize, Vec<bool>), String> {
    let label: usize = label
        .parse()
        .map_err(|_| format!("bad label '{label}'"))?;
    let features: Result<Vec<bool>, String> = bits
        .chars()
        .map(|c| match c {
            '0' => Ok(false),
            '1' => Ok(true),
            other => Err(format!("bad bit '{other}'")),
        })
        .collect();
    Ok((label, features?))
}

/// One-line `k=v` stats reply. Parse-stable: every pre-existing key
/// keeps its position (consumers match `requests=`..`p99_us=` by
/// token); observability keys only ever *append* after `p99_us=`.
fn stats_line(model: &str, st: &RouteStats) -> String {
    use std::fmt::Write as _;
    let m = &st.metrics;
    let opt = |v: Option<u64>| v.map(|v| v.to_string()).unwrap_or_else(|| "-".to_string());
    let version = opt(st.version);
    let generation = opt(st.generation);
    let mut out = format!(
        "ok model={model} version={version} generation={generation} requests={} \
         completed={} shed={} errors={} restarts={} queue_depth={} batches={} \
         mean_batch={:.2} p50_us={} p95_us={} p99_us={}",
        m.requests,
        m.completed,
        m.shed,
        m.errors,
        m.restarts,
        m.queue_depth,
        m.batches,
        m.mean_batch_size(),
        m.p50_us(),
        m.p95_us(),
        m.p99_us(),
    );
    let _ = write!(
        out,
        " uptime_s={} dense_requests={} sparse_requests={} index_efficiency={:.4}",
        m.uptime_s,
        m.dense_requests,
        m.sparse_requests,
        m.index_efficiency(),
    );
    for stage in crate::obs::Stage::ALL {
        let h = m.stage(stage);
        let _ = write!(
            out,
            " {0}_p50_us={1} {0}_p95_us={2} {0}_p99_us={3}",
            stage.name(),
            h.p50(),
            h.p95(),
            h.p99(),
        );
    }
    // online-learning keys (append-only like the rest): counters,
    // staleness, drift accuracy, and the snapshot's CRC-32 digest —
    // the crash-recovery equality witness
    let _ = write!(
        out,
        " feedback_applied={} feedback_errors={} publishes={} publish_lag={} \
         feedback_recent_acc={:.4} digest={}",
        m.feedback_applied,
        m.feedback_errors,
        m.publishes,
        m.publish_lag,
        m.feedback_recent_accuracy(),
        st.digest
            .map(|d| d.to_string())
            .unwrap_or_else(|| "-".to_string()),
    );
    // server-wide connection-cap rejections (same value on every
    // route's line — the cap fires before routing)
    let _ = write!(out, " conn_rejected={}", m.conn_rejected);
    out.push('\n');
    out
}

fn parse_request_line(line: &str) -> Result<(&str, Vec<bool>), String> {
    let line = line.trim();
    let (model, bits) = line
        .split_once(' ')
        .ok_or_else(|| "expected '<model> <bits>'".to_string())?;
    let features: Result<Vec<bool>, String> = bits
        .chars()
        .map(|c| match c {
            '0' => Ok(false),
            '1' => Ok(true),
            other => Err(format!("bad bit '{other}'")),
        })
        .collect();
    Ok((model, features?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuBackend;
    use crate::eval;
    use crate::tm::params::TMParams;
    use crate::tm::trainer::Trainer;
    use crate::util::Rng;
    use std::net::Shutdown;
    use std::time::Duration;

    fn toy_trainer(seed: u64) -> Trainer {
        let params = TMParams::new(2, 10, 8).with_seed(seed);
        let mut tr = Trainer::new(params, eval::Backend::Indexed);
        let mut rng = Rng::new(seed.wrapping_mul(3).wrapping_add(1));
        let samples: Vec<(BitVec, usize)> = (0..200)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..8).map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..5 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr
    }

    fn toy_backend() -> Box<dyn Backend + Send> {
        Box::new(CpuBackend::new(toy_trainer(3).tm, eval::Backend::Indexed))
    }

    fn class0_features() -> Vec<bool> {
        let mut f = vec![false; 8];
        f[0] = true;
        f
    }

    #[test]
    fn register_infer_shutdown() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        let p = h.infer_features("toy", &class0_features()).unwrap();
        assert_eq!(p.class, 0);
        assert_eq!(p.scores.len(), 2);
        let m = coord.metrics("toy").unwrap();
        assert_eq!(m.requests, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(m.shed, 0);
        coord.shutdown();
    }

    #[test]
    fn unknown_model_and_wrong_width() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        assert!(matches!(
            h.infer_features("nope", &class0_features()),
            Err(InferError::UnknownModel(_))
        ));
        assert!(matches!(
            h.infer("toy", BitVec::zeros(4)),
            Err(InferError::WrongWidth { expected: 16, got: 4 })
        ));
        coord.shutdown();
    }

    #[test]
    fn concurrent_clients_all_answered() {
        let mut coord = Coordinator::new();
        coord.register(
            "toy",
            toy_backend(),
            BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
        );
        let h = coord.handle();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        let p = h.infer_features("toy", &class0_features()).unwrap();
                        assert_eq!(p.class, 0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let m = coord.metrics("toy").unwrap();
        assert_eq!(m.completed, 200);
        assert!(m.batches <= 200);
        coord.shutdown();
    }

    #[test]
    fn snapshot_route_multiworker_serves_and_counts() {
        let mut tr = toy_trainer(3);
        let want = {
            let f = class0_features();
            let lits = crate::data::Dataset::literals_from_bools(&f);
            tr.scores(&lits)
        };
        let mut coord = Coordinator::new();
        coord.register_model(
            "toy",
            tr.publish(),
            RouteConfig {
                workers: 3,
                queue_cap: 128,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_micros(200),
                },
                ..RouteConfig::default()
            },
        );
        let h = coord.handle();
        let threads: Vec<_> = (0..6)
            .map(|_| {
                let h = h.clone();
                let want = want.clone();
                std::thread::spawn(move || {
                    for _ in 0..30 {
                        let p = h.infer_features("toy", &class0_features()).unwrap();
                        assert_eq!(p.scores, want);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let st = coord.stats("toy").unwrap();
        assert_eq!(st.metrics.completed, 180);
        assert_eq!(st.metrics.errors, 0);
        assert_eq!(st.version, Some(1));
        assert_eq!(st.generation, Some(0));
        coord.shutdown();
    }

    #[test]
    fn swap_replaces_serving_version() {
        let mut tr_a = toy_trainer(3);
        let mut tr_b = toy_trainer(4);
        let f = class0_features();
        let lits = crate::data::Dataset::literals_from_bools(&f);
        let want_a = tr_a.scores(&lits);
        let want_b = tr_b.scores(&lits);

        let mut coord = Coordinator::new();
        coord.register_model("toy", tr_a.publish(), RouteConfig::default());
        let h = coord.handle();
        assert_eq!(h.infer_features("toy", &f).unwrap().scores, want_a);
        let st = h.stats("toy").unwrap();
        assert_eq!((st.version, st.generation), (Some(1), Some(0)));

        let retired = coord.swap("toy", tr_b.publish()).unwrap();
        assert_eq!(retired, 1);
        assert_eq!(h.infer_features("toy", &f).unwrap().scores, want_b);
        // publisher versions can collide (tr_b's first publish is also
        // v1) — the route generation is what proves the swap landed
        let st = h.stats("toy").unwrap();
        assert_eq!((st.version, st.generation), (Some(1), Some(1)));

        // swap through the handle too
        let retired = h.swap("toy", tr_a.publish()).unwrap();
        assert_eq!(retired, 1);
        assert_eq!(h.infer_features("toy", &f).unwrap().scores, want_a);
        let st = h.stats("toy").unwrap();
        assert_eq!((st.version, st.generation), (Some(2), Some(2)));
        coord.shutdown();
    }

    #[test]
    fn swap_rejects_factory_routes_and_wrong_width() {
        let mut coord = Coordinator::new();
        coord.register("fact", toy_backend(), BatchPolicy::default());
        let mut tr = toy_trainer(3);
        coord.register_model("snap", tr.publish(), RouteConfig::default());
        let h = coord.handle();
        assert!(matches!(
            coord.swap("fact", tr.publish()),
            Err(SwapError::Unsupported(_))
        ));
        assert!(matches!(
            h.swap("missing", tr.publish()),
            Err(SwapError::UnknownModel(_))
        ));
        // wrong literal width: a machine over 4 features (8 literals)
        let mut small = Trainer::new(
            TMParams::new(2, 4, 4),
            eval::Backend::Indexed,
        );
        assert!(matches!(
            h.swap("snap", small.publish()),
            Err(SwapError::WrongWidth { expected: 16, got: 8 })
        ));
        coord.shutdown();
    }

    /// Backend that sleeps per batch — drives overload and shutdown
    /// ordering tests.
    struct SlowBackend {
        delay: Duration,
    }
    impl Backend for SlowBackend {
        fn infer_batch(
            &mut self,
            batch: &[BitVec],
        ) -> anyhow::Result<Vec<crate::coordinator::backend::Scored>> {
            std::thread::sleep(self.delay);
            Ok(batch
                .iter()
                .map(|_| crate::coordinator::backend::Scored {
                    prediction: 0,
                    scores: vec![0, 0],
                })
                .collect())
        }
        fn n_literals(&self) -> usize {
            4
        }
        fn name(&self) -> String {
            "slow".into()
        }
    }

    #[test]
    fn full_queue_sheds_with_overloaded() {
        let mut coord = Coordinator::new();
        coord
            .register_with_config(
                "slow",
                || {
                    Ok(Box::new(SlowBackend {
                        delay: Duration::from_millis(5),
                    }) as Box<dyn Backend>)
                },
                RouteConfig {
                    workers: 1,
                    queue_cap: 2,
                    policy: BatchPolicy {
                        max_batch: 1,
                        max_wait: Duration::ZERO,
                    },
                    ..RouteConfig::default()
                },
            )
            .unwrap();
        let h = coord.handle();
        let counters: Vec<_> = (0..10)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    let (mut ok, mut shed) = (0u64, 0u64);
                    for _ in 0..5 {
                        match h.infer("slow", BitVec::zeros(4)) {
                            Ok(_) => ok += 1,
                            Err(InferError::Overloaded) => shed += 1,
                            Err(e) => panic!("unexpected error: {e}"),
                        }
                    }
                    (ok, shed)
                })
            })
            .collect();
        let (mut ok, mut shed) = (0u64, 0u64);
        for c in counters {
            let (o, s) = c.join().unwrap();
            ok += o;
            shed += s;
        }
        assert_eq!(ok + shed, 50, "every request answered, none hung");
        assert!(shed > 0, "sustained overload must shed");
        assert!(ok > 0, "admitted requests must still complete");
        let m = coord.metrics("slow").unwrap();
        assert_eq!(m.shed, shed);
        assert_eq!(m.completed, ok);
        assert_eq!(m.requests, 50);
        coord.shutdown();
    }

    #[test]
    fn shutdown_answers_queued_and_in_flight_requests() {
        let mut coord = Coordinator::new();
        coord
            .register_with_config(
                "slow",
                || {
                    Ok(Box::new(SlowBackend {
                        delay: Duration::from_millis(10),
                    }) as Box<dyn Backend>)
                },
                RouteConfig {
                    workers: 1,
                    queue_cap: 64,
                    policy: BatchPolicy {
                        max_batch: 2,
                        max_wait: Duration::ZERO,
                    },
                    ..RouteConfig::default()
                },
            )
            .unwrap();
        let h = coord.handle();
        let clients: Vec<_> = (0..8)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || h.infer("slow", BitVec::zeros(4)))
            })
            .collect();
        // let every client enqueue (first batch in flight, rest queued)
        std::thread::sleep(Duration::from_millis(20));
        coord.shutdown();
        for c in clients {
            let r = c.join().unwrap();
            assert!(r.is_ok(), "admitted request must be answered, got {r:?}");
        }
    }

    /// Backend that panics — the route must fail closed, not hang.
    struct PanickingBackend;
    impl Backend for PanickingBackend {
        fn infer_batch(
            &mut self,
            _batch: &[BitVec],
        ) -> anyhow::Result<Vec<crate::coordinator::backend::Scored>> {
            panic!("injected worker panic")
        }
        fn n_literals(&self) -> usize {
            4
        }
        fn name(&self) -> String {
            "panicking".into()
        }
    }

    #[test]
    fn worker_panic_fails_clients_instead_of_hanging() {
        let mut coord = Coordinator::new();
        coord.register("boom", Box::new(PanickingBackend), BatchPolicy::default());
        let h = coord.handle();
        // first request rides the panicking batch: its response channel
        // is dropped, the client unblocks with ShuttingDown
        assert!(matches!(
            h.infer("boom", BitVec::zeros(4)),
            Err(InferError::ShuttingDown)
        ));
        // `register` backends are one-shot, so the restart attempt finds
        // nothing to rebuild and the route fails closed: either the
        // guard already closed the queue (immediate rejection) or this
        // request is drained during close (dropped response channel) —
        // ShuttingDown both ways, never a hang
        assert!(matches!(
            h.infer("boom", BitVec::zeros(4)),
            Err(InferError::ShuttingDown)
        ));
        coord.shutdown();
    }

    /// Backend whose first life panics on its first batch; rebuilt
    /// lives are healthy — exercises the factory-route restart path.
    struct FlakyBackend {
        panic_once: bool,
    }
    impl Backend for FlakyBackend {
        fn infer_batch(
            &mut self,
            batch: &[BitVec],
        ) -> anyhow::Result<Vec<crate::coordinator::backend::Scored>> {
            if self.panic_once {
                panic!("injected: first life dies");
            }
            Ok(batch
                .iter()
                .map(|_| crate::coordinator::backend::Scored {
                    prediction: 0,
                    scores: vec![0, 0],
                })
                .collect())
        }
        fn n_literals(&self) -> usize {
            4
        }
        fn name(&self) -> String {
            "flaky".into()
        }
    }

    #[test]
    fn factory_route_restarts_after_worker_panic() {
        let mut coord = Coordinator::new();
        let built = Arc::new(AtomicUsize::new(0));
        let built_factory = Arc::clone(&built);
        coord
            .register_with(
                "flaky",
                move || {
                    let n = built_factory.fetch_add(1, Ordering::SeqCst);
                    Ok(Box::new(FlakyBackend { panic_once: n == 0 }) as Box<dyn Backend>)
                },
                BatchPolicy::default(),
            )
            .unwrap();
        let h = coord.handle();
        // the first request rides the panicking batch and fails...
        assert!(matches!(
            h.infer("flaky", BitVec::zeros(4)),
            Err(InferError::ShuttingDown)
        ));
        // ...but the supervisor re-ran the factory: the route survives
        let p = h.infer("flaky", BitVec::zeros(4)).unwrap();
        assert_eq!(p.class, 0);
        assert_eq!(built.load(Ordering::SeqCst), 2);
        let m = coord.metrics("flaky").unwrap();
        assert_eq!(m.restarts, 1);
        assert_eq!(m.completed, 1);
        coord.shutdown();
    }

    #[test]
    fn snapshot_route_restarts_after_injected_panic() {
        let mut tr = toy_trainer(3);
        let want = {
            let lits = crate::data::Dataset::literals_from_bools(&class0_features());
            tr.scores(&lits)
        };
        let mut coord = Coordinator::new();
        coord.register_model("faulty", tr.publish(), RouteConfig::default());
        let h = coord.handle();
        // healthy before the fault
        assert_eq!(
            h.infer_features("faulty", &class0_features()).unwrap().scores,
            want
        );
        fault::arm_worker_panics("faulty", 1);
        // this request's batch takes the injected mid-swap panic
        assert!(matches!(
            h.infer_features("faulty", &class0_features()),
            Err(InferError::ShuttingDown)
        ));
        // the restarted worker answers bit-identically, and the restart
        // is visible in stats
        assert_eq!(
            h.infer_features("faulty", &class0_features()).unwrap().scores,
            want
        );
        let st = coord.stats("faulty").unwrap();
        assert_eq!(st.metrics.restarts, 1);
        assert!(
            stats_line("faulty", &st).contains(" restarts=1 "),
            "stats must surface the restart: {}",
            stats_line("faulty", &st)
        );
        coord.shutdown();
    }

    #[test]
    fn poisoned_swap_cell_recovers_instead_of_cascading() {
        let mut tr = toy_trainer(3);
        let snap_a = tr.publish();
        let cell = Arc::new(SwapCell::new(Arc::clone(&snap_a)));
        let poisoner = Arc::clone(&cell);
        let _ = std::thread::spawn(move || {
            let _g = poisoner.snap.write().unwrap();
            panic!("poison the swap cell");
        })
        .join();
        assert!(cell.snap.is_poisoned(), "test setup must poison the lock");
        // reads and writes recover instead of propagating the panic
        assert_eq!(cell.load().version(), snap_a.version());
        let snap_b = tr.publish();
        let retired = cell.store(Arc::clone(&snap_b));
        assert_eq!(retired, snap_a.version());
        assert_eq!(cell.load().version(), snap_b.version());
        assert_eq!(cell.generation(), 1);
    }

    /// Backend that fails every batch — exercises the error path.
    struct FailingBackend;
    impl Backend for FailingBackend {
        fn infer_batch(
            &mut self,
            _batch: &[BitVec],
        ) -> anyhow::Result<Vec<crate::coordinator::backend::Scored>> {
            anyhow::bail!("injected backend failure")
        }
        fn n_literals(&self) -> usize {
            4
        }
        fn name(&self) -> String {
            "failing".into()
        }
    }

    #[test]
    fn backend_errors_propagate_and_are_counted() {
        let mut coord = Coordinator::new();
        coord.register("bad", Box::new(FailingBackend), BatchPolicy::default());
        let h = coord.handle();
        for _ in 0..3 {
            match h.infer("bad", BitVec::zeros(4)) {
                Err(InferError::BackendError(msg)) => {
                    assert!(msg.contains("injected"), "{msg}")
                }
                other => panic!("expected backend error, got {other:?}"),
            }
        }
        let m = coord.metrics("bad").unwrap();
        assert_eq!(m.errors, 3);
        assert_eq!(m.completed, 0);
        // coordinator still serves other routes and shuts down cleanly
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        assert!(h.infer_features("toy", &class0_features()).is_ok());
        coord.shutdown();
    }

    #[test]
    fn failing_factory_creates_no_route() {
        let mut coord = Coordinator::new();
        let res = coord.register_with(
            "broken",
            || anyhow::bail!("cannot construct"),
            BatchPolicy::default(),
        );
        assert!(res.is_err());
        assert!(coord.models().is_empty());
        let h = coord.handle();
        assert!(matches!(
            h.infer("broken", BitVec::zeros(4)),
            Err(InferError::UnknownModel(_))
        ));
        coord.shutdown();
    }

    #[test]
    fn infer_after_shutdown_reports_shutting_down() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let h = coord.handle();
        coord.shutdown();
        // workers are gone and the queue is closed; the stale handle
        // must fail, not hang
        let r = h.infer_features("toy", &class0_features());
        assert!(matches!(r, Err(InferError::ShuttingDown)), "{r:?}");
    }

    #[test]
    fn stats_line_appends_observability_keys_after_p99() {
        let st = RouteStats {
            metrics: Metrics::new().snapshot(),
            version: None,
            generation: None,
            digest: None,
        };
        let line = stats_line("m", &st);
        assert!(line.ends_with('\n') && line.matches('\n').count() == 1);
        let p99 = line.find(" p99_us=").expect("p99_us key");
        for key in [
            " uptime_s=",
            " dense_requests=",
            " sparse_requests=",
            " index_efficiency=",
            " queue_p50_us=",
            " batch_p95_us=",
            " score_p99_us=",
            " write_p50_us=",
            " feedback_p99_us=",
            " feedback_applied=",
            " feedback_errors=",
            " publishes=",
            " publish_lag=",
            " feedback_recent_acc=",
            " digest=",
            " conn_rejected=",
        ] {
            let at = line.find(key).unwrap_or_else(|| panic!("missing {key}"));
            assert!(at > p99, "{key} must append after p99_us");
        }
    }

    #[test]
    fn parse_request_line_cases() {
        let (m, f) = parse_request_line("toy 1010\n").unwrap();
        assert_eq!(m, "toy");
        assert_eq!(f, vec![true, false, true, false]);
        assert!(parse_request_line("justmodel").is_err());
        assert!(parse_request_line("toy 10x1").is_err());
    }

    #[test]
    fn tcp_round_trip_with_verbs() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();

        // legacy form
        conn.write_all(b"toy 10000000\n").unwrap();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok 0 "), "reply: {reply}");

        // explicit infer verb gives the same answer
        conn.write_all(b"infer toy 10000000\n").unwrap();
        let mut reply2 = String::new();
        reader.read_line(&mut reply2).unwrap();
        assert_eq!(reply, reply2);

        // stats verb
        conn.write_all(b"stats toy\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(
            reply.starts_with("ok model=toy version=- generation=- requests=2 completed=2"),
            "reply: {reply}"
        );
        assert!(reply.contains(" shed=0 "), "reply: {reply}");
        assert!(reply.contains(" p99_us="), "reply: {reply}");

        conn.write_all(b"stats missing\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err unknown model"), "reply: {reply}");

        // stats events verb: count-framed single-line journal events
        conn.write_all(b"stats events toy\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok events="), "reply: {reply}");
        let n: usize = reply
            .trim()
            .strip_prefix("ok events=")
            .unwrap()
            .parse()
            .unwrap();
        for _ in 0..n {
            reply.clear();
            reader.read_line(&mut reply).unwrap();
            assert!(reply.starts_with("seq="), "event line: {reply}");
        }
        conn.write_all(b"stats events missing\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err unknown model"), "reply: {reply}");

        // metrics verb: EOF-terminated, conformant exposition covering
        // the route's counters
        conn.write_all(b"metrics\n").unwrap();
        let mut expo = String::new();
        loop {
            reply.clear();
            reader.read_line(&mut reply).unwrap();
            expo.push_str(&reply);
            if reply == "# EOF\n" {
                break;
            }
        }
        assert!(
            expo.contains("tmi_requests_total{route=\"toy\"} 2"),
            "exposition: {expo}"
        );
        assert!(expo.contains("tmi_stage_latency_us_bucket{route=\"toy\",stage=\"queue\""));
        crate::obs::prometheus::validate_exposition(&expo).unwrap();

        conn.write_all(b"missing 1\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err "), "reply: {reply}");

        stop.store(true, Ordering::Relaxed);
        drop(conn);
        drop(reader); // the try_clone half also holds the socket open
        server.join().unwrap().unwrap();
        coord.shutdown();
    }

    #[test]
    fn partial_line_on_disconnect_is_dropped() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

        let mut conn = TcpStream::connect(addr).unwrap();
        // half a request, then disconnect mid-write
        conn.write_all(b"toy 1000").unwrap();
        conn.shutdown(Shutdown::Write).unwrap();
        // the server must close without replying to the partial line
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).unwrap();
        assert_eq!(n, 0, "partial line must not be served, got: {reply}");
        let m = coord.metrics("toy").unwrap();
        assert_eq!(m.requests, 0);

        stop.store(true, Ordering::Relaxed);
        drop(conn);
        drop(reader);
        server.join().unwrap().unwrap();
        coord.shutdown();
    }

    #[test]
    fn oversized_line_is_refused_and_connection_survives() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        // a single "line" well past MAX_LINE_BYTES, eventually terminated
        let chunk = vec![b'1'; 64 * 1024];
        for _ in 0..17 {
            if conn.write_all(&chunk).is_err() {
                break;
            }
        }
        conn.write_all(b"\n").unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err line too long"), "reply: {reply}");
        // the oversized line was discarded, not buffered: the same
        // connection keeps serving
        conn.write_all(b"toy 10000000\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok 0 "), "reply: {reply}");

        stop.store(true, Ordering::Relaxed);
        drop(conn);
        drop(reader);
        server.join().unwrap().unwrap();
        coord.shutdown();
    }

    #[test]
    fn feedback_without_learner_is_unsupported() {
        let mut tr = toy_trainer(3);
        let mut coord = Coordinator::new();
        coord.register_model("toy", tr.publish(), RouteConfig::default());
        let h = coord.handle();
        assert!(matches!(
            h.feedback_features("toy", 0, &class0_features()),
            Err(FeedbackError::Unsupported(_))
        ));
        assert!(matches!(
            h.feedback_features("nope", 0, &class0_features()),
            Err(FeedbackError::UnknownModel(_))
        ));
        // the snapshot route still reports its digest
        let st = h.stats("toy").unwrap();
        assert!(st.digest.is_some());
        coord.shutdown();
    }

    #[test]
    fn feedback_and_train_verbs_apply_and_republish() {
        use crate::coordinator::online::{OnlineConfig, OnlineLearner, PublishFn, PublishReport};

        let mut learner_tr =
            Trainer::from_machine(toy_trainer(3).tm, eval::Backend::Indexed);
        let mut coord = Coordinator::new();
        coord.register_model("toy", learner_tr.publish(), RouteConfig::default());
        // the hook handle predates the learner: it only needs the swap
        // cell, which is shared by Arc with every later handle
        let hook = coord.handle();
        let metrics = coord.route_metrics("toy").unwrap();
        let publish: PublishFn = Box::new(move |tr, _updates| {
            let snap = tr.publish();
            let version = snap.version();
            hook.swap("toy", snap).map_err(|e| e.to_string())?;
            let generation = hook
                .stats("toy")
                .and_then(|s| s.generation)
                .unwrap_or(0);
            Ok(PublishReport {
                version,
                generation,
                durable: false,
            })
        });
        let learner = OnlineLearner::spawn(
            "toy",
            learner_tr,
            None,
            publish,
            metrics,
            OnlineConfig {
                publish_every: 2,
                publish_interval: None,
                ..OnlineConfig::default()
            },
        );
        coord.attach_learner("toy", learner.sender()).unwrap();
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || serve_tcp(listener, handle, stop2));

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();

        conn.write_all(b"feedback toy 0 10000000\n").unwrap();
        reader.read_line(&mut reply).unwrap();
        assert_eq!(reply, "ok applied=1\n");

        conn.write_all(b"train toy 0:10000000 1:01000000\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert_eq!(reply, "ok applied=2\n");

        // validation errors: label out of range, bad syntax, no route
        conn.write_all(b"feedback toy 9 10000000\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err label 9 out of range"), "reply: {reply}");
        conn.write_all(b"train toy\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err expected 'train"), "reply: {reply}");
        conn.write_all(b"feedback missing 0 1\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err unknown model"), "reply: {reply}");

        // 3 applied at publish_every=2: one cadence publish so far —
        // the route generation advanced and the learner keys surface
        conn.write_all(b"stats toy\n").unwrap();
        reply.clear();
        reader.read_line(&mut reply).unwrap();
        assert!(reply.contains(" generation=1 "), "reply: {reply}");
        assert!(reply.contains(" feedback_applied=3 "), "reply: {reply}");
        assert!(reply.contains(" feedback_errors=1 "), "reply: {reply}");
        assert!(reply.contains(" publishes=1 "), "reply: {reply}");
        assert!(reply.contains(" publish_lag=1 "), "reply: {reply}");
        let digest_val = reply
            .split(" digest=")
            .nth(1)
            .map(|s| s.trim())
            .unwrap_or("");
        assert!(
            !digest_val.is_empty() && digest_val.chars().all(|c| c.is_ascii_digit()),
            "digest must be numeric for snapshot routes: {reply}"
        );

        // the metrics verb exposes the new families
        conn.write_all(b"metrics\n").unwrap();
        let mut expo = String::new();
        loop {
            reply.clear();
            reader.read_line(&mut reply).unwrap();
            expo.push_str(&reply);
            if reply == "# EOF\n" {
                break;
            }
        }
        assert!(expo.contains("tmi_feedback_applied_total{route=\"toy\"} 3"), "{expo}");
        assert!(expo.contains("tmi_publishes_total{route=\"toy\"} 1"), "{expo}");
        assert!(expo.contains("tmi_publish_lag{route=\"toy\"} 1"), "{expo}");
        assert!(expo.contains("tmi_snapshot_digest{route=\"toy\"}"), "{expo}");
        assert!(
            expo.contains("tmi_stage_latency_us_bucket{route=\"toy\",stage=\"feedback\""),
            "{expo}"
        );
        crate::obs::prometheus::validate_exposition(&expo).unwrap();

        stop.store(true, Ordering::Relaxed);
        drop(conn);
        drop(reader);
        server.join().unwrap().unwrap();
        // shutdown final-publishes the pending update
        learner.shutdown();
        assert_eq!(coord.stats("toy").unwrap().metrics.publishes, 2);
        coord.shutdown();
    }

    #[test]
    fn connection_cap_answers_busy_and_reaps() {
        let mut coord = Coordinator::new();
        coord.register("toy", toy_backend(), BatchPolicy::default());
        let handle = coord.handle();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let server = std::thread::spawn(move || {
            serve_tcp_with(
                listener,
                handle,
                stop2,
                ServeOptions {
                    max_conns: 1,
                    ..ServeOptions::default()
                },
            )
        });

        // first connection occupies the only slot
        let mut c1 = TcpStream::connect(addr).unwrap();
        c1.write_all(b"toy 10000000\n").unwrap();
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        let mut reply = String::new();
        r1.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("ok "), "reply: {reply}");

        // second connection is refused with err busy — and the
        // rejection is visible to observability, not just the client
        let rejected_before = conn_rejected_total();
        let c2 = TcpStream::connect(addr).unwrap();
        let mut r2 = BufReader::new(c2);
        reply.clear();
        r2.read_line(&mut reply).unwrap();
        assert!(reply.starts_with("err busy"), "reply: {reply}");
        assert!(
            conn_rejected_total() > rejected_before,
            "cap rejection did not bump conn_rejected"
        );

        // free the slot; the server reaps the finished thread and
        // accepts again (poll: reaping happens on the next accept)
        drop(r1);
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut served = false;
        while Instant::now() < deadline {
            let mut c3 = TcpStream::connect(addr).unwrap();
            c3.write_all(b"toy 10000000\n").unwrap();
            let mut r3 = BufReader::new(c3.try_clone().unwrap());
            reply.clear();
            r3.read_line(&mut reply).unwrap();
            if reply.starts_with("ok ") {
                served = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(served, "capacity never freed after disconnect");

        stop.store(true, Ordering::Relaxed);
        drop(r2);
        server.join().unwrap().unwrap();
        coord.shutdown();
    }
}
