//! Serving coordinator: hot-swap model registry, bounded request
//! queues with admission control, dynamic batcher workers, metrics,
//! TCP front end, and a load generator.
//!
//! Layer-3 of the stack. The vendored offline environment has no tokio,
//! so the coordinator is built directly on `std::thread` + condvar
//! queues (DESIGN.md §Substitutions): each registered model gets a
//! bounded [`queue::BoundedQueue`] drained by one or more batcher
//! workers running a collect-then-execute loop; a shared handle routes
//! requests by model name, sheds `err overloaded` when a queue is
//! full, and blocks on a per-request completion channel. A
//! line-oriented TCP front end (with a reaped, capped connection pool)
//! exposes the same router — `infer` and `stats` verbs — over the
//! network, and [`loadgen`] drives it for capacity measurement.
//!
//! Models are served as immutable, versioned
//! [`crate::engine::ModelSnapshot`]s that [`Coordinator::swap`] (or
//! `tmi serve --watch`) replaces atomically under live traffic — the
//! paper's train-while-serving story (arXiv 2004.03188: constant-time
//! index updates keep a learner publishable mid-stream). [`online`]
//! completes that story: a per-route single-writer learner accepts
//! `feedback`/`train` verbs, applies them through the clause index's
//! O(1) update hooks, and republishes on a configurable cadence
//! (`--publish-every` / `--publish-interval`), with an optional
//! crash-durable feedback WAL ([`crate::registry::FeedbackWal`]).
//!
//! Backends:
//! * [`backend::CpuBackend`] — the paper's system: clause-indexed
//!   evaluation on the Rust hot path (also naive/bitpacked for A/B).
//! * [`backend::XlaBackend`] — the AOT-compiled XLA executable
//!   (Layer 1/2), device-resident model buffers, true batched scoring.

pub mod backend;
pub mod batcher;
pub mod loadgen;
pub mod metrics;
pub mod online;
pub mod queue;
pub mod server;
pub mod supervisor;

pub use backend::{Backend as ServeBackend, CpuBackend, XlaBackend};
pub use batcher::BatchPolicy;
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use metrics::{Metrics, MetricsSnapshot};
pub use online::{
    FeedbackError, FeedbackSender, OnlineConfig, OnlineLearner, PublishFn, PublishReport,
};
pub use queue::{BoundedQueue, PushError};
pub use supervisor::{RestartPolicy, SupervisedExit};
pub use server::{
    Coordinator, CoordinatorHandle, InferError, Prediction, RouteConfig, RouteStats,
    ServeOptions, SwapError,
};
