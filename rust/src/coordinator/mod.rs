//! Serving coordinator: router, dynamic batcher, backend workers,
//! metrics.
//!
//! Layer-3 of the stack. The vendored offline environment has no tokio,
//! so the coordinator is built directly on `std::thread` + channels
//! (DESIGN.md §Substitutions): one worker thread per registered model,
//! each running a collect-then-execute dynamic-batching loop; a shared
//! handle routes requests by model name and blocks on a per-request
//! completion channel. An optional line-oriented TCP front end exposes
//! the same router over the network.
//!
//! Backends:
//! * [`backend::CpuBackend`] — the paper's system: clause-indexed
//!   evaluation on the Rust hot path (also naive/bitpacked for A/B).
//! * [`backend::XlaBackend`] — the AOT-compiled XLA executable
//!   (Layer 1/2), device-resident model buffers, true batched scoring.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{Backend as ServeBackend, CpuBackend, XlaBackend};
pub use batcher::BatchPolicy;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{Coordinator, InferError, Prediction};
