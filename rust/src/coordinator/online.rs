//! Learn-while-serving: a single-writer online learner per route.
//!
//! The paper's falsification index supports *constant-time updating,
//! thus use also during learning* — this module exercises that claim
//! under live traffic. A route's [`OnlineLearner`] owns a real
//! [`Trainer`] (indexed backend: every `train_sample` maintains the
//! clause index through the O(1) flip hooks) on a dedicated thread;
//! reader workers keep scoring the current published
//! [`crate::engine::ModelSnapshot`] untouched. `feedback`/`train`
//! protocol verbs enqueue labeled examples into a bounded channel, the
//! learner applies them in arrival order, and a publish cadence —
//! every K updates ([`OnlineConfig::publish_every`]) or T elapsed
//! ([`OnlineConfig::publish_interval`]) — freezes the trainer into a
//! fresh snapshot and hot-swaps it in via the caller-supplied publish
//! hook (which may also persist to the registry; see
//! [`PublishReport::durable`]).
//!
//! ## Determinism and durability
//!
//! Updates are applied strictly in channel-arrival order by one
//! thread, so a single client's feedback stream replays bit-identically
//! offline (`tests/online_feedback.rs`). With a WAL attached
//! ([`crate::registry::FeedbackWal`]), each event is logged *before*
//! it is applied and acked (WAL-first), so `kill -9` at any point
//! loses nothing: restart reloads the last durable snapshot, reseeds
//! the trainer's RNG streams to the same epoch ([`reseed_seed`]), and
//! replays the log — landing on the exact pre-crash machine. Durable
//! publishes sync the log, truncate it (the published snapshot owns
//! those updates), and advance the RNG epoch on both the live and the
//! restart path, keeping the two aligned.
//!
//! Truncation is *idempotent* with respect to the published version:
//! every WAL record is stamped with the registry version it is based
//! on, and [`replay_feedback`] skips records below the recovered
//! snapshot's version. A crash between the registry publish and the
//! truncate — or a truncate that outright fails — therefore cannot
//! double-apply updates the published snapshot already owns.
//!
//! Per-append durability is process-crash-only (OS page cache); the
//! sync at each durable publish bounds power-loss exposure to the
//! updates since the last publish, and `--wal-fsync`
//! ([`crate::registry::FeedbackWal::set_sync_on_append`]) closes even
//! that window.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::obs::{self, journal, EventKind, Stage};
use crate::registry::wal::{FeedbackRecord, FeedbackWal};
use crate::tm::trainer::Trainer;
use crate::util::BitVec;

/// Publish cadence and queue sizing for one route's learner.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OnlineConfig {
    /// Republish after this many applied updates (0 disables the
    /// count trigger).
    pub publish_every: u64,
    /// Republish when this much time has passed since the last publish
    /// and at least one update is pending (`None` disables the timer).
    pub publish_interval: Option<Duration>,
    /// Bound of the feedback channel; submissions beyond it are shed
    /// with [`FeedbackError::Overloaded`].
    pub queue_cap: usize,
    /// Size of the recent-accuracy drift window (predict-before-apply
    /// correctness over the last N examples).
    pub window: usize,
    /// fsync every WAL append before acking (`--wal-fsync`): feedback
    /// survives power loss, not just `kill -9`, at a per-event latency
    /// cost. Default off — the sync at each durable publish already
    /// bounds power-loss exposure to the since-last-publish window.
    pub wal_fsync: bool,
}

impl Default for OnlineConfig {
    fn default() -> Self {
        OnlineConfig {
            publish_every: 64,
            publish_interval: Some(Duration::from_millis(500)),
            queue_cap: 1024,
            window: 256,
            wal_fsync: false,
        }
    }
}

/// Why a feedback submission failed.
#[derive(Clone, Debug, PartialEq)]
pub enum FeedbackError {
    /// No route with that name.
    UnknownModel(String),
    /// The route has no online learner attached.
    Unsupported(String),
    /// Literal width does not match the model.
    WrongWidth {
        /// Literal width the model expects.
        expected: usize,
        /// Literal width the request carried.
        got: usize,
    },
    /// Label outside the model's class range.
    BadLabel {
        /// Number of classes the model has.
        classes: usize,
        /// Label the request carried.
        got: usize,
    },
    /// Shed: the feedback queue is full.
    Overloaded,
    /// The server is draining; no new feedback accepted.
    ShuttingDown,
    /// The learner refused the event (e.g. the WAL append failed).
    Rejected(String),
}

impl std::fmt::Display for FeedbackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FeedbackError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            FeedbackError::Unsupported(m) => {
                write!(f, "route '{m}' has no online learner (serve with --feedback)")
            }
            FeedbackError::WrongWidth { expected, got } => {
                write!(f, "literal width {got}, model expects {expected}")
            }
            FeedbackError::BadLabel { classes, got } => {
                write!(f, "label {got} out of range (model has {classes} classes)")
            }
            // keep the leading token machine-matchable as `err overloaded`
            FeedbackError::Overloaded => write!(f, "overloaded: feedback queue full"),
            FeedbackError::ShuttingDown => write!(f, "online learner shutting down"),
            FeedbackError::Rejected(why) => write!(f, "feedback rejected: {why}"),
        }
    }
}

impl std::error::Error for FeedbackError {}

/// What the publish hook did with the trainer's current machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PublishReport {
    /// Version of the snapshot now serving (publisher-scoped for plain
    /// hot swaps, registry version for durable publishes).
    pub version: u64,
    /// Route swap generation after the install — the cross-publisher
    /// monotonic key deploy checks watch.
    pub generation: u64,
    /// `true` when the publish persisted to the registry: the learner
    /// truncates the WAL and advances the RNG epoch to
    /// [`reseed_seed`]`(base_seed, version)`.
    pub durable: bool,
}

/// The caller-supplied publish hook: freeze the trainer into a
/// snapshot, install it (hot swap; optionally registry-persist), and
/// report what now serves. Invoked only from the learner thread.
pub type PublishFn = Box<dyn FnMut(&mut Trainer, u64) -> Result<PublishReport, String> + Send>;

/// Mix a durable publish version into the training seed: the RNG
/// epoch both the live learner (at each durable publish) and the
/// restart path (after recovering that version) reseed to, keeping
/// WAL replay draw-for-draw identical to the live run.
pub fn reseed_seed(base_seed: u64, version: u64) -> u64 {
    base_seed ^ version.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// What [`replay_feedback`] did with each recovered WAL record.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ReplaySummary {
    /// Records applied to the recovered trainer, in log order.
    pub applied: u64,
    /// Records stamped with a version below the recovered snapshot's:
    /// the published snapshot already owns these updates (the crash
    /// window between registry publish and WAL truncate), so replaying
    /// them would double-apply. Expected after such a crash — benign.
    pub stale: u64,
    /// Records with an out-of-range label or wrong literal width — a
    /// foreign or corrupt log. Never expected; surface to the operator
    /// before the log is truncated away.
    pub skipped: u64,
}

/// Apply replayed WAL records to a recovered trainer in log order
/// (the restart path, before serving resumes). `recovered_version` is
/// the registry version the trainer was recovered from: records
/// stamped below it are counted [`ReplaySummary::stale`] and skipped
/// (that snapshot already owns them — truncation idempotence);
/// records with an out-of-range label or wrong width (a foreign log)
/// are counted [`ReplaySummary::skipped`].
pub fn replay_feedback(
    trainer: &mut Trainer,
    records: &[FeedbackRecord],
    recovered_version: u64,
) -> ReplaySummary {
    let classes = trainer.tm.classes();
    let n_literals = trainer.tm.params.n_literals();
    let mut summary = ReplaySummary::default();
    for rec in records {
        if rec.version < recovered_version {
            summary.stale += 1;
            continue;
        }
        let label = rec.label as usize;
        if label >= classes || rec.literals.len() != n_literals {
            summary.skipped += 1;
            continue;
        }
        trainer.train_sample(&rec.literals, label);
        summary.applied += 1;
    }
    summary
}

struct FeedbackMsg {
    label: usize,
    literals: BitVec,
    resp: SyncSender<Result<(), FeedbackError>>,
}

enum Msg {
    Feedback(FeedbackMsg),
    /// Final-publish pending updates and exit ([`OnlineLearner::shutdown`]).
    Stop,
}

/// Cloneable submission handle ([`Coordinator::attach_learner`] stores
/// one per route; every [`CoordinatorHandle`] clone shares it).
///
/// [`Coordinator::attach_learner`]: crate::coordinator::Coordinator::attach_learner
/// [`CoordinatorHandle`]: crate::coordinator::CoordinatorHandle
#[derive(Clone)]
pub struct FeedbackSender {
    tx: SyncSender<Msg>,
    classes: usize,
    n_literals: usize,
    metrics: Arc<Metrics>,
}

impl FeedbackSender {
    /// Submit one labeled example and block until the learner has
    /// logged and applied it (applied-then-ack: an `Ok` here means the
    /// update is in the trainer — and in the WAL, when one is
    /// attached). Sheds with [`FeedbackError::Overloaded`] when the
    /// feedback queue is full.
    pub fn submit(&self, label: usize, literals: BitVec) -> Result<(), FeedbackError> {
        if literals.len() != self.n_literals {
            self.metrics.feedback_errors.fetch_add(1, Ordering::Relaxed);
            return Err(FeedbackError::WrongWidth {
                expected: self.n_literals,
                got: literals.len(),
            });
        }
        if label >= self.classes {
            self.metrics.feedback_errors.fetch_add(1, Ordering::Relaxed);
            return Err(FeedbackError::BadLabel {
                classes: self.classes,
                got: label,
            });
        }
        let (resp, ack) = sync_channel(1);
        let msg = Msg::Feedback(FeedbackMsg {
            label,
            literals,
            resp,
        });
        match self.tx.try_send(msg) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) => {
                self.metrics.feedback_errors.fetch_add(1, Ordering::Relaxed);
                return Err(FeedbackError::Overloaded);
            }
            Err(TrySendError::Disconnected(_)) => return Err(FeedbackError::ShuttingDown),
        }
        ack.recv().map_err(|_| FeedbackError::ShuttingDown)?
    }
}

/// One route's online learner: the single-writer thread plus its
/// submission channel. Keep this alive for the serve lifetime and
/// call [`OnlineLearner::shutdown`] on drain — it final-publishes any
/// pending updates before exiting.
pub struct OnlineLearner {
    tx: SyncSender<Msg>,
    sender: FeedbackSender,
    thread: JoinHandle<()>,
}

impl OnlineLearner {
    /// Spawn the learner thread for `route` around `trainer` (built
    /// with the indexed backend so feedback flows through the O(1)
    /// index maintenance hooks). `wal`, when given, receives every
    /// event before it is applied. `publish` installs cadence
    /// snapshots; `metrics` is the route's (shared with the serving
    /// workers).
    pub fn spawn(
        route: impl Into<String>,
        trainer: Trainer,
        wal: Option<FeedbackWal>,
        publish: PublishFn,
        metrics: Arc<Metrics>,
        cfg: OnlineConfig,
    ) -> OnlineLearner {
        let route = route.into();
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap.max(1));
        let sender = FeedbackSender {
            tx: tx.clone(),
            classes: trainer.tm.classes(),
            n_literals: trainer.tm.params.n_literals(),
            metrics: Arc::clone(&metrics),
        };
        let thread = std::thread::Builder::new()
            .name(format!("tmi-learner-{route}"))
            .spawn(move || learner_loop(route, trainer, wal, publish, metrics, cfg, rx))
            .expect("spawning learner thread");
        OnlineLearner { tx, sender, thread }
    }

    /// The route's submission handle (clone freely).
    pub fn sender(&self) -> FeedbackSender {
        self.sender.clone()
    }

    /// Stop the learner: pending queued feedback is still applied,
    /// pending updates are final-published, then the thread exits.
    pub fn shutdown(self) {
        let _ = self.tx.send(Msg::Stop);
        let _ = self.thread.join();
    }
}

fn learner_loop(
    route: String,
    mut trainer: Trainer,
    mut wal: Option<FeedbackWal>,
    mut publish: PublishFn,
    metrics: Arc<Metrics>,
    cfg: OnlineConfig,
    rx: Receiver<Msg>,
) {
    let base_seed = trainer.tm.params.seed;
    let mut window: VecDeque<bool> = VecDeque::with_capacity(cfg.window.max(1));
    let mut window_correct = 0u64;
    let mut since_publish = 0u64;
    let mut last_publish = Instant::now();
    // the recv timeout drives the interval trigger; poll at most every
    // 50 ms so a short interval is honored without a hot spin
    let tick = cfg
        .publish_interval
        .unwrap_or(Duration::from_millis(500))
        .min(Duration::from_millis(50))
        .max(Duration::from_millis(1));
    // `wal` is threaded through as a parameter (not captured): the
    // receive loop below also appends to it between publishes.
    let mut do_publish = |trainer: &mut Trainer,
                          wal: &mut Option<FeedbackWal>,
                          since: &mut u64,
                          last: &mut Instant| {
        if *since == 0 {
            return;
        }
        // durable-publish boundary: force the log to stable storage
        // before the registry publish, so across power loss every
        // update is owned by a published snapshot or a synced record.
        // A sync failure is journaled but doesn't block the publish —
        // the snapshot about to be published owns these updates.
        if let Some(w) = wal.as_mut() {
            if let Err(e) = w.sync() {
                journal().emit(EventKind::RouteFailed {
                    route: route.clone(),
                    error: format!("wal sync: {e}"),
                });
            }
        }
        match publish(trainer, *since) {
            Ok(rep) => {
                metrics.publishes.fetch_add(1, Ordering::Relaxed);
                metrics.publish_lag.store(0, Ordering::Relaxed);
                journal().emit(EventKind::FeedbackPublish {
                    route: route.clone(),
                    version: rep.version,
                    generation: rep.generation,
                    updates: *since,
                });
                *since = 0;
                *last = Instant::now();
                if rep.durable {
                    if let Some(w) = wal.as_mut() {
                        // advance the stamp *before* truncating: even
                        // if truncate fails (or we crash before it),
                        // records at the old stamp are below the
                        // published version and replay skips them —
                        // no double-apply, and the next durable
                        // publish retries the truncate.
                        w.set_version(rep.version);
                        if let Err(e) = w.truncate() {
                            journal().emit(EventKind::RouteFailed {
                                route: route.clone(),
                                error: format!("wal truncate: {e}"),
                            });
                        }
                    }
                    // advance the RNG epoch in lockstep with the
                    // restart path (which reseeds after recovering
                    // this version, then replays an empty log)
                    trainer.reseed_streams(reseed_seed(base_seed, rep.version));
                }
            }
            Err(e) => {
                // keep `since` pending: the next trigger retries
                journal().emit(EventKind::RouteFailed {
                    route: route.clone(),
                    error: format!("feedback publish: {e}"),
                });
            }
        }
    };
    loop {
        let msg = match rx.recv_timeout(tick) {
            Ok(msg) => msg,
            Err(RecvTimeoutError::Timeout) => {
                if let Some(interval) = cfg.publish_interval {
                    if since_publish > 0 && last_publish.elapsed() >= interval {
                        do_publish(&mut trainer, &mut wal, &mut since_publish, &mut last_publish);
                    }
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let fb = match msg {
            Msg::Feedback(fb) => fb,
            Msg::Stop => break,
        };
        let t0 = if obs::enabled() {
            Some(Instant::now())
        } else {
            None
        };
        // drift probe: score with the pre-update machine through the
        // per-class evaluators (no inference-engine rebuild, no RNG
        // draws — replay-neutral)
        let correct = trainer.predict_online(&fb.literals) == fb.label;
        if window.len() == cfg.window.max(1) {
            if window.pop_front() == Some(true) {
                window_correct -= 1;
            }
        }
        window.push_back(correct);
        if correct {
            window_correct += 1;
        }
        metrics.set_feedback_window(window_correct, window.len() as u64);
        // WAL-first: the event is durable before it mutates the model
        if let Some(w) = wal.as_mut() {
            if let Err(e) = w.append(fb.label as u32, &fb.literals) {
                metrics.feedback_errors.fetch_add(1, Ordering::Relaxed);
                let _ = fb.resp.send(Err(FeedbackError::Rejected(format!(
                    "wal append: {e}"
                ))));
                continue;
            }
        }
        trainer.train_sample(&fb.literals, fb.label);
        since_publish += 1;
        metrics.feedback_applied.fetch_add(1, Ordering::Relaxed);
        metrics.publish_lag.store(since_publish, Ordering::Relaxed);
        if let Some(t0) = t0 {
            metrics.record_stage(Stage::Feedback, t0.elapsed());
        }
        let _ = fb.resp.send(Ok(()));
        // evaluate BOTH triggers here, not just the count: under a
        // continuous stream the channel is never empty, the Timeout
        // arm never runs, and an interval-only cadence
        // (--publish-every 0) would otherwise never publish
        let count_due = cfg.publish_every > 0 && since_publish >= cfg.publish_every;
        let timer_due = cfg
            .publish_interval
            .is_some_and(|interval| last_publish.elapsed() >= interval);
        if count_due || timer_due {
            do_publish(&mut trainer, &mut wal, &mut since_publish, &mut last_publish);
        }
    }
    // drain-then-stop: final-publish whatever is pending so a clean
    // shutdown leaves nothing only-in-WAL
    do_publish(&mut trainer, &mut wal, &mut since_publish, &mut last_publish);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Backend;
    use crate::tm::io;
    use crate::tm::params::TMParams;
    use crate::util::Rng;
    use std::sync::Mutex;

    fn toy_trainer(seed: u64) -> Trainer {
        let params = TMParams::new(2, 10, 8).with_seed(seed);
        Trainer::new(params, Backend::Indexed)
    }

    fn toy_samples(n: usize, seed: u64) -> Vec<(BitVec, usize)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..8).map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect()
    }

    #[test]
    fn reseed_seed_is_version_sensitive() {
        assert_ne!(reseed_seed(7, 1), reseed_seed(7, 2));
        assert_eq!(reseed_seed(7, 3), reseed_seed(7, 3));
        // version 0 is the identity epoch
        assert_eq!(reseed_seed(7, 0), 7);
    }

    #[test]
    fn online_feedback_matches_offline_training() {
        // the in-module differential check (the deep one, over TCP,
        // is tests/online_feedback.rs): N submissions through the
        // learner == the same samples through a plain Trainer
        let samples = toy_samples(120, 11);
        let mut offline = toy_trainer(5);
        for (l, y) in &samples {
            offline.train_sample(l, *y);
        }
        let metrics = Arc::new(Metrics::new());
        let published: Arc<Mutex<Vec<(u64, u32)>>> = Arc::new(Mutex::new(Vec::new()));
        let log = Arc::clone(&published);
        let publish: PublishFn = Box::new(move |tr, updates| {
            let snap = tr.publish();
            log.lock().unwrap().push((updates, io::model_digest(&tr.tm)));
            Ok(PublishReport {
                version: snap.version(),
                generation: 0,
                durable: false,
            })
        });
        let learner = OnlineLearner::spawn(
            "toy",
            toy_trainer(5),
            None,
            publish,
            Arc::clone(&metrics),
            OnlineConfig {
                publish_every: 50,
                publish_interval: None,
                ..OnlineConfig::default()
            },
        );
        let sender = learner.sender();
        for (l, y) in &samples {
            sender.submit(*y, l.clone()).unwrap();
        }
        learner.shutdown();
        let s = metrics.snapshot();
        assert_eq!(s.feedback_applied, 120);
        assert_eq!(s.feedback_errors, 0);
        // 120 updates at publish_every=50: two cadence publishes plus
        // the final drain publish of the remaining 20
        let pubs = published.lock().unwrap();
        assert_eq!(pubs.iter().map(|(u, _)| *u).collect::<Vec<_>>(), vec![50, 50, 20]);
        // the last published state is bit-identical to replaying the
        // same arrival order through a plain offline trainer
        assert_eq!(pubs.last().unwrap().1, io::model_digest(&offline.tm));
        assert_eq!(s.publishes, 3);
        assert!(s.feedback_window_len > 0);
    }

    #[test]
    fn submit_validates_label_and_width() {
        let metrics = Arc::new(Metrics::new());
        let publish: PublishFn = Box::new(|tr, _| {
            let snap = tr.publish();
            Ok(PublishReport {
                version: snap.version(),
                generation: 0,
                durable: false,
            })
        });
        let learner = OnlineLearner::spawn(
            "toy",
            toy_trainer(5),
            None,
            publish,
            Arc::clone(&metrics),
            OnlineConfig::default(),
        );
        let sender = learner.sender();
        assert!(matches!(
            sender.submit(9, BitVec::zeros(16)),
            Err(FeedbackError::BadLabel { classes: 2, got: 9 })
        ));
        assert!(matches!(
            sender.submit(0, BitVec::zeros(4)),
            Err(FeedbackError::WrongWidth { expected: 16, got: 4 })
        ));
        assert!(sender.submit(0, BitVec::zeros(16)).is_ok());
        learner.shutdown();
        let s = metrics.snapshot();
        assert_eq!(s.feedback_errors, 2);
        assert_eq!(s.feedback_applied, 1);
        // submissions after shutdown shed instead of hanging
        assert!(matches!(
            sender.submit(0, BitVec::zeros(16)),
            Err(FeedbackError::ShuttingDown)
        ));
    }

    #[test]
    fn replay_applies_records_in_order_and_skips_foreign() {
        let samples = toy_samples(40, 13);
        let mut offline = toy_trainer(5);
        for (l, y) in &samples {
            offline.train_sample(l, *y);
        }
        let mut recovered = toy_trainer(5);
        let mut records: Vec<FeedbackRecord> = samples
            .iter()
            .map(|(l, y)| FeedbackRecord {
                version: 1,
                label: *y as u32,
                literals: l.clone(),
            })
            .collect();
        // a foreign record (bad width) must be skipped, not applied
        records.push(FeedbackRecord {
            version: 1,
            label: 0,
            literals: BitVec::zeros(4),
        });
        let summary = replay_feedback(&mut recovered, &records, 1);
        assert_eq!(
            summary,
            ReplaySummary {
                applied: 40,
                stale: 0,
                skipped: 1
            }
        );
        for c in 0..2 {
            assert_eq!(
                offline.tm.bank(c).states(),
                recovered.tm.bank(c).states(),
                "class {c} diverged after replay"
            );
        }
    }

    #[test]
    fn replay_skips_records_owned_by_the_recovered_snapshot() {
        // the crash window between registry publish and WAL truncate:
        // the log still holds records the published snapshot already
        // owns (stamped with the *previous* version). Replay against
        // the recovered version must skip them — applying them again
        // would silently produce a different machine — while records
        // stamped at the recovered version still apply, in order.
        let samples = toy_samples(30, 17);
        let (owned, fresh) = samples.split_at(20);
        let mut records: Vec<FeedbackRecord> = owned
            .iter()
            .map(|(l, y)| FeedbackRecord {
                version: 1, // based on v1, folded into the published v2
                label: *y as u32,
                literals: l.clone(),
            })
            .collect();
        records.extend(fresh.iter().map(|(l, y)| FeedbackRecord {
            version: 2, // appended after v2 published: not yet owned
            label: *y as u32,
            literals: l.clone(),
        }));
        let mut offline = toy_trainer(5);
        for (l, y) in fresh {
            offline.train_sample(l, *y);
        }
        let mut recovered = toy_trainer(5);
        let summary = replay_feedback(&mut recovered, &records, 2);
        assert_eq!(
            summary,
            ReplaySummary {
                applied: 10,
                stale: 20,
                skipped: 0
            }
        );
        for c in 0..2 {
            assert_eq!(
                offline.tm.bank(c).states(),
                recovered.tm.bank(c).states(),
                "class {c} diverged: a stale record was double-applied"
            );
        }
        // idempotence: replaying a fully-owned log is a no-op
        let before: Vec<Vec<i8>> = (0..2).map(|c| recovered.tm.bank(c).states()).collect();
        let summary = replay_feedback(&mut recovered, &records[..20], 2);
        assert_eq!(summary.applied, 0);
        assert_eq!(summary.stale, 20);
        for c in 0..2 {
            assert_eq!(recovered.tm.bank(c).states(), before[c]);
        }
    }

    #[test]
    fn interval_trigger_fires_under_a_continuous_stream() {
        // regression: with --publish-every 0 (interval-only cadence)
        // and a stream that keeps the channel busy, the Timeout arm of
        // the receive loop never runs — the interval must also be
        // checked on the apply path or the learner never publishes
        let metrics = Arc::new(Metrics::new());
        let publish: PublishFn = Box::new(|tr, _| {
            let snap = tr.publish();
            Ok(PublishReport {
                version: snap.version(),
                generation: 0,
                durable: false,
            })
        });
        let learner = OnlineLearner::spawn(
            "toy",
            toy_trainer(5),
            None,
            publish,
            Arc::clone(&metrics),
            OnlineConfig {
                publish_every: 0,
                publish_interval: Some(Duration::from_millis(10)),
                ..OnlineConfig::default()
            },
        );
        let sender = learner.sender();
        let samples = toy_samples(16, 19);
        // submit back-to-back (each ack returns in far less than the
        // 10 ms interval, so the channel stays hot) for ~6 intervals
        let start = Instant::now();
        while start.elapsed() < Duration::from_millis(60) {
            for (l, y) in &samples {
                sender.submit(*y, l.clone()).unwrap();
            }
        }
        learner.shutdown();
        let s = metrics.snapshot();
        // at least one cadence publish beyond the final drain publish
        assert!(
            s.publishes >= 2,
            "interval-only cadence never published under load (publishes={})",
            s.publishes
        );
    }
}
