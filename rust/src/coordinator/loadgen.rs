//! TCP load generator for the serving front end (`tmi loadgen`).
//!
//! Drives the line protocol ([`crate::coordinator::server::serve_tcp`])
//! in either of the two canonical load-testing disciplines:
//!
//! * **closed loop** (`rate == 0`): each connection keeps exactly one
//!   request in flight — send, wait for the reply, send the next.
//!   Throughput is latency-bound; this measures the server's capacity
//!   at a fixed concurrency.
//! * **open loop** (`rate > 0`): each connection sends on a fixed
//!   schedule (`rate / connections` requests per second per
//!   connection) regardless of replies, with a separate reader thread
//!   matching replies in order. This is the arrival-process model that
//!   exposes queueing: when the offered rate exceeds capacity the
//!   server must *shed* (`err overloaded`), and the shed rate is the
//!   headline number.
//!
//! Latency is measured client-side per request (write → reply line)
//! and reported as exact sorted quantiles — unlike the server's
//! power-of-two histogram, the client holds every sample. Results
//! serialize to the repo's `BENCH_serve.json` perf-trajectory format
//! via [`LoadgenReport::to_json`].

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::{Json, Rng};

/// What to offer the server.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// `host:port` of a running `tmi serve`.
    pub addr: String,
    /// Multi-target mode (`--targets a,b,...`): the cluster endpoints
    /// to spread connections across. Empty means single-target
    /// ([`LoadgenConfig::addr`] only). Closed-loop connections fail
    /// over to the next target when their node dies mid-run — the
    /// cluster smoke test kills a node under load and gates on the
    /// surviving ok-rate; open-loop connections pin to their assigned
    /// target (the fixed-schedule writer cannot re-home mid-flight
    /// without skewing the offered rate).
    pub targets: Vec<String>,
    /// Route name to drive (`infer <model> <bits>`).
    pub model: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Total offered request rate in req/s across all connections;
    /// `0.0` selects the closed loop.
    pub rate: f64,
    /// How long to drive load.
    pub duration: Duration,
    /// Raw feature width of the model (the protocol sends feature
    /// bits; the server derives `[x, ¬x]`).
    pub features: usize,
    /// Seed for the request-pattern RNG.
    pub seed: u64,
    /// Fraction of requests sent as `feedback <model> <label> <bits>`
    /// (online learning); the rest stay `infer`. `0.0` disables the
    /// mixed phase. Needs a server running `--feedback`.
    pub feedback_rate: f64,
    /// Label range for synthetic feedback (`below(classes)`).
    pub classes: usize,
}

/// Aggregated client-side results of one run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// `"closed"` or `"open"` loop discipline.
    pub mode: &'static str,
    /// Requests sent.
    pub sent: u64,
    /// `ok` replies received.
    pub ok: u64,
    /// `err overloaded` replies (admission sheds).
    pub shed: u64,
    /// Other `err` replies plus transport failures.
    pub errors: u64,
    /// Wall-clock duration of the run in seconds.
    pub elapsed_s: f64,
    /// Completed (ok) replies per second.
    pub throughput_rps: f64,
    /// Fraction of sent requests that were shed.
    pub shed_rate: f64,
    /// Client-observed p50 latency, microseconds.
    pub p50_us: u64,
    /// Client-observed p95 latency, microseconds.
    pub p95_us: u64,
    /// Client-observed p99 latency, microseconds.
    pub p99_us: u64,
    /// Client-observed mean latency, microseconds.
    pub mean_us: f64,
    /// Feedback requests written / acknowledged `ok` (mixed phase).
    pub feedback_sent: u64,
    /// `ok applied=` feedback acks received.
    pub feedback_ok: u64,
    /// Torn replies: a reply line with no terminating newline, or one
    /// that is neither `ok …` nor `err …` — a reader observed a
    /// half-written response. Must be zero under hot swap.
    pub torn: u64,
    /// Closed-loop connections re-homed to another target after their
    /// node died (multi-target mode only).
    pub failovers: u64,
    /// Route swap generation from `stats` before/after the run — the
    /// cross-publisher monotonic key (`--assert-monotone-generations`).
    pub generation_start: Option<u64>,
    /// Route swap generation after the run (from `stats`).
    pub generation_end: Option<u64>,
    /// The server's own `stats <model>` line, fetched after the run.
    pub server_stats: Option<String>,
}

/// Per-connection tallies.
#[derive(Default)]
struct ConnResult {
    sent: u64,
    ok: u64,
    shed: u64,
    errors: u64,
    feedback_sent: u64,
    feedback_ok: u64,
    torn: u64,
    failovers: u64,
    latencies_us: Vec<u64>,
}

impl ConnResult {
    fn classify(&mut self, reply: &str, t0: Instant, feedback: bool) {
        self.sent += 1;
        if feedback {
            self.feedback_sent += 1;
        }
        // a reply without its newline (EOF mid-line) or with neither
        // protocol prefix is torn: the reader saw a half-written
        // response. Counted inside `errors` so the answered invariant
        // (ok + shed + errors) is unchanged.
        if !reply.ends_with('\n') {
            self.torn += 1;
            self.errors += 1;
            return;
        }
        if reply.starts_with("ok ") {
            self.ok += 1;
            if feedback {
                self.feedback_ok += 1;
            }
            // only completed requests contribute latency samples
            self.latencies_us
                .push(t0.elapsed().as_micros().min(u64::MAX as u128) as u64);
        } else if reply.starts_with("err overloaded") {
            self.shed += 1;
        } else if reply.starts_with("err ") {
            self.errors += 1;
        } else {
            self.torn += 1;
            self.errors += 1;
        }
    }
}

/// One pre-rendered request: the wire line and whether it is a
/// feedback submission (for the split tallies).
type PoolEntry = (String, bool);

/// Pre-render a pool of distinct request lines (cycled per send) so
/// the hot loop does no formatting. With `feedback_rate > 0` the pool
/// mixes `feedback` lines at that fraction (deterministic per seed).
fn request_pool(cfg: &LoadgenConfig) -> Vec<PoolEntry> {
    let mut rng = Rng::new(cfg.seed);
    (0..32)
        .map(|_| {
            let bits: String = (0..cfg.features)
                .map(|_| if rng.bern(0.5) { '1' } else { '0' })
                .collect();
            if cfg.feedback_rate > 0.0 && rng.bern(cfg.feedback_rate.clamp(0.0, 1.0)) {
                let label = rng.below(cfg.classes.max(1) as u32);
                (format!("feedback {} {} {}\n", cfg.model, label, bits), true)
            } else {
                (format!("infer {} {}\n", cfg.model, bits), false)
            }
        })
        .collect()
}

/// Connect to the first target that answers, starting at `first` and
/// walking the list once. `None` when every target refused.
fn connect_any(targets: &[String], first: usize) -> Option<(TcpStream, usize)> {
    for k in 0..targets.len() {
        let idx = (first + k) % targets.len();
        if let Ok(stream) = TcpStream::connect(&targets[idx]) {
            stream.set_nodelay(true).ok();
            // a wedged server must fail the run, not hang it (CI gates
            // on this)
            if stream.set_read_timeout(Some(Duration::from_secs(5))).is_ok() {
                return Some((stream, idx));
            }
        }
    }
    None
}

fn closed_loop_conn(
    targets: &[String],
    first: usize,
    pool: &[PoolEntry],
    stop_at: Instant,
) -> Result<ConnResult> {
    let multi = targets.len() > 1;
    let mut res = ConnResult::default();
    let mut reply = String::new();
    let mut i = 0usize;
    let mut target = first % targets.len().max(1);
    let mut connected_once = false;
    'conn: while Instant::now() < stop_at {
        let Some((stream, idx)) = connect_any(targets, target) else {
            if !multi {
                if connected_once {
                    break; // single-target: server gone, run ends
                }
                // single-target and never up: surface the connect error
                // like the pre-cluster loadgen did
                TcpStream::connect(&targets[0])
                    .with_context(|| format!("connecting {}", targets[0]))?;
            }
            // every target down right now: brief pause, then retry
            // until the deadline — a restarted node picks the run up
            std::thread::sleep(Duration::from_millis(20));
            continue;
        };
        if connected_once {
            res.failovers += 1;
        }
        connected_once = true;
        target = idx;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut stream = stream;
        while Instant::now() < stop_at {
            let (line, feedback) = &pool[i % pool.len()];
            i += 1;
            let t0 = Instant::now();
            if stream.write_all(line.as_bytes()).is_err() {
                // connection died before the request was accepted:
                // nothing to classify — the request was never answered
                if multi {
                    target += 1;
                    continue 'conn;
                }
                break 'conn;
            }
            reply.clear();
            match reader.read_line(&mut reply) {
                Ok(0) | Err(_) => {
                    if multi {
                        target += 1;
                        continue 'conn;
                    }
                    break 'conn;
                }
                Ok(_) if multi && !reply.ends_with('\n') => {
                    // EOF cut the reply line: the node died mid-write.
                    // That is a connection failure, not a tear served
                    // by a live node — re-home and retry (requests in
                    // the pool are idempotent infer unless the caller
                    // opted into feedback, where a lost in-flight
                    // apply is simply not re-counted).
                    target += 1;
                    continue 'conn;
                }
                Ok(_) => res.classify(&reply, t0, *feedback),
            }
        }
        break;
    }
    Ok(res)
}

fn open_loop_conn(
    addr: &str,
    pool: &[PoolEntry],
    stop_at: Instant,
    interval: Duration,
) -> Result<ConnResult> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    // generous read timeout: the reader must notice a dead server
    // instead of blocking forever after the writer stops
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let reader_stream = stream.try_clone()?;
    let (tx, rx) = channel::<(Instant, bool)>();
    let reader = std::thread::spawn(move || {
        let mut reader = BufReader::new(reader_stream);
        let mut res = ConnResult::default();
        let mut reply = String::new();
        // one reply per recorded send, in order (the protocol is
        // strictly request-ordered per connection)
        while let Ok((t0, feedback)) = rx.recv() {
            reply.clear();
            match reader.read_line(&mut reply) {
                Ok(0) | Err(_) => break,
                Ok(_) => res.classify(&reply, t0, feedback),
            }
        }
        res
    });
    let mut stream_w = stream;
    let mut i = 0usize;
    let mut feedback_writes = 0u64;
    let mut next = Instant::now();
    while Instant::now() < stop_at {
        let (line, feedback) = &pool[i % pool.len()];
        let t0 = Instant::now();
        if stream_w.write_all(line.as_bytes()).is_err() {
            break;
        }
        i += 1;
        if *feedback {
            feedback_writes += 1;
        }
        let _ = tx.send((t0, *feedback));
        next += interval;
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        // behind schedule: send immediately (the offered rate is the
        // schedule; falling behind is the measurement, not an error)
    }
    drop(tx); // reader drains outstanding replies, then exits
    let mut res = reader.join().expect("open-loop reader panicked");
    // replies never received (server shed the connection or timed out)
    // count as neither ok nor shed; sent reflects writes
    res.sent = i as u64;
    res.feedback_sent = feedback_writes;
    Ok(res)
}

/// The endpoint list a run drives: `--targets` when given, else the
/// single `addr`.
fn endpoints(cfg: &LoadgenConfig) -> Vec<String> {
    if cfg.targets.is_empty() {
        vec![cfg.addr.clone()]
    } else {
        cfg.targets.clone()
    }
}

/// Fetch `stats <model>` from the first endpoint that answers.
fn fetch_stats_any(targets: &[String], model: &str) -> Option<String> {
    targets.iter().find_map(|t| fetch_server_stats(t, model))
}

/// Fetch the server-side `stats <model>` line over a fresh connection.
fn fetch_server_stats(addr: &str, model: &str) -> Option<String> {
    let stream = TcpStream::connect(addr).ok()?;
    stream
        .set_read_timeout(Some(Duration::from_secs(2)))
        .ok()?;
    let mut reader = BufReader::new(stream.try_clone().ok()?);
    let mut stream = stream;
    stream
        .write_all(format!("stats {model}\n").as_bytes())
        .ok()?;
    let mut line = String::new();
    reader.read_line(&mut line).ok()?;
    let line = line.trim();
    (!line.is_empty()).then(|| line.to_string())
}

/// Parse the server's extended `stats` line (`k=v` tokens) into the
/// per-stage BENCH_serve.json breakdown: one object per pipeline stage
/// (queue / batch / score / write) with its p50/p95/p99, plus the
/// route's `index_efficiency`. Returns `None` when the line predates
/// the observability keys, so old baselines still parse.
fn stage_breakdown(stats: &str) -> Option<Json> {
    let kv: std::collections::HashMap<&str, &str> = stats
        .split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .collect();
    let num = |key: String| kv.get(key.as_str()).and_then(|v| v.parse::<f64>().ok());
    let mut fields: Vec<(&'static str, Json)> = Vec::new();
    for stage in ["queue", "batch", "score", "write"] {
        fields.push((
            stage,
            Json::obj([
                ("p50_us", Json::num(num(format!("{stage}_p50_us"))?)),
                ("p95_us", Json::num(num(format!("{stage}_p95_us"))?)),
                ("p99_us", Json::num(num(format!("{stage}_p99_us"))?)),
            ]),
        ));
    }
    fields.push((
        "index_efficiency",
        Json::num(num("index_efficiency".to_string())?),
    ));
    Some(Json::obj(fields))
}

/// Extract the route swap generation from a `stats` line (`None` on
/// `generation=-`, i.e. a factory route, or a missing/unparsable key).
fn parse_generation(stats: Option<&str>) -> Option<u64> {
    stats?
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix("generation="))
        .and_then(|v| v.parse().ok())
}

/// Nearest-rank quantile: the smallest sample with at least `q` of
/// the mass at or below it (0 on an empty set).
fn quantile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Run the configured load against a live server.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(cfg.connections > 0, "need at least one connection");
    anyhow::ensure!(cfg.features > 0, "need the model's feature width");
    anyhow::ensure!(
        (0.0..=1.0).contains(&cfg.feedback_rate),
        "feedback rate must be within [0, 1]"
    );
    let pool = request_pool(cfg);
    let targets = endpoints(cfg);
    let generation_start = parse_generation(fetch_stats_any(&targets, &cfg.model).as_deref());
    let open_loop = cfg.rate > 0.0;
    let interval = if open_loop {
        Duration::from_secs_f64(cfg.connections as f64 / cfg.rate)
    } else {
        Duration::ZERO
    };
    let t0 = Instant::now();
    let stop_at = t0 + cfg.duration;
    let workers: Vec<_> = (0..cfg.connections)
        .map(|i| {
            let targets = targets.clone();
            let pool = pool.clone();
            std::thread::spawn(move || {
                if open_loop {
                    // open loop pins each connection to its target: a
                    // fixed-schedule writer cannot re-home mid-flight
                    // without skewing the offered rate
                    open_loop_conn(&targets[i % targets.len()], &pool, stop_at, interval)
                } else {
                    closed_loop_conn(&targets, i, &pool, stop_at)
                }
            })
        })
        .collect();
    let mut total = ConnResult::default();
    for w in workers {
        let r = w.join().expect("loadgen connection panicked")?;
        total.sent += r.sent;
        total.ok += r.ok;
        total.shed += r.shed;
        total.errors += r.errors;
        total.feedback_sent += r.feedback_sent;
        total.feedback_ok += r.feedback_ok;
        total.torn += r.torn;
        total.failovers += r.failovers;
        total.latencies_us.extend(r.latencies_us);
    }
    let elapsed_s = t0.elapsed().as_secs_f64();
    total.latencies_us.sort_unstable();
    let answered = total.ok + total.shed + total.errors;
    let mean_us = if total.latencies_us.is_empty() {
        0.0
    } else {
        total.latencies_us.iter().sum::<u64>() as f64 / total.latencies_us.len() as f64
    };
    let server_stats = fetch_stats_any(&targets, &cfg.model);
    Ok(LoadgenReport {
        mode: if open_loop { "open" } else { "closed" },
        sent: total.sent,
        ok: total.ok,
        shed: total.shed,
        errors: total.errors,
        elapsed_s,
        throughput_rps: if elapsed_s > 0.0 {
            total.ok as f64 / elapsed_s
        } else {
            0.0
        },
        shed_rate: if answered == 0 {
            0.0
        } else {
            total.shed as f64 / answered as f64
        },
        p50_us: quantile(&total.latencies_us, 0.5),
        p95_us: quantile(&total.latencies_us, 0.95),
        p99_us: quantile(&total.latencies_us, 0.99),
        mean_us,
        feedback_sent: total.feedback_sent,
        feedback_ok: total.feedback_ok,
        torn: total.torn,
        failovers: total.failovers,
        generation_start,
        generation_end: parse_generation(server_stats.as_deref()),
        server_stats,
    })
}

impl LoadgenReport {
    /// One human line per run (the CLI prints this).
    pub fn summary(&self) -> String {
        let mut line = format!(
            "{} loop: {:.0} ok/s over {:.1}s  sent={} ok={} shed={} errors={} torn={} \
             shed_rate={:.4}  latency p50={}us p95={}us p99={}us mean={:.0}us",
            self.mode,
            self.throughput_rps,
            self.elapsed_s,
            self.sent,
            self.ok,
            self.shed,
            self.errors,
            self.torn,
            self.shed_rate,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us,
        );
        if self.failovers > 0 {
            line.push_str(&format!(" failovers={}", self.failovers));
        }
        if self.feedback_sent > 0 {
            line.push_str(&format!(
                "  feedback={}/{} generation {}->{}",
                self.feedback_ok,
                self.feedback_sent,
                self.generation_start
                    .map(|g| g.to_string())
                    .unwrap_or_else(|| "-".into()),
                self.generation_end
                    .map(|g| g.to_string())
                    .unwrap_or_else(|| "-".into()),
            ));
        }
        line
    }

    /// The `BENCH_serve.json` payload for this run.
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        Json::obj([
            ("bench", Json::str("serve_load")),
            ("mode", Json::str(self.mode)),
            (
                "config",
                Json::obj([
                    ("model", Json::str(cfg.model.clone())),
                    ("connections", Json::num(cfg.connections as f64)),
                    ("rate_rps", Json::num(cfg.rate)),
                    ("duration_s", Json::num(cfg.duration.as_secs_f64())),
                    ("features", Json::num(cfg.features as f64)),
                    ("feedback_rate", Json::num(cfg.feedback_rate)),
                    (
                        "targets",
                        Json::Arr(cfg.targets.iter().cloned().map(Json::str).collect()),
                    ),
                ]),
            ),
            ("sent", Json::num(self.sent as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("torn", Json::num(self.torn as f64)),
            ("failovers", Json::num(self.failovers as f64)),
            ("feedback_sent", Json::num(self.feedback_sent as f64)),
            ("feedback_ok", Json::num(self.feedback_ok as f64)),
            (
                "generation_start",
                self.generation_start.map(|g| Json::num(g as f64)).unwrap_or(Json::Null),
            ),
            (
                "generation_end",
                self.generation_end.map(|g| Json::num(g as f64)).unwrap_or(Json::Null),
            ),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("shed_rate", Json::num(self.shed_rate)),
            (
                "latency_us",
                Json::obj([
                    ("p50", Json::num(self.p50_us as f64)),
                    ("p95", Json::num(self.p95_us as f64)),
                    ("p99", Json::num(self.p99_us as f64)),
                    ("mean", Json::num(self.mean_us)),
                ]),
            ),
            (
                "server_stats",
                match &self.server_stats {
                    Some(s) => Json::str(s.clone()),
                    None => Json::Null,
                },
            ),
            (
                "server_stages",
                self.server_stats
                    .as_deref()
                    .and_then(stage_breakdown)
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_use_nearest_rank() {
        assert_eq!(quantile(&[], 0.5), 0);
        assert_eq!(quantile(&[7], 0.99), 7);
        assert_eq!(quantile(&[7], 0.0), 7);
        let v: Vec<u64> = (1..=100).collect();
        // nearest rank on 1..=100: ceil(q*100) is the value itself
        assert_eq!(quantile(&v, 0.5), 50);
        assert_eq!(quantile(&v, 0.95), 95);
        assert_eq!(quantile(&v, 0.99), 99);
        assert_eq!(quantile(&v, 1.0), 100);
        let odd: Vec<u64> = vec![10, 20, 30];
        assert_eq!(quantile(&odd, 0.5), 20);
        assert_eq!(quantile(&odd, 0.99), 30);
    }

    #[test]
    fn pool_lines_are_wellformed_and_deterministic() {
        let cfg = LoadgenConfig {
            addr: "unused".into(),
            targets: vec![],
            model: "cpu".into(),
            connections: 1,
            rate: 0.0,
            duration: Duration::from_secs(1),
            features: 12,
            seed: 7,
            feedback_rate: 0.0,
            classes: 2,
        };
        let a = request_pool(&cfg);
        let b = request_pool(&cfg);
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        for (line, feedback) in &a {
            assert!(!feedback);
            assert!(line.starts_with("infer cpu "));
            assert!(line.ends_with('\n'));
            let bits = line.trim_end().rsplit(' ').next().unwrap();
            assert_eq!(bits.len(), 12);
            assert!(bits.chars().all(|c| c == '0' || c == '1'));
        }
    }

    #[test]
    fn pool_mixes_feedback_lines_at_the_configured_rate() {
        let cfg = LoadgenConfig {
            addr: "unused".into(),
            targets: vec![],
            model: "cpu".into(),
            connections: 1,
            rate: 0.0,
            duration: Duration::from_secs(1),
            features: 6,
            seed: 3,
            feedback_rate: 0.5,
            classes: 4,
        };
        let pool = request_pool(&cfg);
        assert_eq!(pool, request_pool(&cfg), "pool must stay deterministic");
        let feedback: Vec<&PoolEntry> = pool.iter().filter(|(_, f)| *f).collect();
        // at rate 0.5 over 32 draws, both kinds must appear
        assert!(!feedback.is_empty());
        assert!(feedback.len() < pool.len());
        for (line, _) in &feedback {
            assert!(line.starts_with("feedback cpu "));
            assert!(line.ends_with('\n'));
            let mut tok = line.trim_end().split(' ').skip(2);
            let label: usize = tok.next().unwrap().parse().unwrap();
            assert!(label < 4);
            let bits = tok.next().unwrap();
            assert_eq!(bits.len(), 6);
            assert!(tok.next().is_none());
        }
    }

    #[test]
    fn torn_and_protocol_replies_are_classified() {
        let mut res = ConnResult::default();
        let t0 = Instant::now();
        res.classify("ok 1 scores=...\n", t0, false);
        res.classify("ok applied=1\n", t0, true);
        res.classify("err overloaded: queue full\n", t0, false);
        res.classify("err unknown model 'x'\n", t0, false);
        res.classify("ok 1 sco", t0, false); // EOF mid-reply: torn
        res.classify("garbage\n", t0, false); // no protocol prefix: torn
        assert_eq!(res.sent, 6);
        assert_eq!(res.ok, 2);
        assert_eq!(res.shed, 1);
        assert_eq!(res.errors, 3); // unknown-model + both torn
        assert_eq!(res.torn, 2);
        assert_eq!((res.feedback_sent, res.feedback_ok), (1, 1));
        assert_eq!(res.ok + res.shed + res.errors, res.sent);
    }

    #[test]
    fn closed_loop_fails_over_when_its_node_dies() {
        use std::net::TcpListener;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        // node A answers one request, then slams the connection shut;
        // node B answers everything
        let spawn_node = |answers: Option<usize>| {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let served = Arc::new(AtomicUsize::new(0));
            let served2 = Arc::clone(&served);
            std::thread::spawn(move || {
                while let Ok((stream, _)) = listener.accept() {
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut stream = stream;
                    let mut line = String::new();
                    let mut n = 0usize;
                    loop {
                        line.clear();
                        if reader.read_line(&mut line).unwrap_or(0) == 0 {
                            break;
                        }
                        if answers.is_some_and(|cap| n >= cap) {
                            break; // die mid-conversation
                        }
                        stream.write_all(b"ok 1 5\n").unwrap();
                        served2.fetch_add(1, Ordering::SeqCst);
                        n += 1;
                    }
                }
            });
            (addr, served)
        };
        let (addr_a, _served_a) = spawn_node(Some(1));
        let (addr_b, served_b) = spawn_node(None);
        let targets = vec![addr_a, addr_b];
        let pool = vec![("infer cpu 1\n".to_string(), false)];
        let stop_at = Instant::now() + Duration::from_millis(300);
        let res = closed_loop_conn(&targets, 0, &pool, stop_at).unwrap();
        assert!(res.failovers >= 1, "node A's death must re-home the connection");
        assert_eq!(res.torn, 0, "a died connection is not a torn reply");
        assert_eq!(res.errors, 0);
        assert!(res.ok > 1, "the run must continue on node B");
        assert!(served_b.load(Ordering::SeqCst) > 0);
    }

    #[test]
    fn endpoints_prefer_targets_over_addr() {
        let mut cfg = LoadgenConfig {
            addr: "a:1".into(),
            targets: vec![],
            model: "cpu".into(),
            connections: 1,
            rate: 0.0,
            duration: Duration::from_secs(1),
            features: 4,
            seed: 1,
            feedback_rate: 0.0,
            classes: 2,
        };
        assert_eq!(endpoints(&cfg), vec!["a:1".to_string()]);
        cfg.targets = vec!["n1:1".into(), "n2:2".into()];
        assert_eq!(endpoints(&cfg), vec!["n1:1".to_string(), "n2:2".to_string()]);
    }

    #[test]
    fn generation_parses_from_stats_line() {
        assert_eq!(
            parse_generation(Some("ok model=cpu version=3 generation=7 requests=1")),
            Some(7)
        );
        assert_eq!(parse_generation(Some("ok model=cpu generation=-")), None);
        assert_eq!(parse_generation(Some("ok model=cpu requests=1")), None);
        assert_eq!(parse_generation(None), None);
    }

    #[test]
    fn stage_breakdown_parses_extended_stats_only() {
        // a line predating the observability keys yields no breakdown
        assert!(stage_breakdown("ok model=cpu requests=5 p99_us=10").is_none());
        let line = "ok model=cpu version=1 generation=0 requests=5 completed=5 shed=0 \
                    errors=0 restarts=0 queue_depth=0 batches=5 mean_batch=1.00 p50_us=64 \
                    p95_us=128 p99_us=128 uptime_s=3 dense_requests=4 sparse_requests=1 \
                    index_efficiency=0.8125 queue_p50_us=32 queue_p95_us=64 queue_p99_us=64 \
                    batch_p50_us=8 batch_p95_us=16 batch_p99_us=16 score_p50_us=16 \
                    score_p95_us=32 score_p99_us=32 write_p50_us=4 write_p95_us=8 \
                    write_p99_us=8";
        let j = stage_breakdown(line).expect("extended line must parse");
        assert_eq!(j.get("queue").unwrap().get("p50_us").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("score").unwrap().get("p99_us").unwrap().as_usize(), Some(32));
        assert_eq!(j.get("write").unwrap().get("p95_us").unwrap().as_usize(), Some(8));
        let eff = j.get("index_efficiency").unwrap().as_f64().unwrap();
        assert!((eff - 0.8125).abs() < 1e-12);
        // one missing stage key disqualifies the whole breakdown
        let truncated = line.rsplit_once(" write_p99_us=").unwrap().0;
        assert!(stage_breakdown(truncated).is_none());
    }

    #[test]
    fn report_json_shape() {
        let cfg = LoadgenConfig {
            addr: "unused".into(),
            targets: vec![],
            model: "cpu".into(),
            connections: 2,
            rate: 100.0,
            duration: Duration::from_secs(2),
            features: 8,
            seed: 1,
            feedback_rate: 0.25,
            classes: 2,
        };
        let report = LoadgenReport {
            mode: "open",
            sent: 10,
            ok: 8,
            shed: 2,
            errors: 0,
            elapsed_s: 2.0,
            throughput_rps: 4.0,
            shed_rate: 0.2,
            p50_us: 100,
            p95_us: 200,
            p99_us: 300,
            mean_us: 120.0,
            feedback_sent: 3,
            feedback_ok: 3,
            torn: 0,
            failovers: 2,
            generation_start: Some(1),
            generation_end: Some(4),
            server_stats: Some("ok model=cpu".into()),
        };
        let j = report.to_json(&cfg);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str(), Some("serve_load"));
        assert_eq!(parsed.get("ok").unwrap().as_usize(), Some(8));
        assert_eq!(parsed.get("torn").unwrap().as_usize(), Some(0));
        assert_eq!(parsed.get("failovers").unwrap().as_usize(), Some(2));
        assert!(report.summary().contains("failovers=2"));
        assert_eq!(parsed.get("feedback_ok").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("generation_start").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("generation_end").unwrap().as_usize(), Some(4));
        assert!(report.summary().contains("feedback=3/3 generation 1->4"));
        assert_eq!(
            parsed.get("latency_us").unwrap().get("p95").unwrap().as_usize(),
            Some(200)
        );
        assert_eq!(
            parsed.get("config").unwrap().get("connections").unwrap().as_usize(),
            Some(2)
        );
        assert!(report.summary().contains("open loop"));
        // a pre-observability stats line carries no per-stage breakdown
        assert_eq!(parsed.get("server_stages"), Some(&Json::Null));
    }
}
