//! Lock-free serving metrics: counters + a fixed-bucket latency
//! histogram (power-of-two microsecond buckets).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

const BUCKETS: usize = 24; // 1us .. ~8s

/// Shared metrics for one model route.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Requests refused at admission (`err overloaded`).
    pub shed: AtomicU64,
    pub errors: AtomicU64,
    /// Worker restarts performed by the supervisor after a panic
    /// ([`crate::coordinator::supervisor`]).
    pub restarts: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    /// Requests shed at admission (queue full).
    pub shed: u64,
    pub errors: u64,
    /// Supervisor-performed worker restarts (0 on healthy routes).
    pub restarts: u64,
    pub batches: u64,
    pub batched_items: u64,
    /// Queue depth at snapshot time. [`Metrics`] does not own the
    /// queue, so [`Metrics::snapshot`] leaves this 0 and the
    /// coordinator fills it from the route's queue gauge.
    pub queue_depth: u64,
    pub latency_buckets_us: Vec<(u64, u64)>, // (upper_bound_us, count)
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn bucket(us: u64) -> usize {
        (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.latency_us[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            queue_depth: 0,
            latency_buckets_us: self
                .latency_us
                .iter()
                .enumerate()
                .map(|(i, c)| (1u64 << (i + 1), c.load(Ordering::Relaxed)))
                .filter(|(_, c)| *c > 0)
                .collect(),
        }
    }
}

impl MetricsSnapshot {
    /// Approximate quantile from the histogram (upper bucket bounds).
    pub fn latency_quantile_us(&self, q: f64) -> Option<u64> {
        let total: u64 = self.latency_buckets_us.iter().map(|(_, c)| c).sum();
        if total == 0 {
            return None;
        }
        let target = ((total as f64) * q).ceil() as u64;
        let mut seen = 0;
        for &(bound, count) in &self.latency_buckets_us {
            seen += count;
            if seen >= target {
                return Some(bound);
            }
        }
        self.latency_buckets_us.last().map(|&(b, _)| b)
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Fraction of requests shed at admission (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }

    /// p50 latency in microseconds (0 when no latencies recorded) —
    /// the `stats` protocol verb's formatting convenience; quantiles
    /// are upper bucket bounds of the power-of-two histogram.
    pub fn p50_us(&self) -> u64 {
        self.latency_quantile_us(0.5).unwrap_or(0)
    }

    /// p95 latency in microseconds (0 when empty).
    pub fn p95_us(&self) -> u64 {
        self.latency_quantile_us(0.95).unwrap_or(0)
    }

    /// p99 latency in microseconds (0 when empty).
    pub fn p99_us(&self) -> u64 {
        self.latency_quantile_us(0.99).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Metrics::bucket(0), 0);
        assert_eq!(Metrics::bucket(1), 0);
        assert_eq!(Metrics::bucket(2), 1);
        assert_eq!(Metrics::bucket(3), 1);
        assert_eq!(Metrics::bucket(1024), 10);
        assert_eq!(Metrics::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn snapshot_reflects_counts() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.restarts.fetch_add(1, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(90));
        m.record_latency(Duration::from_millis(10));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.restarts, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size() - 3.0).abs() < 1e-12);
        // 2 fast + 1 slow: p50 lands in the ~128us bucket
        assert_eq!(s.latency_quantile_us(0.5), Some(128));
        assert!(s.latency_quantile_us(0.99).unwrap() >= 8192);
        assert_eq!(s.p50_us(), 128);
        assert!(s.p95_us() >= 8192 && s.p99_us() >= s.p95_us());
    }

    #[test]
    fn empty_quantile_is_none() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_quantile_us(0.5), None);
        assert_eq!((s.p50_us(), s.p95_us(), s.p99_us()), (0, 0, 0));
    }

    #[test]
    fn shed_rate_tracks_counters() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().shed_rate(), 0.0);
        m.requests.fetch_add(8, Ordering::Relaxed);
        m.shed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert!((s.shed_rate() - 0.25).abs() < 1e-12);
    }
}
