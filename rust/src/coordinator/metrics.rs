//! Lock-free serving metrics for one model route: admission counters,
//! engine probe counters, the end-to-end latency histogram, and one
//! [`Histogram`] per pipeline [`Stage`] (all power-of-two microsecond
//! buckets from [`crate::obs::histogram`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::obs::histogram::{Histogram, HistogramSnapshot};
use crate::obs::probes::{index_efficiency, ProbeDelta};
use crate::obs::{Stage, STAGES};

/// Shared metrics for one model route.
#[derive(Debug)]
pub struct Metrics {
    /// Requests admitted (completed + shed + errors when drained).
    pub requests: AtomicU64,
    /// Requests answered with a complete `ok` reply.
    pub completed: AtomicU64,
    /// Requests refused at admission (`err overloaded`).
    pub shed: AtomicU64,
    /// Requests failed by the backend or a worker panic.
    pub errors: AtomicU64,
    /// Worker restarts performed by the supervisor after a panic
    /// ([`crate::coordinator::supervisor`]).
    pub restarts: AtomicU64,
    /// Engine dispatches.
    pub batches: AtomicU64,
    /// Requests carried across all dispatches.
    pub batched_items: AtomicU64,
    /// Requests scored by the dense fused walk (engine probe).
    pub dense_requests: AtomicU64,
    /// Requests scored by the O(nnz) sparse-delta walk (engine probe).
    pub sparse_requests: AtomicU64,
    /// Unique clauses the index walk falsified (engine probe).
    pub clauses_falsified: AtomicU64,
    /// Clause evaluations the index skipped outright (engine probe).
    pub clauses_skipped: AtomicU64,
    /// False non-empty literals walked by the dense engine.
    pub features_walked: AtomicU64,
    /// Per-literal delta-row toggles applied by the sparse engine.
    pub sparse_toggles: AtomicU64,
    /// Labeled examples applied by the online learner
    /// (`feedback`/`train` verbs, WAL replay included).
    pub feedback_applied: AtomicU64,
    /// Feedback submissions rejected (bad label, width mismatch,
    /// learner queue closed).
    pub feedback_errors: AtomicU64,
    /// Snapshots published by the online learner's cadence.
    pub publishes: AtomicU64,
    /// Feedback updates applied since the last publish (gauge: how
    /// stale the served snapshot is, in updates).
    pub publish_lag: AtomicU64,
    /// Correct predict-before-apply calls in the learner's recent
    /// feedback window (drift gauge numerator).
    feedback_window_correct: AtomicU64,
    /// Examples currently in the recent feedback window (denominator).
    feedback_window_len: AtomicU64,
    /// Set while the route is inside a shed episode (first shed after a
    /// healthy period begins one; the next successful admission ends
    /// it) — drives the journal's shed_start/shed_end events.
    shedding: AtomicBool,
    latency_us: Histogram,
    stages: [Histogram; STAGES],
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            requests: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            dense_requests: AtomicU64::new(0),
            sparse_requests: AtomicU64::new(0),
            clauses_falsified: AtomicU64::new(0),
            clauses_skipped: AtomicU64::new(0),
            features_walked: AtomicU64::new(0),
            sparse_toggles: AtomicU64::new(0),
            feedback_applied: AtomicU64::new(0),
            feedback_errors: AtomicU64::new(0),
            publishes: AtomicU64::new(0),
            publish_lag: AtomicU64::new(0),
            feedback_window_correct: AtomicU64::new(0),
            feedback_window_len: AtomicU64::new(0),
            shedding: AtomicBool::new(false),
            latency_us: Histogram::new(),
            stages: Default::default(),
            started: Instant::now(),
        }
    }
}

/// Point-in-time copy for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests admitted.
    pub requests: u64,
    /// Requests answered with a complete `ok` reply.
    pub completed: u64,
    /// Requests shed at admission (queue full).
    pub shed: u64,
    /// Requests failed by the backend or a worker panic.
    pub errors: u64,
    /// Supervisor-performed worker restarts (0 on healthy routes).
    pub restarts: u64,
    /// Engine dispatches.
    pub batches: u64,
    /// Requests carried across all dispatches.
    pub batched_items: u64,
    /// Queue depth at snapshot time. [`Metrics`] does not own the
    /// queue, so [`Metrics::snapshot`] leaves this 0 and the
    /// coordinator fills it from the route's queue gauge.
    pub queue_depth: u64,
    /// Connections refused at the accept-loop cap since process start.
    /// Process-wide like the cap itself — [`Metrics::snapshot`] leaves
    /// it 0 and the coordinator fills it from
    /// [`crate::coordinator::server::conn_rejected_total`], so every
    /// route's snapshot carries the same server total.
    pub conn_rejected: u64,
    /// Samples scored through the dense fused walk.
    pub dense_requests: u64,
    /// Samples scored through the sparse-delta walk.
    pub sparse_requests: u64,
    /// Clause knock-outs performed by the walks.
    pub clauses_falsified: u64,
    /// Clause evaluations the index avoided.
    pub clauses_skipped: u64,
    /// False/set literals actually walked.
    pub features_walked: u64,
    /// Sparse delta-row counter toggles.
    pub sparse_toggles: u64,
    /// Labeled examples the online learner applied.
    pub feedback_applied: u64,
    /// Feedback submissions rejected.
    pub feedback_errors: u64,
    /// Online-learner snapshot publishes.
    pub publishes: u64,
    /// Updates applied since the last publish (staleness gauge).
    pub publish_lag: u64,
    /// Drift-window numerator: correct predict-before-apply calls.
    pub feedback_window_correct: u64,
    /// Drift-window denominator: examples in the recent window.
    pub feedback_window_len: u64,
    /// Whole seconds since the route's metrics were created.
    pub uptime_s: u64,
    /// End-to-end (admission -> scored) latency histogram.
    pub latency: HistogramSnapshot,
    /// Per-stage histograms, indexed by `Stage as usize`.
    pub stages: [HistogramSnapshot; STAGES],
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one end-to-end request latency.
    pub fn record_latency(&self, d: Duration) {
        self.latency_us.record_duration(d);
    }

    /// Record one pipeline-stage duration ([`Stage`] semantics).
    pub fn record_stage(&self, stage: Stage, d: Duration) {
        self.stages[stage as usize].record_duration(d);
    }

    /// Record one engine dispatch of `items` requests.
    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Flush an engine scratch's accumulated probe delta (batch-wise;
    /// one relaxed `fetch_add` per non-zero field).
    pub fn apply_probes(&self, d: &ProbeDelta) {
        if d.is_empty() {
            return;
        }
        self.dense_requests
            .fetch_add(d.dense_samples, Ordering::Relaxed);
        self.sparse_requests
            .fetch_add(d.sparse_samples, Ordering::Relaxed);
        self.clauses_falsified
            .fetch_add(d.clauses_falsified, Ordering::Relaxed);
        self.clauses_skipped
            .fetch_add(d.clauses_skipped, Ordering::Relaxed);
        self.features_walked
            .fetch_add(d.features_walked, Ordering::Relaxed);
        self.sparse_toggles
            .fetch_add(d.sparse_toggles, Ordering::Relaxed);
    }

    /// Count one shed; returns `true` when it begins a new episode
    /// (the caller emits the journal event — metrics stays silent).
    pub fn note_shed(&self) -> bool {
        self.shed.fetch_add(1, Ordering::Relaxed);
        !self.shedding.swap(true, Ordering::Relaxed)
    }

    /// Note a successful admission; returns `Some(total shed so far)`
    /// when it ends a shed episode.
    pub fn note_admitted(&self) -> Option<u64> {
        if self.shedding.load(Ordering::Relaxed) && self.shedding.swap(false, Ordering::Relaxed) {
            Some(self.shed.load(Ordering::Relaxed))
        } else {
            None
        }
    }

    /// Store the online learner's recent-window drift gauge: how many
    /// of the last `len` feedback examples the *served-era* model
    /// predicted correctly before the update was applied. Single
    /// writer (the learner thread), so plain stores suffice.
    pub fn set_feedback_window(&self, correct: u64, len: u64) {
        self.feedback_window_correct
            .store(correct, Ordering::Relaxed);
        self.feedback_window_len.store(len, Ordering::Relaxed);
    }

    /// Time since the route's metrics were created (route uptime).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Coherent point-in-time copy of every counter and quantile.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            queue_depth: 0,
            conn_rejected: 0,
            dense_requests: self.dense_requests.load(Ordering::Relaxed),
            sparse_requests: self.sparse_requests.load(Ordering::Relaxed),
            clauses_falsified: self.clauses_falsified.load(Ordering::Relaxed),
            clauses_skipped: self.clauses_skipped.load(Ordering::Relaxed),
            features_walked: self.features_walked.load(Ordering::Relaxed),
            sparse_toggles: self.sparse_toggles.load(Ordering::Relaxed),
            feedback_applied: self.feedback_applied.load(Ordering::Relaxed),
            feedback_errors: self.feedback_errors.load(Ordering::Relaxed),
            publishes: self.publishes.load(Ordering::Relaxed),
            publish_lag: self.publish_lag.load(Ordering::Relaxed),
            feedback_window_correct: self.feedback_window_correct.load(Ordering::Relaxed),
            feedback_window_len: self.feedback_window_len.load(Ordering::Relaxed),
            uptime_s: self.started.elapsed().as_secs(),
            latency: self.latency_us.snapshot(),
            stages: std::array::from_fn(|i| self.stages[i].snapshot()),
        }
    }
}

impl MetricsSnapshot {
    /// Approximate end-to-end latency quantile (upper bucket bounds).
    pub fn latency_quantile_us(&self, q: f64) -> Option<u64> {
        self.latency.quantile(q)
    }

    /// One stage's histogram snapshot.
    pub fn stage(&self, s: Stage) -> &HistogramSnapshot {
        &self.stages[s as usize]
    }

    /// Mean requests per engine dispatch.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Fraction of requests shed at admission (0 when idle).
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }

    /// Fraction of clause evaluations the index avoided — the paper's
    /// speedup claim observed on live traffic (0 with no probe data).
    pub fn index_efficiency(&self) -> f64 {
        index_efficiency(self.clauses_falsified, self.clauses_skipped)
    }

    /// Accuracy of the *served-era* model over the learner's recent
    /// feedback window (drift gauge; 0 before any feedback arrives).
    /// Falling accuracy while feedback flows means the published
    /// snapshot is drifting behind the labeled stream.
    pub fn feedback_recent_accuracy(&self) -> f64 {
        if self.feedback_window_len == 0 {
            0.0
        } else {
            self.feedback_window_correct as f64 / self.feedback_window_len as f64
        }
    }

    /// p50 latency in microseconds (0 when no latencies recorded) —
    /// the `stats` protocol verb's formatting convenience; quantiles
    /// are upper bucket bounds of the power-of-two histogram.
    pub fn p50_us(&self) -> u64 {
        self.latency.p50()
    }

    /// p95 latency in microseconds (0 when empty).
    pub fn p95_us(&self) -> u64 {
        self.latency.p95()
    }

    /// p99 latency in microseconds (0 when empty).
    pub fn p99_us(&self) -> u64 {
        self.latency.p99()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_counts() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.restarts.fetch_add(1, Ordering::Relaxed);
        m.record_batch(2);
        m.record_batch(4);
        m.record_latency(Duration::from_micros(100));
        m.record_latency(Duration::from_micros(90));
        m.record_latency(Duration::from_millis(10));
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.restarts, 1);
        assert_eq!(s.batches, 2);
        assert!((s.mean_batch_size() - 3.0).abs() < 1e-12);
        // 2 fast + 1 slow: p50 lands in the ~128us bucket
        assert_eq!(s.latency_quantile_us(0.5), Some(128));
        assert!(s.latency_quantile_us(0.99).unwrap() >= 8192);
        assert_eq!(s.p50_us(), 128);
        assert!(s.p95_us() >= 8192 && s.p99_us() >= s.p95_us());
    }

    #[test]
    fn empty_quantile_is_none() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_quantile_us(0.5), None);
        assert_eq!((s.p50_us(), s.p95_us(), s.p99_us()), (0, 0, 0));
    }

    #[test]
    fn shed_rate_tracks_counters() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().shed_rate(), 0.0);
        m.requests.fetch_add(8, Ordering::Relaxed);
        m.shed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert!((s.shed_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn stages_record_independently() {
        let m = Metrics::new();
        m.record_stage(Stage::Queue, Duration::from_micros(100));
        m.record_stage(Stage::Score, Duration::from_micros(10));
        let s = m.snapshot();
        assert_eq!(s.stage(Stage::Queue).count, 1);
        assert_eq!(s.stage(Stage::Batch).count, 0);
        assert_eq!(s.stage(Stage::Score).count, 1);
        assert_eq!(s.stage(Stage::Queue).p50(), 128);
        assert_eq!(s.stage(Stage::Score).p50(), 16);
    }

    #[test]
    fn probe_flush_and_efficiency() {
        let m = Metrics::new();
        m.apply_probes(&ProbeDelta {
            dense_samples: 2,
            clauses_falsified: 10,
            clauses_skipped: 90,
            features_walked: 55,
            ..ProbeDelta::default()
        });
        m.apply_probes(&ProbeDelta::default()); // no-op
        let s = m.snapshot();
        assert_eq!(s.dense_requests, 2);
        assert_eq!(s.sparse_requests, 0);
        assert_eq!(s.features_walked, 55);
        assert!((s.index_efficiency() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn shed_episode_edges() {
        let m = Metrics::new();
        assert_eq!(m.note_admitted(), None, "healthy: no episode to end");
        assert!(m.note_shed(), "first shed begins an episode");
        assert!(!m.note_shed(), "second shed continues it");
        assert_eq!(m.note_admitted(), Some(2), "admission ends it at 2 shed");
        assert_eq!(m.note_admitted(), None);
        assert!(m.note_shed(), "a fresh episode can begin");
    }

    #[test]
    fn feedback_window_gauge_and_accuracy() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().feedback_recent_accuracy(), 0.0);
        m.feedback_applied.fetch_add(4, Ordering::Relaxed);
        m.publish_lag.store(3, Ordering::Relaxed);
        m.set_feedback_window(3, 4);
        let s = m.snapshot();
        assert_eq!(s.feedback_applied, 4);
        assert_eq!(s.publish_lag, 3);
        assert!((s.feedback_recent_accuracy() - 0.75).abs() < 1e-12);
        // the gauge is absolute: a fresh store replaces, not adds
        m.set_feedback_window(1, 2);
        assert!((m.snapshot().feedback_recent_accuracy() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn uptime_is_monotonic() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert!(s.uptime_s <= m.uptime().as_secs() + 1);
    }
}
