//! Serving backends: CPU (indexed/naive/bitpacked evaluators) and XLA
//! (AOT artifact).
//!
//! A backend turns a batch of literal vectors into per-request
//! predictions. The CPU backend is the paper's system — clause-indexed
//! falsification on the Rust hot path; the XLA backend runs the
//! Layer-1/2 dense kernel through PJRT with device-resident model
//! buffers.

use anyhow::Result;

use crate::eval;
use crate::runtime::{PreparedModel, Runtime, TmExecutable};
use crate::tm::classifier::MultiClassTM;
use crate::tm::io::DenseModel;
use crate::tm::trainer::Trainer;
use crate::util::BitVec;

/// One scored request.
#[derive(Clone, Debug, PartialEq)]
pub struct Scored {
    pub prediction: usize,
    pub scores: Vec<i32>,
}

/// A serving backend for one model.
///
/// Deliberately NOT `Send`: PJRT handles are thread-pinned (`Rc`
/// internals), so the coordinator constructs each backend *inside* its
/// worker thread via a `Send` factory closure
/// ([`crate::coordinator::Coordinator::register_with`]).
pub trait Backend {
    /// Score a batch of literal vectors.
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>>;
    /// Literal width this backend expects.
    fn n_literals(&self) -> usize;
    fn name(&self) -> String;
}

/// CPU backend: the trained machine + a chosen evaluator.
///
/// With `replicas > 1` the machine is cloned per replica and batches
/// are split across scoped threads — evaluator scratch (generation
/// stamps) is per-replica, so replicas never contend. Memory cost is
/// one machine copy per replica; latency scales with
/// `batch / replicas` for large batches.
pub struct CpuBackend {
    replicas: Vec<Trainer>,
}

impl CpuBackend {
    pub fn new(tm: MultiClassTM, backend: eval::Backend) -> Self {
        Self::new_parallel(tm, backend, 1)
    }

    pub fn new_parallel(tm: MultiClassTM, backend: eval::Backend, replicas: usize) -> Self {
        let replicas = replicas.max(1);
        CpuBackend {
            replicas: (0..replicas)
                .map(|_| Trainer::from_machine(tm.clone(), backend))
                .collect(),
        }
    }

    fn score_one(trainer: &mut Trainer, lits: &BitVec) -> Scored {
        let scores = trainer.scores(lits);
        let prediction = scores
            .iter()
            .enumerate()
            .max_by_key(|&(i, &s)| (s, std::cmp::Reverse(i)))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Scored { prediction, scores }
    }
}

impl Backend for CpuBackend {
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>> {
        let n_rep = self.replicas.len();
        // below ~4 items per replica, thread spawn overhead dominates
        if n_rep == 1 || batch.len() < 4 * n_rep {
            let tr = &mut self.replicas[0];
            return Ok(batch.iter().map(|l| Self::score_one(tr, l)).collect());
        }
        let chunk = batch.len().div_ceil(n_rep);
        let mut out: Vec<Vec<Scored>> = Vec::with_capacity(n_rep);
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .zip(batch.chunks(chunk))
                .map(|(tr, items)| {
                    scope.spawn(move || {
                        items
                            .iter()
                            .map(|l| Self::score_one(tr, l))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                out.push(h.join().expect("replica thread panicked"));
            }
        });
        Ok(out.into_iter().flatten().collect())
    }

    fn n_literals(&self) -> usize {
        self.replicas[0].tm.params.n_literals()
    }

    fn name(&self) -> String {
        let base = format!("cpu-{}", self.replicas[0].backend().name());
        if self.replicas.len() == 1 {
            base
        } else {
            format!("{base}x{}", self.replicas.len())
        }
    }
}

/// XLA backend: compiled artifact + device-resident model buffers.
pub struct XlaBackend {
    rt: Runtime,
    exe: TmExecutable,
    prepared: PreparedModel,
    n_literals: usize,
    classes: usize,
}

impl XlaBackend {
    pub fn new(rt: Runtime, exe: TmExecutable, model: &DenseModel) -> Result<Self> {
        let prepared = rt.prepare_model(&exe, model)?;
        Ok(XlaBackend {
            n_literals: model.n_literals,
            classes: model.classes,
            rt,
            exe,
            prepared,
        })
    }

    fn literals_to_f32(&self, batch: &[BitVec]) -> Vec<f32> {
        let mut out = vec![0f32; batch.len() * self.n_literals];
        for (b, lits) in batch.iter().enumerate() {
            let row = &mut out[b * self.n_literals..(b + 1) * self.n_literals];
            for k in lits.iter_ones() {
                row[k] = 1.0;
            }
        }
        out
    }
}

impl Backend for XlaBackend {
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>> {
        let max = self.exe.meta.batch;
        let mut out = Vec::with_capacity(batch.len());
        // chunk oversized batches to the artifact's batch dimension
        for chunk in batch.chunks(max) {
            let lits = self.literals_to_f32(chunk);
            let fwd = self.exe.run(&self.rt, &self.prepared, &lits, chunk.len())?;
            for b in 0..chunk.len() {
                out.push(Scored {
                    prediction: fwd.predictions[b] as usize,
                    scores: fwd.scores[b * self.classes..(b + 1) * self.classes]
                        .iter()
                        .map(|&s| s as i32)
                        .collect(),
                });
            }
        }
        Ok(out)
    }

    fn n_literals(&self) -> usize {
        self.n_literals
    }

    fn name(&self) -> String {
        format!("xla-{}", self.exe.meta.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn toy_model() -> MultiClassTM {
        let params = TMParams::new(2, 10, 8);
        let mut tr = Trainer::new(params, eval::Backend::Indexed);
        let mut rng = Rng::new(3);
        let samples: Vec<(BitVec, usize)> = (0..200)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..8).map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..5 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    #[test]
    fn cpu_backend_scores_batches() {
        let tm = toy_model();
        let mut be = CpuBackend::new(tm, eval::Backend::Indexed);
        assert_eq!(be.n_literals(), 16);
        assert_eq!(be.name(), "cpu-indexed");
        // class 0 signature: feature 0 set
        let mut bits = vec![false; 8];
        bits[0] = true;
        let mut l = bits.clone();
        l.extend(bits.iter().map(|b| !b));
        let pos = BitVec::from_bools(&l);
        let bits = vec![false; 8];
        let mut l = bits.clone();
        l.extend(bits.iter().map(|b| !b));
        let neg = BitVec::from_bools(&l);
        let scored = be.infer_batch(&[pos, neg]).unwrap();
        assert_eq!(scored.len(), 2);
        assert_eq!(scored[0].prediction, 0);
        assert_eq!(scored[1].prediction, 1);
        assert_eq!(scored[0].scores.len(), 2);
    }

    #[test]
    fn parallel_replicas_agree_with_serial() {
        let tm = toy_model();
        let mut rng = Rng::new(17);
        let batch: Vec<BitVec> = (0..64)
            .map(|_| {
                let bits: Vec<bool> = (0..8).map(|_| rng.bern(0.5)).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                BitVec::from_bools(&l)
            })
            .collect();
        let mut serial = CpuBackend::new(tm.clone(), eval::Backend::Indexed);
        let mut par = CpuBackend::new_parallel(tm, eval::Backend::Indexed, 4);
        assert_eq!(par.name(), "cpu-indexedx4");
        assert_eq!(
            serial.infer_batch(&batch).unwrap(),
            par.infer_batch(&batch).unwrap()
        );
        // tiny batch takes the serial fast path but must still answer
        assert_eq!(par.infer_batch(&batch[..2]).unwrap().len(), 2);
    }

    #[test]
    fn cpu_backends_agree() {
        let tm = toy_model();
        let mut rng = Rng::new(9);
        let batch: Vec<BitVec> = (0..20)
            .map(|_| {
                let bits: Vec<bool> = (0..8).map(|_| rng.bern(0.5)).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                BitVec::from_bools(&l)
            })
            .collect();
        let mut a = CpuBackend::new(tm.clone(), eval::Backend::Indexed);
        let mut b = CpuBackend::new(tm.clone(), eval::Backend::Naive);
        let mut c = CpuBackend::new(tm, eval::Backend::BitPacked);
        let ra = a.infer_batch(&batch).unwrap();
        let rb = b.infer_batch(&batch).unwrap();
        let rc = c.infer_batch(&batch).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(ra, rc);
    }
}
