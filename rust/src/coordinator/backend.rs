//! Serving backends: CPU (indexed/naive/bitpacked evaluators) and XLA
//! (AOT artifact).
//!
//! A backend turns a batch of literal vectors into per-request
//! predictions. The CPU backend is the paper's system — clause-indexed
//! falsification on the Rust hot path; the XLA backend runs the
//! Layer-1/2 dense kernel through PJRT with device-resident model
//! buffers.
//!
//! Backends power the coordinator's **factory routes**
//! ([`crate::coordinator::Coordinator::register_with`]): one worker
//! owning mutable state. The factory is `FnMut` so the route's
//! supervisor can re-run it to rebuild the backend after a worker
//! panic — a torn, half-mutated backend is never reused; factories
//! should therefore capture what they need to build a *fresh* backend
//! on every call (clone the model in, don't move it). The indexed
//! serving hot path has moved to **snapshot routes**
//! ([`crate::coordinator::Coordinator::register_model`]
//! over [`crate::engine::ModelSnapshot`]), which add hot swap and
//! multi-worker scale-out; `CpuBackend` remains the serving vehicle
//! for the naive/bitpacked ablation evaluators and the XLA route.

use anyhow::Result;

use crate::engine::argmax;
use crate::eval;
use crate::runtime::{PreparedModel, Runtime, TmExecutable};
use crate::tm::classifier::MultiClassTM;
use crate::tm::io::DenseModel;
use crate::tm::trainer::Trainer;
use crate::util::BitVec;

/// One scored request.
#[derive(Clone, Debug, PartialEq)]
pub struct Scored {
    /// The argmax class.
    pub prediction: usize,
    /// Per-class vote sums.
    pub scores: Vec<i32>,
}

/// A serving backend for one model.
///
/// Deliberately NOT `Send`: PJRT handles are thread-pinned (`Rc`
/// internals), so the coordinator constructs each backend *inside* its
/// worker thread via a `Send` factory closure
/// ([`crate::coordinator::Coordinator::register_with`]).
pub trait Backend {
    /// Score a batch of literal vectors.
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>>;
    /// Literal width this backend expects.
    fn n_literals(&self) -> usize;
    /// Backend name (diagnostics, `stats` output).
    fn name(&self) -> String;
}

/// CPU backend: the trained machine + a chosen evaluator.
///
/// Inference goes through [`Trainer::score_batch_into`]: for the
/// indexed evaluator that is the class-fused batch engine
/// ([`crate::engine::FusedEngine`]) — one falsification walk per
/// sample scores every class, and with `threads > 1` large batches
/// shard across scoped workers that share the read-only index. This
/// replaces the old clone-the-whole-machine replica scheme: per-worker
/// state is a scratch buffer (generation stamps + walk targets)
/// instead of a full model copy, so memory stays O(model + threads ×
/// scratch) and warm batches allocate only their output.
pub struct CpuBackend {
    trainer: Trainer,
    threads: usize,
    /// Reusable row-major score matrix (batch × classes).
    flat: Vec<i32>,
}

impl CpuBackend {
    /// CPU backend scoring through the chosen evaluation backend.
    pub fn new(tm: MultiClassTM, backend: eval::Backend) -> Self {
        Self::new_parallel(tm, backend, 1)
    }

    /// `threads` inference workers over one shared machine. Only the
    /// indexed backend shards batches (its fused index is shared
    /// read-only); the naive/bitpacked ablation backends score
    /// serially, and `threads` is clamped to 1 for them so the route
    /// name never advertises parallelism that is not happening.
    pub fn new_parallel(tm: MultiClassTM, backend: eval::Backend, threads: usize) -> Self {
        let threads = if backend == eval::Backend::Indexed {
            threads.max(1)
        } else {
            if threads > 1 {
                eprintln!(
                    "cpu-{}: batch sharding requires the indexed backend; \
                     scoring serially (requested {threads} threads)",
                    backend.name()
                );
            }
            1
        };
        CpuBackend {
            trainer: Trainer::from_machine(tm, backend).with_infer_threads(threads),
            threads,
            flat: Vec::new(),
        }
    }
}

impl Backend for CpuBackend {
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>> {
        let m = self.trainer.tm.classes();
        self.flat.clear();
        self.flat.resize(batch.len() * m, 0);
        self.trainer.score_batch_into(batch, &mut self.flat);
        Ok(self
            .flat
            .chunks(m)
            .map(|scores| Scored {
                prediction: argmax(scores),
                scores: scores.to_vec(),
            })
            .collect())
    }

    fn n_literals(&self) -> usize {
        self.trainer.tm.params.n_literals()
    }

    fn name(&self) -> String {
        let base = format!("cpu-{}", self.trainer.backend().name());
        if self.threads == 1 {
            base
        } else {
            format!("{base}x{}", self.threads)
        }
    }
}

/// XLA backend: compiled artifact + device-resident model buffers.
pub struct XlaBackend {
    rt: Runtime,
    exe: TmExecutable,
    prepared: PreparedModel,
    n_literals: usize,
    classes: usize,
}

impl XlaBackend {
    /// XLA backend over a compiled executable and uploaded model arrays.
    pub fn new(rt: Runtime, exe: TmExecutable, model: &DenseModel) -> Result<Self> {
        let prepared = rt.prepare_model(&exe, model)?;
        Ok(XlaBackend {
            n_literals: model.n_literals,
            classes: model.classes,
            rt,
            exe,
            prepared,
        })
    }

    fn literals_to_f32(&self, batch: &[BitVec]) -> Vec<f32> {
        let mut out = vec![0f32; batch.len() * self.n_literals];
        for (b, lits) in batch.iter().enumerate() {
            let row = &mut out[b * self.n_literals..(b + 1) * self.n_literals];
            for k in lits.iter_ones() {
                row[k] = 1.0;
            }
        }
        out
    }
}

impl Backend for XlaBackend {
    fn infer_batch(&mut self, batch: &[BitVec]) -> Result<Vec<Scored>> {
        let max = self.exe.meta.batch;
        let mut out = Vec::with_capacity(batch.len());
        // chunk oversized batches to the artifact's batch dimension
        for chunk in batch.chunks(max) {
            let lits = self.literals_to_f32(chunk);
            let fwd = self.exe.run(&self.rt, &self.prepared, &lits, chunk.len())?;
            for b in 0..chunk.len() {
                out.push(Scored {
                    prediction: fwd.predictions[b] as usize,
                    scores: fwd.scores[b * self.classes..(b + 1) * self.classes]
                        .iter()
                        .map(|&s| s as i32)
                        .collect(),
                });
            }
        }
        Ok(out)
    }

    fn n_literals(&self) -> usize {
        self.n_literals
    }

    fn name(&self) -> String {
        format!("xla-{}", self.exe.meta.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn toy_model() -> MultiClassTM {
        let params = TMParams::new(2, 10, 8);
        let mut tr = Trainer::new(params, eval::Backend::Indexed);
        let mut rng = Rng::new(3);
        let samples: Vec<(BitVec, usize)> = (0..200)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..8).map(|k| if k == 0 { y == 0 } else { rng.bern(0.5) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..5 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    #[test]
    fn cpu_backend_scores_batches() {
        let tm = toy_model();
        let mut be = CpuBackend::new(tm, eval::Backend::Indexed);
        assert_eq!(be.n_literals(), 16);
        assert_eq!(be.name(), "cpu-indexed");
        // class 0 signature: feature 0 set
        let mut bits = vec![false; 8];
        bits[0] = true;
        let mut l = bits.clone();
        l.extend(bits.iter().map(|b| !b));
        let pos = BitVec::from_bools(&l);
        let bits = vec![false; 8];
        let mut l = bits.clone();
        l.extend(bits.iter().map(|b| !b));
        let neg = BitVec::from_bools(&l);
        let scored = be.infer_batch(&[pos, neg]).unwrap();
        assert_eq!(scored.len(), 2);
        assert_eq!(scored[0].prediction, 0);
        assert_eq!(scored[1].prediction, 1);
        assert_eq!(scored[0].scores.len(), 2);
    }

    #[test]
    fn parallel_replicas_agree_with_serial() {
        let tm = toy_model();
        let mut rng = Rng::new(17);
        let batch: Vec<BitVec> = (0..64)
            .map(|_| {
                let bits: Vec<bool> = (0..8).map(|_| rng.bern(0.5)).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                BitVec::from_bools(&l)
            })
            .collect();
        let mut serial = CpuBackend::new(tm.clone(), eval::Backend::Indexed);
        let mut par = CpuBackend::new_parallel(tm, eval::Backend::Indexed, 4);
        assert_eq!(par.name(), "cpu-indexedx4");
        assert_eq!(
            serial.infer_batch(&batch).unwrap(),
            par.infer_batch(&batch).unwrap()
        );
        // tiny batch takes the serial fast path but must still answer
        assert_eq!(par.infer_batch(&batch[..2]).unwrap().len(), 2);
    }

    #[test]
    fn cpu_backends_agree() {
        let tm = toy_model();
        let mut rng = Rng::new(9);
        let batch: Vec<BitVec> = (0..20)
            .map(|_| {
                let bits: Vec<bool> = (0..8).map(|_| rng.bern(0.5)).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                BitVec::from_bools(&l)
            })
            .collect();
        let mut a = CpuBackend::new(tm.clone(), eval::Backend::Indexed);
        let mut b = CpuBackend::new(tm.clone(), eval::Backend::Naive);
        let mut c = CpuBackend::new(tm, eval::Backend::BitPacked);
        let ra = a.infer_batch(&batch).unwrap();
        let rb = b.infer_batch(&batch).unwrap();
        let rc = c.infer_batch(&batch).unwrap();
        assert_eq!(ra, rb);
        assert_eq!(ra, rc);
    }
}
