//! Dynamic batching: collect-until-full-or-deadline.
//!
//! The worker blocks for the first request, then drains the queue until
//! either `max_batch` items are held or `max_wait` has elapsed since the
//! first item — the standard size/deadline policy (vLLM-style), tuned
//! per backend: the XLA backend wants full batches (one `execute` per
//! batch), the CPU backend prefers short waits (per-item cost is flat).
//!
//! Collection runs against a shared [`BoundedQueue`], so any number of
//! workers can collect from one route concurrently: each in-flight
//! request belongs to exactly one worker's batch, and the queue's
//! close-then-drain shutdown means a closed route still flushes every
//! admitted request before the workers see [`Collected::Disconnected`].

use std::time::{Duration, Instant};

use crate::coordinator::queue::{BoundedQueue, PopTimeout};

/// Size/deadline batching policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Maximum requests fused into one engine call.
    pub max_batch: usize,
    /// Longest a request may wait for batch-mates before dispatch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// Outcome of one collection round.
pub enum Collected<T> {
    /// A non-empty batch.
    Batch {
        /// The collected requests.
        items: Vec<T>,
        /// First pop to batch-ready: the assembly window this batch
        /// actually spent collecting (the observability `batch` stage —
        /// excludes the idle block waiting for the first item).
        assembled: Duration,
    },
    /// The queue is closed and drained: shut down.
    Disconnected,
}

/// Collect one batch according to `policy`. Blocks for the first item.
pub fn collect<T>(queue: &BoundedQueue<T>, policy: &BatchPolicy) -> Collected<T> {
    let first = match queue.pop_blocking() {
        Some(item) => item,
        None => return Collected::Disconnected,
    };
    let t_first = Instant::now();
    let mut batch = Vec::with_capacity(policy.max_batch.min(64));
    batch.push(first);
    let deadline = t_first + policy.max_wait;
    while batch.len() < policy.max_batch {
        // drain whatever is already queued without waiting
        if let Some(item) = queue.try_pop() {
            batch.push(item);
            continue;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match queue.pop_timeout(deadline - now) {
            PopTimeout::Item(item) => batch.push(item),
            // Closed mid-collection: flush what we hold; the *next*
            // collect call reports Disconnected once the queue drains.
            PopTimeout::TimedOut | PopTimeout::Closed => break,
        }
    }
    Collected::Batch {
        items: batch,
        assembled: t_first.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::PushError;
    use std::sync::Arc;

    fn filled(cap: usize, items: impl IntoIterator<Item = u32>) -> BoundedQueue<u32> {
        let q = BoundedQueue::new(cap);
        for i in items {
            q.try_push(i).unwrap();
        }
        q
    }

    #[test]
    fn collects_up_to_max_batch() {
        let q = filled(16, 0..10);
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        match collect(&q, &policy) {
            Collected::Batch { items, .. } => assert_eq!(items, vec![0, 1, 2, 3]),
            _ => panic!("expected batch"),
        }
        match collect(&q, &policy) {
            Collected::Batch { items, .. } => assert_eq!(items, vec![4, 5, 6, 7]),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q = filled(4, [1]);
        let policy = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        match collect(&q, &policy) {
            Collected::Batch { items, assembled } => {
                assert_eq!(items, vec![1]);
                // waited out (most of) the 5ms deadline for stragglers
                assert!(assembled >= Duration::from_millis(1), "assembled {assembled:?}");
            }
            _ => panic!("expected batch"),
        }
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn close_before_any_item_disconnects() {
        let q = BoundedQueue::<u32>::new(4);
        q.close();
        assert!(matches!(
            collect(&q, &BatchPolicy::default()),
            Collected::Disconnected
        ));
    }

    #[test]
    fn close_flushes_held_items_then_disconnects() {
        let q = filled(4, [7, 8]);
        q.close();
        let policy = BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_secs(5), // must not wait this long
        };
        let t0 = Instant::now();
        match collect(&q, &policy) {
            Collected::Batch { items, .. } => assert_eq!(items, vec![7, 8]),
            _ => panic!("expected batch"),
        }
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert!(matches!(collect(&q, &policy), Collected::Disconnected));
    }

    #[test]
    fn blocks_for_first_item_then_batches_stragglers() {
        let q = Arc::new(BoundedQueue::new(8));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.try_push(1).unwrap();
            q2.try_push(2).unwrap();
        });
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
        };
        match collect(&q, &policy) {
            Collected::Batch { items, .. } => {
                assert!(!items.is_empty() && items[0] == 1);
            }
            _ => panic!("expected batch"),
        }
        h.join().unwrap();
    }

    /// Many producers racing several collectors across a spread of
    /// `max_wait` values: every item must land in exactly one batch —
    /// no loss, no duplication — and batches never exceed `max_batch`.
    #[test]
    fn contended_collect_partitions_items_exactly() {
        const PRODUCERS: usize = 6;
        const PER_PRODUCER: usize = 400;
        const COLLECTORS: usize = 3;
        for (max_batch, max_wait_us) in [(1, 0u64), (7, 50), (32, 500), (256, 2000)] {
            let q = Arc::new(BoundedQueue::new(32));
            let producers: Vec<_> = (0..PRODUCERS)
                .map(|p| {
                    let q = Arc::clone(&q);
                    std::thread::spawn(move || {
                        for i in 0..PER_PRODUCER {
                            let mut item = (p * PER_PRODUCER + i) as u32;
                            loop {
                                match q.try_push(item) {
                                    Ok(()) => break,
                                    Err(PushError::Full(v)) => {
                                        item = v;
                                        std::thread::yield_now();
                                    }
                                    Err(PushError::Closed(_)) => panic!("closed early"),
                                }
                            }
                        }
                    })
                })
                .collect();
            let policy = BatchPolicy {
                max_batch,
                max_wait: Duration::from_micros(max_wait_us),
            };
            let collectors: Vec<_> = (0..COLLECTORS)
                .map(|_| {
                    let q = Arc::clone(&q);
                    std::thread::spawn(move || {
                        let mut got = Vec::new();
                        loop {
                            match collect(&q, &policy) {
                                Collected::Batch { items, .. } => {
                                    assert!(!items.is_empty(), "empty batch");
                                    assert!(items.len() <= policy.max_batch, "oversized batch");
                                    got.extend(items);
                                }
                                Collected::Disconnected => return got,
                            }
                        }
                    })
                })
                .collect();
            for p in producers {
                p.join().unwrap();
            }
            q.close();
            let mut all: Vec<u32> = collectors
                .into_iter()
                .flat_map(|c| c.join().unwrap())
                .collect();
            all.sort_unstable();
            assert_eq!(
                all,
                (0..(PRODUCERS * PER_PRODUCER) as u32).collect::<Vec<_>>(),
                "max_batch={max_batch} max_wait={max_wait_us}us"
            );
        }
    }

    /// The `max_wait` race: a closed queue mid-straggler-wait must
    /// flush promptly instead of sleeping out a long deadline.
    #[test]
    fn close_races_straggler_wait_without_stalling() {
        for _ in 0..20 {
            let q = Arc::new(BoundedQueue::new(8));
            q.try_push(1u32).unwrap();
            let q2 = Arc::clone(&q);
            let closer = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_micros(200));
                q2.close();
            });
            let policy = BatchPolicy {
                max_batch: 64,
                max_wait: Duration::from_secs(10),
            };
            let t0 = Instant::now();
            match collect(&q, &policy) {
                Collected::Batch { items, .. } => assert_eq!(items, vec![1]),
                _ => panic!("expected batch"),
            }
            assert!(
                t0.elapsed() < Duration::from_secs(2),
                "collect slept through close"
            );
            closer.join().unwrap();
        }
    }
}
