//! Dynamic batching: collect-until-full-or-deadline.
//!
//! The worker blocks for the first request, then drains the queue until
//! either `max_batch` items are held or `max_wait` has elapsed since the
//! first item — the standard size/deadline policy (vLLM-style), tuned
//! per backend: the XLA backend wants full batches (one `execute` per
//! batch), the CPU backend prefers short waits (per-item cost is flat).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Size/deadline batching policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// Outcome of one collection round.
pub enum Collected<T> {
    /// A non-empty batch.
    Batch(Vec<T>),
    /// The channel closed and no items remain: shut down.
    Disconnected,
}

/// Collect one batch according to `policy`. Blocks for the first item.
pub fn collect<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Collected<T> {
    let first = match rx.recv() {
        Ok(item) => item,
        Err(_) => return Collected::Disconnected,
    };
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break, // flush what we hold
        }
    }
    Collected::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![0, 1, 2, 3]),
            _ => panic!("expected batch"),
        }
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![4, 5, 6, 7]),
            _ => panic!("expected batch"),
        }
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![1]),
            _ => panic!("expected batch"),
        }
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn disconnect_before_any_item() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(matches!(
            collect(&rx, &BatchPolicy::default()),
            Collected::Disconnected
        ));
    }

    #[test]
    fn disconnect_flushes_held_items() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_secs(5), // must not wait this long
        };
        let t0 = Instant::now();
        match collect(&rx, &policy) {
            Collected::Batch(b) => assert_eq!(b, vec![7, 8]),
            _ => panic!("expected batch"),
        }
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn blocks_for_first_item_then_batches_stragglers() {
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(1).unwrap();
            tx.send(2).unwrap();
        });
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
        };
        match collect(&rx, &policy) {
            Collected::Batch(b) => {
                assert!(!b.is_empty() && b[0] == 1);
            }
            _ => panic!("expected batch"),
        }
        h.join().unwrap();
    }
}
