//! Bounded multi-producer/multi-consumer request queue: the admission
//! control point of every serving route.
//!
//! Producers (routing handles) use [`BoundedQueue::try_push`], which
//! **sheds** instead of blocking when the queue is full — the
//! coordinator turns a [`PushError::Full`] into an `err overloaded`
//! reply, so a saturated server degrades by refusing work rather than
//! by queueing unboundedly and timing every client out. Consumers (the
//! route's batcher workers) pop under a condvar; any number of workers
//! may drain one queue concurrently.
//!
//! Shutdown is close-then-drain: [`BoundedQueue::close`] rejects new
//! pushes but consumers keep popping until the queue is empty, so every
//! request admitted before the close is still answered.
//! [`BoundedQueue::close_and_drain`] additionally drops whatever is
//! still queued — the last-worker-panicked escape hatch that turns
//! would-be-hung requests into disconnect errors at their response
//! channels.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The queue is at capacity: shed this request.
    Full(T),
    /// The queue was closed: the route is shutting down.
    Closed(T),
}

/// Outcome of a bounded wait for one item.
#[derive(Debug)]
pub enum PopTimeout<T> {
    /// An item arrived within the deadline.
    Item(T),
    /// The deadline passed with the queue still empty.
    TimedOut,
    /// The queue is closed *and* empty — no item will ever arrive.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue (mutex + condvar; the offline build has no
/// crossbeam, see DESIGN.md §Substitutions). Capacity is clamped to at
/// least 1.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
    /// Mirror of the current length, readable without the lock (the
    /// `queue_depth` metrics gauge).
    depth: AtomicUsize,
}

impl<T> BoundedQueue<T> {
    /// Lock with poison *recovery*: every mutation completes under the
    /// lock (no caller code runs mid-update), so the queue's invariants
    /// hold at every unlock and a lock poisoned by a panicking worker
    /// is safe to keep using. Propagating the poison instead would
    /// cascade one worker's panic into every client and sibling worker
    /// sharing the route.
    fn lock(&self) -> MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Bounded queue holding at most `cap` items.
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            cap: cap.max(1),
            depth: AtomicUsize::new(0),
        }
    }

    /// The admission bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current queue depth (lock-free gauge; momentarily stale under
    /// concurrency).
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// True if no items are waiting.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`close`](Self::close) has been called.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Admit `item` if there is room; never blocks.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.lock();
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        self.depth.store(g.items.len(), Ordering::Relaxed);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Refuse new pushes; queued items remain poppable (drain).
    pub fn close(&self) {
        self.lock().closed = true;
        self.not_empty.notify_all();
    }

    /// Close *and* drop everything still queued. Used when the last
    /// worker of a route dies abnormally: dropping a queued request
    /// drops its response channel, which unblocks its client with a
    /// disconnect instead of a hang.
    pub fn close_and_drain(&self) {
        let drained = {
            let mut g = self.lock();
            g.closed = true;
            self.depth.store(0, Ordering::Relaxed);
            std::mem::take(&mut g.items)
        };
        drop(drained); // outside the lock: item Drop impls may be slow
        self.not_empty.notify_all();
    }

    /// Pop without blocking.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.lock();
        let item = g.items.pop_front();
        self.depth.store(g.items.len(), Ordering::Relaxed);
        item
    }

    /// Block until an item arrives; `None` iff the queue is closed and
    /// drained (the consumer's shutdown signal).
    pub fn pop_blocking(&self) -> Option<T> {
        let mut g = self.lock();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.depth.store(g.items.len(), Ordering::Relaxed);
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self
                .not_empty
                .wait(g)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Block up to `timeout` for an item.
    pub fn pop_timeout(&self, timeout: Duration) -> PopTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut g = self.lock();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.depth.store(g.items.len(), Ordering::Relaxed);
                return PopTimeout::Item(item);
            }
            if g.closed {
                return PopTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimeout::TimedOut;
            }
            let (guard, _) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            g = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo_and_depth_gauge() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.capacity(), 4);
        assert!(q.is_empty());
        for i in 0..4 {
            q.try_push(i).unwrap();
            assert_eq!(q.len(), i + 1);
        }
        assert!(matches!(q.try_push(9), Err(PushError::Full(9))));
        assert_eq!(q.try_pop(), Some(0));
        assert_eq!(q.len(), 3);
        q.try_push(9).unwrap();
        assert_eq!(
            (1..4).chain([9]).collect::<Vec<_>>(),
            std::iter::from_fn(|| q.try_pop()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let q = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.try_push(1).unwrap();
        assert!(matches!(q.try_push(2), Err(PushError::Full(2))));
    }

    #[test]
    fn close_rejects_pushes_but_drains_items() {
        let q = BoundedQueue::new(8);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert_eq!(q.pop_blocking(), Some(1));
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn close_and_drain_drops_queued_items() {
        let q = BoundedQueue::new(8);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close_and_drain();
        assert_eq!(q.len(), 0);
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn pop_timeout_times_out_then_delivers() {
        let q = BoundedQueue::new(2);
        let t0 = Instant::now();
        assert!(matches!(
            q.pop_timeout(Duration::from_millis(10)),
            PopTimeout::TimedOut
        ));
        assert!(t0.elapsed() >= Duration::from_millis(10));
        q.try_push(7).unwrap();
        assert!(matches!(
            q.pop_timeout(Duration::from_millis(10)),
            PopTimeout::Item(7)
        ));
    }

    #[test]
    fn blocked_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(Duration::from_millis(5));
        q.try_push(42).unwrap();
        assert_eq!(popper.join().unwrap(), Some(42));

        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || q2.pop_blocking());
        std::thread::sleep(Duration::from_millis(5));
        q.close();
        assert_eq!(popper.join().unwrap(), None);
    }

    #[test]
    fn poisoned_lock_recovers_instead_of_cascading() {
        let q = Arc::new(BoundedQueue::new(4));
        q.try_push(1).unwrap();
        let poisoner = Arc::clone(&q);
        let _ = std::thread::spawn(move || {
            let _g = poisoner.inner.lock().unwrap();
            panic!("poison the queue lock");
        })
        .join();
        assert!(q.inner.is_poisoned(), "test setup must poison the lock");
        // every operation keeps working on the poisoned lock
        assert_eq!(q.try_pop(), Some(1));
        q.try_push(2).unwrap();
        assert_eq!(q.len(), 1);
        q.close();
        assert_eq!(q.pop_blocking(), Some(2));
        assert_eq!(q.pop_blocking(), None);
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 3;
        const PER_PRODUCER: usize = 500;
        let q = Arc::new(BoundedQueue::new(16));
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        let mut item = p * PER_PRODUCER + i;
                        // full queue: retry (test producers want lossless
                        // delivery; serving producers shed instead)
                        loop {
                            match q.try_push(item) {
                                Ok(()) => break,
                                Err(PushError::Full(v)) => {
                                    item = v;
                                    std::thread::yield_now();
                                }
                                Err(PushError::Closed(_)) => panic!("closed early"),
                            }
                        }
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..CONSUMERS)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop_blocking() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<usize> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..PRODUCERS * PER_PRODUCER).collect::<Vec<_>>());
    }
}
