//! Bench harness: regenerates every table and figure of the paper's
//! evaluation section (§4) on this machine.
//!
//! * [`speedup`] — the core measurement: train/inference epoch times for
//!   a (dataset, clauses, features) cell under two backends; speedup =
//!   t_unindexed / t_indexed (the paper's Tables 1–3 cells).
//! * [`tables`] — the three table grids (M1–M4, I1–I4, F1–F4).
//! * [`figures`] — epoch-time-vs-clauses series (Figs. 3–8) as CSV.
//! * [`report`] — markdown/CSV emission.
//!
//! Absolute seconds depend on the machine; the paper's *shape* —
//! who wins, by what factor, where the crossovers sit — is what the
//! harness is expected to reproduce (see EXPERIMENTS.md).

pub mod figures;
pub mod report;
pub mod speedup;
pub mod tables;

pub use speedup::{measure_speedup, ExpConfig, SpeedupResult};
