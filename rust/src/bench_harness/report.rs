//! Markdown / CSV / JSON emission for harness results.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::Json;

/// Render a GitHub-flavoured markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", headers.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for row in rows {
        let _ = writeln!(out, "| {} |", row.join(" | "));
    }
    out
}

/// Write a CSV file (no quoting needed for our numeric payloads).
pub fn write_csv(path: &Path, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let mut text = String::new();
    let _ = writeln!(text, "{}", headers.join(","));
    for row in rows {
        let _ = writeln!(text, "{}", row.join(","));
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, text)
}

/// Write a machine-readable JSON report (the repo's `BENCH_*.json`
/// perf-trajectory files). Creates parent directories as needed.
pub fn write_json(path: &Path, value: &Json) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.to_string())
}

/// Format a float with 2 decimals (paper table style).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format seconds with adaptive precision.
pub fn secs(x: f64) -> String {
    if x < 0.001 {
        format!("{:.1}us", x * 1e6)
    } else if x < 1.0 {
        format!("{:.2}ms", x * 1e3)
    } else {
        format!("{x:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "| a | b |");
        assert_eq!(lines[1], "|---|---|");
        assert_eq!(lines[3], "| 3 | 4 |");
    }

    #[test]
    fn csv_roundtrip() {
        let p = std::env::temp_dir().join(format!("tmi-csv-{}.csv", std::process::id()));
        write_csv(&p, &["x", "y"], &[vec!["1".into(), "2.5".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "x,y\n1,2.5\n");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn json_report_roundtrip() {
        let p = std::env::temp_dir().join(format!("tmi-json-{}.json", std::process::id()));
        let v = Json::obj([("bench", Json::str("batch_infer")), ("x", Json::num(2.5))]);
        write_json(&p, &v).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), v);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(3.14159), "3.14");
        assert_eq!(secs(0.0000005), "0.5us");
        assert_eq!(secs(0.5), "500.00ms");
        assert_eq!(secs(2.0), "2.00s");
    }
}
