//! Tables 1–3: the speedup grids (clauses × features) for MNIST-, IMDb-
//! and Fashion-MNIST-shaped workloads.
//!
//! A table run produces the full cell matrix once; the figure renderers
//! ([`crate::bench_harness::figures`]) re-use the same cells (the
//! paper's figures plot the very measurements its tables tabulate).
//!
//! The paper's grid (20k clauses, 60k samples, 400+ epoch-minutes per
//! cell) is scaled by a [`Scale`]: `quick` for CI-sized smoke runs,
//! `standard` for the EXPERIMENTS.md numbers, `paper` for the full grid.
//! Speedup *ratios* are sample-count independent once clause lengths
//! reach regime (each sample costs the same), which is what warmup
//! epochs establish.

use std::path::Path;

use crate::bench_harness::report::{f2, markdown_table};
use crate::bench_harness::speedup::{measure_speedup, ExpConfig, SpeedupResult};
use crate::data::mnist::{self, Split};
use crate::data::synth::ImageStyle;
use crate::data::{imdb, Dataset};
use crate::util::Json;

/// Which paper table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableId {
    /// Table 1: MNIST, features 784/1568/2352/3136 (1–4 grey levels).
    Mnist,
    /// Table 2: IMDb, features 5000/10000/15000/20000.
    Imdb,
    /// Table 3: Fashion-MNIST, features 784–3136.
    Fashion,
}

impl TableId {
    /// The table's caption in the paper.
    pub fn title(self) -> &'static str {
        match self {
            TableId::Mnist => "Table 1: indexing speedup on MNIST",
            TableId::Imdb => "Table 2: indexing speedup on IMDb",
            TableId::Fashion => "Table 3: indexing speedup on Fashion-MNIST",
        }
    }
}

/// Grid scaling.
#[derive(Clone, Debug)]
pub struct Scale {
    /// Training samples per cell.
    pub train_samples: usize,
    /// Held-out samples per cell.
    pub test_samples: usize,
    /// Clause counts forming the table rows.
    pub clause_grid: Vec<usize>,
    /// Image grey levels (Tables 1/3) — paper: 1..=4.
    pub image_levels: Vec<usize>,
    /// BoW vocabulary sizes (Table 2) — paper: 5k/10k/15k/20k.
    pub bow_features: Vec<usize>,
    /// Untimed warm-up epochs before measurement.
    pub warmup_epochs: usize,
    /// Timed epochs averaged into each cell.
    pub timed_epochs: usize,
}

impl Scale {
    /// Smoke-test scale (~seconds per table).
    pub fn quick() -> Self {
        Scale {
            train_samples: 150,
            test_samples: 150,
            clause_grid: vec![100, 200],
            image_levels: vec![1, 2],
            bow_features: vec![500, 1000],
            warmup_epochs: 1,
            timed_epochs: 1,
        }
    }

    /// The EXPERIMENTS.md scale (~minutes per table): large enough for
    /// the paper's asymptotic behaviour to show.
    pub fn standard() -> Self {
        Scale {
            train_samples: 1000,
            test_samples: 1000,
            clause_grid: vec![500, 1000, 2000, 5000],
            image_levels: vec![1, 2, 3, 4],
            bow_features: vec![2500, 5000, 10000],
            warmup_epochs: 1,
            timed_epochs: 1,
        }
    }

    /// The paper's full grid (hours).
    pub fn paper() -> Self {
        Scale {
            train_samples: 60000,
            test_samples: 10000,
            clause_grid: vec![1000, 2000, 5000, 10000, 20000],
            image_levels: vec![1, 2, 3, 4],
            bow_features: vec![5000, 10000, 15000, 20000],
            warmup_epochs: 1,
            timed_epochs: 1,
        }
    }

    /// Scale chosen by `TMI_SCALE` env var (quick|standard|paper).
    pub fn from_env() -> Self {
        match std::env::var("TMI_SCALE").as_deref() {
            Ok("paper") => Self::paper(),
            Ok("standard") => Self::standard(),
            _ => Self::quick(),
        }
    }
}

/// One feature configuration (a column pair of the table).
#[derive(Clone, Debug)]
pub struct FeatureCol {
    /// Column header (feature count or dataset variant).
    pub label: String,
    /// Training split for this column.
    pub train: Dataset,
    /// Held-out split for this column.
    pub test: Dataset,
}

/// All cells of one table.
#[derive(Clone, Debug)]
pub struct TableResult {
    /// Which paper table this reproduces.
    pub id: TableId,
    /// `cells[col][row]` — column = feature config, row = clause count.
    pub cells: Vec<Vec<SpeedupResult>>,
    /// Column headers, aligned with `cells` columns.
    pub col_labels: Vec<String>,
    /// Clause counts, aligned with `cells` rows.
    pub clause_grid: Vec<usize>,
}

/// Build the feature-column datasets for a table.
pub fn feature_columns(id: TableId, scale: &Scale, data_dir: Option<&Path>) -> Vec<FeatureCol> {
    match id {
        TableId::Mnist | TableId::Fashion => {
            let style = if id == TableId::Mnist {
                ImageStyle::Digits
            } else {
                ImageStyle::Fashion
            };
            let seed = if id == TableId::Mnist { 101 } else { 103 };
            scale
                .image_levels
                .iter()
                .map(|&levels| {
                    let train = mnist::load_or_synthesize(
                        data_dir,
                        style,
                        Split::Train,
                        levels,
                        scale.train_samples,
                        seed,
                    );
                    let test = mnist::load_or_synthesize(
                        data_dir,
                        style,
                        Split::Test,
                        levels,
                        scale.test_samples,
                        seed,
                    );
                    FeatureCol {
                        label: format!("{}", levels * 784),
                        train,
                        test,
                    }
                })
                .collect()
        }
        TableId::Imdb => scale
            .bow_features
            .iter()
            .map(|&features| {
                let train =
                    imdb::load_or_synthesize(None, features, scale.train_samples, 0, 102);
                let test =
                    imdb::load_or_synthesize(None, features, scale.test_samples, 1, 102);
                FeatureCol {
                    label: format!("{features}"),
                    train,
                    test,
                }
            })
            .collect(),
    }
}

/// Run all cells of one table.
pub fn run_table(
    id: TableId,
    scale: &Scale,
    data_dir: Option<&Path>,
    mut progress: impl FnMut(&str),
) -> TableResult {
    let cols = feature_columns(id, scale, data_dir);
    let mut cells = Vec::with_capacity(cols.len());
    for col in &cols {
        let mut col_cells = Vec::with_capacity(scale.clause_grid.len());
        for &clauses in &scale.clause_grid {
            let mut cfg = ExpConfig::new(
                format!("{:?}-f{}-c{}", id, col.label, clauses),
                clauses,
            );
            cfg.warmup_epochs = scale.warmup_epochs;
            cfg.timed_epochs = scale.timed_epochs;
            progress(&cfg.name);
            col_cells.push(measure_speedup(&cfg, &col.train, &col.test));
        }
        cells.push(col_cells);
    }
    TableResult {
        id,
        col_labels: cols.iter().map(|c| c.label.clone()).collect(),
        clause_grid: scale.clause_grid.clone(),
        cells,
    }
}

impl TableResult {
    /// Paper-layout markdown: rows = clauses, column pairs = features
    /// (Train | Test speedups).
    pub fn render_markdown(&self) -> String {
        let mut headers: Vec<String> = vec!["Clauses".into()];
        for label in &self.col_labels {
            headers.push(format!("f={label} Train"));
            headers.push(format!("f={label} Test"));
        }
        let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let rows: Vec<Vec<String>> = self
            .clause_grid
            .iter()
            .enumerate()
            .map(|(r, &clauses)| {
                let mut row = vec![clauses.to_string()];
                for col in &self.cells {
                    row.push(f2(col[r].train_speedup));
                    row.push(f2(col[r].test_speedup));
                }
                row
            })
            .collect();
        format!("{}\n{}", self.id.title(), markdown_table(&header_refs, &rows))
    }

    /// Flat CSV rows: one per cell, with raw times (feeds the figures).
    pub fn csv_rows(&self) -> (Vec<&'static str>, Vec<Vec<String>>) {
        let headers = vec![
            "dataset",
            "features",
            "clauses",
            "naive_train_s",
            "indexed_train_s",
            "naive_test_s",
            "indexed_test_s",
            "train_speedup",
            "test_speedup",
            "accuracy",
            "mean_clause_length",
        ];
        let mut rows = Vec::new();
        for (c, col) in self.cells.iter().enumerate() {
            for cell in col {
                rows.push(vec![
                    format!("{:?}", self.id),
                    self.col_labels[c].clone(),
                    cell.total_clauses.to_string(),
                    format!("{:.6}", cell.baseline.train_epoch_s),
                    format!("{:.6}", cell.indexed.train_epoch_s),
                    format!("{:.6}", cell.baseline.test_s),
                    format!("{:.6}", cell.indexed.test_s),
                    f2(cell.train_speedup),
                    f2(cell.test_speedup),
                    format!("{:.4}", cell.indexed.accuracy),
                    f2(cell.mean_clause_length),
                ]);
            }
        }
        (headers, rows)
    }

    /// Mean (train, test) indexed-vs-naive speedups over all cells —
    /// the scalar trajectory the nightly CI job gates on.
    pub fn mean_speedups(&self) -> (f64, f64) {
        let cells: Vec<&SpeedupResult> = self.cells.iter().flatten().collect();
        if cells.is_empty() {
            return (0.0, 0.0);
        }
        let n = cells.len() as f64;
        (
            cells.iter().map(|c| c.train_speedup).sum::<f64>() / n,
            cells.iter().map(|c| c.test_speedup).sum::<f64>() / n,
        )
    }

    /// Machine-readable `BENCH_table*.json` payload: per-cell raw
    /// timings + speedups, plus the mean-speedup headline.
    pub fn to_json(&self) -> Json {
        let mut cells = Vec::new();
        for (c, col) in self.cells.iter().enumerate() {
            for cell in col {
                cells.push(Json::obj([
                    ("features", Json::str(self.col_labels[c].clone())),
                    ("clauses", Json::num(cell.total_clauses as f64)),
                    ("naive_train_s", Json::num(cell.baseline.train_epoch_s)),
                    ("indexed_train_s", Json::num(cell.indexed.train_epoch_s)),
                    ("naive_test_s", Json::num(cell.baseline.test_s)),
                    ("indexed_test_s", Json::num(cell.indexed.test_s)),
                    ("train_speedup", Json::num(cell.train_speedup)),
                    ("test_speedup", Json::num(cell.test_speedup)),
                    ("accuracy", Json::num(cell.indexed.accuracy)),
                    ("mean_clause_length", Json::num(cell.mean_clause_length)),
                ]));
            }
        }
        let (train_mean, test_mean) = self.mean_speedups();
        Json::obj([
            ("bench", Json::str(format!("{:?}", self.id).to_lowercase())),
            ("title", Json::str(self.id.title())),
            ("mean_train_speedup", Json::num(train_mean)),
            ("mean_test_speedup", Json::num(test_mean)),
            ("cells", Json::Arr(cells)),
        ])
    }

    /// The mean indexed-vs-naive *test* speedup must not fall below
    /// `min` (the paper's headline claim — indexing must keep paying).
    /// Panics (failing the bench process) on regression.
    pub fn assert_speedup_floor(&self, min: f64) {
        let (train_mean, test_mean) = self.mean_speedups();
        eprintln!(
            "speedup floor check: mean train {train_mean:.2}x / test {test_mean:.2}x (floor {min})"
        );
        assert!(
            test_mean >= min,
            "{:?}: mean indexed-vs-naive test speedup {test_mean:.2}x fell below floor {min}",
            self.id
        );
    }

    /// Nightly-CI entry point: applies [`TableResult::assert_speedup_floor`]
    /// iff `TMI_ASSERT_MIN_TEST_SPEEDUP` is set (bench binaries only —
    /// tests call the parameterized form to avoid mutating process env).
    pub fn assert_speedup_floor_from_env(&self) {
        if let Ok(raw) = std::env::var("TMI_ASSERT_MIN_TEST_SPEEDUP") {
            let min: f64 = raw
                .parse()
                .expect("TMI_ASSERT_MIN_TEST_SPEEDUP must be a float");
            self.assert_speedup_floor(min);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_scale() -> Scale {
        Scale {
            train_samples: 60,
            test_samples: 40,
            clause_grid: vec![20, 40],
            image_levels: vec![1],
            bow_features: vec![300],
            warmup_epochs: 1,
            timed_epochs: 1,
        }
    }

    #[test]
    fn runs_micro_mnist_table() {
        let t = run_table(TableId::Mnist, &micro_scale(), None, |_| {});
        assert_eq!(t.cells.len(), 1);
        assert_eq!(t.cells[0].len(), 2);
        let md = t.render_markdown();
        assert!(md.contains("Table 1"));
        assert!(md.contains("| 20 |"));
        let (h, rows) = t.csv_rows();
        assert_eq!(h.len(), rows[0].len());
        assert_eq!(rows.len(), 2);
        // BENCH json mirrors the cells and carries the headline means
        let (train_mean, test_mean) = t.mean_speedups();
        assert!(train_mean > 0.0 && test_mean > 0.0);
        let j = Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("mnist"));
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 2);
        let got = j.get("mean_test_speedup").unwrap().as_f64().unwrap();
        assert!((got - test_mean).abs() < 1e-9);
        // floor of 0 can never trip (env mutation stays out of tests)
        t.assert_speedup_floor(0.0);
    }

    #[test]
    fn runs_micro_imdb_table() {
        let t = run_table(TableId::Imdb, &micro_scale(), None, |_| {});
        assert_eq!(t.col_labels, vec!["300"]);
        assert!(t.cells[0][0].indexed.test_s > 0.0);
    }

    #[test]
    fn fashion_uses_fashion_style() {
        let cols = feature_columns(TableId::Fashion, &micro_scale(), None);
        assert!(cols[0].train.name.contains("fashion"));
        let cols = feature_columns(TableId::Mnist, &micro_scale(), None);
        assert!(cols[0].train.name.contains("mnist"));
    }

    #[test]
    fn scale_from_env_default_is_quick() {
        std::env::remove_var("TMI_SCALE");
        assert_eq!(Scale::from_env().train_samples, Scale::quick().train_samples);
    }
}
