//! The core speedup measurement (one cell of Tables 1–3).
//!
//! Protocol: for each backend, train `warmup_epochs` (untimed — lets
//! clause lengths reach a representative regime, as the paper's
//! averages over full training runs do), then time `timed_epochs` of
//! training and one inference pass over the test set. Training is
//! deterministic given the seed, so both backends traverse *identical*
//! machines — the comparison isolates pure evaluation/maintenance cost.

use crate::data::Dataset;
use crate::eval::Backend;
use crate::tm::params::TMParams;
use crate::tm::trainer::Trainer;
use crate::util::timer::time_it;
use crate::util::Rng;

/// One experiment cell.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Experiment label in the emitted table.
    pub name: String,
    /// Total clauses across every class.
    pub total_clauses: usize,
    /// Vote clamp threshold `T`.
    pub threshold: u32,
    /// Specificity `s`.
    pub s: f64,
    /// RNG seed shared by both backends' runs.
    pub seed: u64,
    /// Untimed warm-up epochs before measurement.
    pub warmup_epochs: usize,
    /// Timed epochs averaged into the result.
    pub timed_epochs: usize,
}

impl ExpConfig {
    /// Paper-default experiment config for the given shape.
    pub fn new(name: impl Into<String>, total_clauses: usize) -> Self {
        ExpConfig {
            name: name.into(),
            total_clauses,
            threshold: 25,
            s: 6.0,
            seed: 42,
            warmup_epochs: 1,
            timed_epochs: 1,
        }
    }
}

/// Timings for one backend on one cell.
#[derive(Clone, Copy, Debug, Default)]
pub struct BackendTimes {
    /// Seconds per timed training epoch (mean).
    pub train_epoch_s: f64,
    /// Seconds for one inference pass over the test set.
    pub test_s: f64,
    /// Test accuracy after training (sanity: backends must agree).
    pub accuracy: f64,
}

/// One full cell: a backend pair and the derived speedups.
#[derive(Clone, Debug)]
pub struct SpeedupResult {
    /// Experiment label.
    pub name: String,
    /// Raw boolean features of the workload.
    pub features: usize,
    /// Total clauses across every class.
    pub total_clauses: usize,
    /// Timings for the non-indexed baseline backend.
    pub baseline: BackendTimes,
    /// Timings for the clause-indexed backend.
    pub indexed: BackendTimes,
    /// `baseline.train / indexed.train` (paper's "Train" columns).
    pub train_speedup: f64,
    /// `baseline.test / indexed.test` (paper's "Test" columns).
    pub test_speedup: f64,
    /// Mean learned clause length (paper §3 Remarks statistic).
    pub mean_clause_length: f64,
}

/// Train + time one backend on a cell.
pub fn run_backend(
    cfg: &ExpConfig,
    backend: Backend,
    train: &Dataset,
    test: &Dataset,
) -> (BackendTimes, Trainer) {
    let params = TMParams::from_total_clauses(train.classes, cfg.total_clauses, train.features)
        .with_threshold(cfg.threshold)
        .with_s(cfg.s)
        .with_seed(cfg.seed);
    let mut trainer = Trainer::new(params, backend);
    // Epoch order must be identical across backends: derive it from the
    // experiment seed, not the trainer's internal stream.
    let mut order_rng = Rng::new(cfg.seed ^ 0x0def_ace0);
    for _ in 0..cfg.warmup_epochs {
        let order = train.epoch_order(&mut order_rng);
        trainer.train_epoch(train.iter_order(&order));
    }
    let mut train_total = 0.0;
    for _ in 0..cfg.timed_epochs.max(1) {
        let order = train.epoch_order(&mut order_rng);
        let (_, secs) = time_it(|| trainer.train_epoch(train.iter_order(&order)));
        train_total += secs;
    }
    // Indexed inference goes through the class-fused engine, which is
    // rebuilt lazily after training; warm it outside the timed region
    // so `test_s` measures steady-state inference, not the one-off
    // snapshot build.
    if let Some((lits, _)) = test.iter().next() {
        let _ = trainer.predict(lits);
    }
    let (accuracy, test_s) = time_it(|| trainer.accuracy(test.iter()));
    (
        BackendTimes {
            train_epoch_s: train_total / cfg.timed_epochs.max(1) as f64,
            test_s,
            accuracy,
        },
        trainer,
    )
}

/// Measure one cell: `baseline_backend` (paper: naive) vs indexed.
pub fn measure_speedup_vs(
    cfg: &ExpConfig,
    baseline_backend: Backend,
    train: &Dataset,
    test: &Dataset,
) -> SpeedupResult {
    let (baseline, _) = run_backend(cfg, baseline_backend, train, test);
    let (indexed, trainer) = run_backend(cfg, Backend::Indexed, train, test);
    assert!(
        (baseline.accuracy - indexed.accuracy).abs() < 1e-12,
        "backends diverged: {} vs {} — evaluation is broken",
        baseline.accuracy,
        indexed.accuracy
    );
    SpeedupResult {
        name: cfg.name.clone(),
        features: train.features,
        total_clauses: cfg.total_clauses,
        train_speedup: baseline.train_epoch_s / indexed.train_epoch_s,
        test_speedup: baseline.test_s / indexed.test_s,
        mean_clause_length: trainer.tm.mean_clause_length(),
        baseline,
        indexed,
    }
}

/// Paper-default cell: naive baseline vs indexed.
pub fn measure_speedup(cfg: &ExpConfig, train: &Dataset, test: &Dataset) -> SpeedupResult {
    measure_speedup_vs(cfg, Backend::Naive, train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn speedup_cell_runs_and_backends_agree() {
        let all = synth::image_dataset(synth::ImageStyle::Digits, 4, 260, 1, 3);
        let train = all.slice(0, 200);
        let test = all.slice(200, 260);
        let cfg = ExpConfig::new("smoke", 80);
        let r = measure_speedup(&cfg, &train, &test);
        assert!(r.baseline.train_epoch_s > 0.0);
        assert!(r.indexed.test_s > 0.0);
        assert!(r.train_speedup.is_finite());
        assert_eq!(r.features, 784);
        // accuracies asserted equal inside measure_speedup
    }

    #[test]
    fn indexed_inference_wins_at_scale() {
        // A clause-heavy cell where indexing must win at inference
        // (the paper's central claim). Small sample count keeps it fast.
        let all = synth::bow(2000, 160, 7);
        let train = all.slice(0, 120);
        let test = all.slice(120, 160);
        let mut cfg = ExpConfig::new("idx-wins", 400);
        cfg.warmup_epochs = 1;
        let r = measure_speedup(&cfg, &train, &test);
        assert!(
            r.test_speedup > 1.0,
            "indexed inference should beat naive at 400 clauses x 2000 features, got {}",
            r.test_speedup
        );
    }
}
