//! Figures 3–8: average epoch time (train / inference) as a function of
//! clause count, indexed vs unindexed, one series per feature size.
//!
//! The figures plot exactly the measurements the tables tabulate, so a
//! [`TableResult`] renders directly into figure CSVs — one file per
//! figure, one row per clause count, one column pair per feature size.
//! The paper's qualitative claims to verify: both series grow ~linearly
//! in clause count with similar slopes, and the indexed series sits
//! several-fold lower at inference.

use std::path::Path;

use crate::bench_harness::report::write_csv;
use crate::bench_harness::tables::{TableId, TableResult};

/// Which time series a figure plots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Training-epoch timing figure.
    Train,
    /// Inference timing figure.
    Inference,
}

/// Paper figure ids and their (table, phase) mapping.
pub fn figure_spec(fig: usize) -> Option<(TableId, Phase)> {
    match fig {
        3 => Some((TableId::Mnist, Phase::Train)),
        4 => Some((TableId::Mnist, Phase::Inference)),
        5 => Some((TableId::Imdb, Phase::Train)),
        6 => Some((TableId::Imdb, Phase::Inference)),
        7 => Some((TableId::Fashion, Phase::Train)),
        8 => Some((TableId::Fashion, Phase::Inference)),
        _ => None,
    }
}

/// Render one figure's CSV: `clauses, <f>_naive_s, <f>_indexed_s, ...`
pub fn figure_csv(table: &TableResult, phase: Phase) -> (Vec<String>, Vec<Vec<String>>) {
    let mut headers = vec!["clauses".to_string()];
    for label in &table.col_labels {
        headers.push(format!("f{label}_naive_s"));
        headers.push(format!("f{label}_indexed_s"));
    }
    let rows: Vec<Vec<String>> = table
        .clause_grid
        .iter()
        .enumerate()
        .map(|(r, &clauses)| {
            let mut row = vec![clauses.to_string()];
            for col in &table.cells {
                let cell = &col[r];
                let (naive, indexed) = match phase {
                    Phase::Train => {
                        (cell.baseline.train_epoch_s, cell.indexed.train_epoch_s)
                    }
                    Phase::Inference => (cell.baseline.test_s, cell.indexed.test_s),
                };
                row.push(format!("{naive:.6}"));
                row.push(format!("{indexed:.6}"));
            }
            row
        })
        .collect();
    (headers, rows)
}

/// Write both figures derived from one table (e.g. Figs. 3+4 from
/// Table 1's cells) into `out_dir/figN_<name>.csv`.
pub fn write_figures(table: &TableResult, out_dir: &Path) -> std::io::Result<Vec<String>> {
    let (figs, name) = match table.id {
        TableId::Mnist => ([3usize, 4], "mnist"),
        TableId::Imdb => ([5, 6], "imdb"),
        TableId::Fashion => ([7, 8], "fmnist"),
    };
    let mut written = Vec::new();
    for fig in figs {
        let (_, phase) = figure_spec(fig).unwrap();
        let (headers, rows) = figure_csv(table, phase);
        let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let path = out_dir.join(format!(
            "fig{fig}_{name}_{}.csv",
            match phase {
                Phase::Train => "train",
                Phase::Inference => "inference",
            }
        ));
        write_csv(&path, &header_refs, &rows)?;
        written.push(path.display().to_string());
    }
    Ok(written)
}

/// Check the paper's qualitative claim on a series: time grows roughly
/// linearly with clause count (R² of a least-squares line).
pub fn linearity_r2(clauses: &[usize], times: &[f64]) -> f64 {
    assert_eq!(clauses.len(), times.len());
    let n = clauses.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let xs: Vec<f64> = clauses.iter().map(|&c| c as f64).collect();
    let mx = xs.iter().sum::<f64>() / n;
    let my = times.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(times).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = times.iter().map(|y| (y - my) * (y - my)).sum();
    if sxx == 0.0 || syy == 0.0 {
        return 1.0;
    }
    (sxy * sxy) / (sxx * syy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::tables::{run_table, Scale};

    #[test]
    fn figure_mapping_is_complete() {
        for fig in 3..=8 {
            assert!(figure_spec(fig).is_some(), "figure {fig}");
        }
        assert!(figure_spec(1).is_none());
        assert!(figure_spec(9).is_none());
    }

    #[test]
    fn figures_from_micro_table() {
        let scale = Scale {
            train_samples: 50,
            test_samples: 30,
            clause_grid: vec![20, 40],
            image_levels: vec![1],
            bow_features: vec![200],
            warmup_epochs: 0,
            timed_epochs: 1,
        };
        let t = run_table(TableId::Mnist, &scale, None, |_| {});
        let (headers, rows) = figure_csv(&t, Phase::Train);
        assert_eq!(headers, vec!["clauses", "f784_naive_s", "f784_indexed_s"]);
        assert_eq!(rows.len(), 2);
        let dir = std::env::temp_dir().join(format!("tmi-figs-{}", std::process::id()));
        let written = write_figures(&t, &dir).unwrap();
        assert_eq!(written.len(), 2);
        assert!(written[0].contains("fig3_mnist_train"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn r2_of_perfect_line_is_one() {
        let r2 = linearity_r2(&[1, 2, 3, 4], &[2.0, 4.0, 6.0, 8.0]);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_of_noise_is_low() {
        let r2 = linearity_r2(&[1, 2, 3, 4, 5, 6], &[5.0, 1.0, 4.0, 2.0, 5.0, 1.0]);
        assert!(r2 < 0.5, "r2={r2}");
    }
}
