//! The Tsetlin Machine substrate.
//!
//! * [`params`] — hyper-parameters and validation.
//! * [`bank`] — per-class clause bank: TA states, include-masks, flip
//!   detection (the state machine of §2 of the paper).
//! * [`feedback`] — Type I / Type II feedback (learning rules).
//! * [`classifier`] — multi-class machine (eq. 3/4).
//! * [`trainer`] — the training loop: clause-update sampling against the
//!   voting margin `T`, paired target/negative-class updates.
//! * [`io`] — model save/load and densification for the XLA backend.

pub mod bank;
pub mod classifier;
pub mod feedback;
pub mod interpret;
pub mod io;
pub mod params;
pub mod trainer;

pub use bank::{ClauseBank, Flip, TaLayout};
pub use classifier::MultiClassTM;
pub use params::TMParams;
pub use trainer::Trainer;
