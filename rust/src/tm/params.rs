//! Hyper-parameters of a multi-class Tsetlin Machine.

use crate::tm::bank::TaLayout;
use crate::util::simd::SimdMode;
use crate::util::Json;

/// Hyper-parameters (paper §2). `clauses_per_class` is the paper's `n`;
/// tables report the *total* clause count `m * n` — helpers convert.
#[derive(Clone, Debug, PartialEq)]
pub struct TMParams {
    /// Number of classes `m`.
    pub classes: usize,
    /// Clauses per class `n` (must be even: alternating +/- polarity).
    pub clauses_per_class: usize,
    /// Input features `o`; literals are `2o` (feature + negation).
    pub features: usize,
    /// Voting margin `T` — the annealing-style cooling parameter gating
    /// how many clauses receive feedback per sample.
    pub threshold: u32,
    /// Specificity `s` — reward/penalty split (1/s vs 1-1/s).
    pub s: f64,
    /// Boost true-positive feedback (include reinforcement with
    /// probability 1 instead of (s-1)/s). Matches CAIR's default.
    pub boost_true_positive: bool,
    /// RNG seed for the whole machine (training is fully deterministic
    /// given the seed and the dataset order).
    pub seed: u64,
    /// Weighted TM (paper ref [8]): integer clause weights, letting one
    /// clause represent many — fewer clauses for the same accuracy.
    pub weighted: bool,
    /// TA storage layout (default bit-sliced). A *representation*
    /// choice, not a learning hyper-parameter: both layouts produce
    /// bit-identical training trajectories and flip streams — the
    /// sliced layout turns per-literal feedback into word-parallel
    /// bitplane arithmetic, the scalar layout is the portable escape
    /// hatch (and the serialized form either way, see
    /// [`crate::tm::io`]).
    pub ta_layout: TaLayout,
    /// SIMD lane selector for the hot loops (default auto). Like
    /// `ta_layout`, a *representation/dispatch* choice, not a learning
    /// hyper-parameter: scalar, wide, and auto produce bit-identical
    /// machines, scores, flip streams, and RNG positions
    /// (`rust/tests/simd_equiv.rs`) — only throughput changes. See
    /// [`crate::util::simd`].
    pub simd: SimdMode,
}

impl TMParams {
    /// Paper-default hyperparameters for the given machine shape.
    pub fn new(classes: usize, clauses_per_class: usize, features: usize) -> Self {
        TMParams {
            classes,
            clauses_per_class,
            features,
            threshold: 15,
            s: 3.9,
            boost_true_positive: true,
            seed: 42,
            weighted: false,
            ta_layout: TaLayout::default(),
            simd: SimdMode::default(),
        }
    }

    /// Toggle integer clause weighting (arXiv 1911.12607).
    pub fn with_weighted(mut self, weighted: bool) -> Self {
        self.weighted = weighted;
        self
    }

    /// Select the TA storage layout (bit-sliced default or scalar).
    pub fn with_ta_layout(mut self, layout: TaLayout) -> Self {
        self.ta_layout = layout;
        self
    }

    /// Set the SIMD lane selector (see [`TMParams::simd`]).
    pub fn with_simd(mut self, simd: SimdMode) -> Self {
        self.simd = simd;
        self
    }

    /// Set the vote clamp threshold `T`.
    pub fn with_threshold(mut self, t: u32) -> Self {
        self.threshold = t;
        self
    }

    /// Set the specificity `s` (feedback forget/memorize ratio).
    pub fn with_s(mut self, s: f64) -> Self {
        self.s = s;
        self
    }

    /// Set the RNG seed that every training stream derives from.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Literal count `2o`.
    #[inline]
    pub fn n_literals(&self) -> usize {
        2 * self.features
    }

    /// Total clauses across classes (`m * n`, the number the paper's
    /// tables index by).
    #[inline]
    pub fn total_clauses(&self) -> usize {
        self.classes * self.clauses_per_class
    }

    /// Build params from a paper-style *total* clause budget, split
    /// evenly across classes (rounded up to an even per-class count).
    pub fn from_total_clauses(
        classes: usize,
        total_clauses: usize,
        features: usize,
    ) -> Self {
        let per = (total_clauses / classes).max(2);
        let per = per + per % 2;
        TMParams::new(classes, per, features)
    }

    /// JSON encoding (model files, manifests).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("classes", Json::num(self.classes as f64)),
            ("clauses_per_class", Json::num(self.clauses_per_class as f64)),
            ("features", Json::num(self.features as f64)),
            ("threshold", Json::num(self.threshold as f64)),
            ("s", Json::num(self.s)),
            ("boost_true_positive", Json::Bool(self.boost_true_positive)),
            ("seed", Json::num(self.seed as f64)),
            ("weighted", Json::Bool(self.weighted)),
            ("ta_layout", Json::str(self.ta_layout.name())),
            ("simd", Json::str(self.simd.name())),
        ])
    }

    /// Parse params from the model-file JSON block.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let field = |name: &str| v.get(name).ok_or_else(|| format!("missing field '{name}'"));
        let p = TMParams {
            classes: field("classes")?.as_usize().ok_or("classes must be uint")?,
            clauses_per_class: field("clauses_per_class")?
                .as_usize()
                .ok_or("clauses_per_class must be uint")?,
            features: field("features")?.as_usize().ok_or("features must be uint")?,
            threshold: field("threshold")?.as_usize().ok_or("threshold must be uint")? as u32,
            s: field("s")?.as_f64().ok_or("s must be number")?,
            boost_true_positive: field("boost_true_positive")?
                .as_bool()
                .ok_or("boost_true_positive must be bool")?,
            seed: field("seed")?.as_f64().ok_or("seed must be number")? as u64,
            // absent in pre-weighted model files: default plain TM
            weighted: v.get("weighted").and_then(Json::as_bool).unwrap_or(false),
            // absent in pre-sliced model files: the current default
            // layout (states are serialized in the portable scalar byte
            // form either way, so this only picks the in-memory form)
            ta_layout: match v.get("ta_layout").and_then(Json::as_str) {
                Some(name) => name.parse()?,
                None => TaLayout::default(),
            },
            // absent in pre-SIMD model files: auto dispatch (a pure
            // representation choice, so old models stay bit-identical)
            simd: match v.get("simd").and_then(Json::as_str) {
                Some(name) => name.parse()?,
                None => SimdMode::default(),
            },
        };
        p.validate()?;
        Ok(p)
    }

    /// Check shape/hyperparameter consistency, returning the first problem.
    pub fn validate(&self) -> Result<(), String> {
        if self.classes < 2 {
            return Err(format!("need >= 2 classes, got {}", self.classes));
        }
        if self.clauses_per_class == 0 || self.clauses_per_class % 2 != 0 {
            return Err(format!(
                "clauses_per_class must be positive and even, got {}",
                self.clauses_per_class
            ));
        }
        if self.features == 0 {
            return Err("features must be positive".into());
        }
        if self.threshold == 0 {
            return Err("threshold T must be positive".into());
        }
        // NaN is rejected explicitly: it would silently clamp in
        // FeedbackCtx and emit unparseable params JSON on model save
        if self.s.is_nan() || self.s < 1.0 {
            return Err(format!("s must be >= 1.0, got {}", self.s));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(TMParams::new(10, 100, 784).validate().is_ok());
    }

    #[test]
    fn rejects_odd_clause_count() {
        assert!(TMParams::new(2, 3, 10).validate().is_err());
    }

    #[test]
    fn rejects_single_class() {
        assert!(TMParams::new(1, 4, 10).validate().is_err());
    }

    #[test]
    fn rejects_zero_features_threshold_s() {
        assert!(TMParams::new(2, 4, 0).validate().is_err());
        assert!(TMParams::new(2, 4, 5).with_threshold(0).validate().is_err());
        assert!(TMParams::new(2, 4, 5).with_s(0.5).validate().is_err());
        assert!(TMParams::new(2, 4, 5).with_s(f64::NAN).validate().is_err());
    }

    #[test]
    fn from_total_clauses_splits_evenly() {
        let p = TMParams::from_total_clauses(10, 20_000, 784);
        assert_eq!(p.clauses_per_class, 2000);
        assert_eq!(p.total_clauses(), 20_000);
        assert!(p.validate().is_ok());
        // odd split rounds up to even
        let p = TMParams::from_total_clauses(3, 1000, 10);
        assert_eq!(p.clauses_per_class % 2, 0);
    }

    #[test]
    fn literal_count_is_double_features() {
        assert_eq!(TMParams::new(2, 4, 784).n_literals(), 1568);
    }

    #[test]
    fn json_roundtrip() {
        let p = TMParams::new(10, 100, 784).with_s(7.5).with_threshold(25);
        let s = p.to_json().to_string();
        let q = TMParams::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn ta_layout_json_roundtrip_and_default() {
        let p = TMParams::new(2, 4, 8).with_ta_layout(TaLayout::Scalar);
        let q = TMParams::from_json(&p.to_json()).unwrap();
        assert_eq!(q.ta_layout, TaLayout::Scalar);
        // pre-sliced model files (no field) get the current default
        let mut json = TMParams::new(2, 4, 8).to_json();
        if let Json::Obj(o) = &mut json {
            o.remove("ta_layout");
        }
        let q = TMParams::from_json(&json).unwrap();
        assert_eq!(q.ta_layout, TaLayout::default());
        // a bogus layout name is rejected
        let mut json = TMParams::new(2, 4, 8).to_json();
        if let Json::Obj(o) = &mut json {
            o.insert("ta_layout".to_string(), Json::str("simd"));
        }
        assert!(TMParams::from_json(&json).is_err());
    }

    #[test]
    fn simd_json_roundtrip_and_default() {
        let p = TMParams::new(2, 4, 8).with_simd(SimdMode::Scalar);
        let q = TMParams::from_json(&p.to_json()).unwrap();
        assert_eq!(q.simd, SimdMode::Scalar);
        // pre-SIMD model files (no field) get auto dispatch
        let mut json = TMParams::new(2, 4, 8).to_json();
        if let Json::Obj(o) = &mut json {
            o.remove("simd");
        }
        let q = TMParams::from_json(&json).unwrap();
        assert_eq!(q.simd, SimdMode::Auto);
        // a bogus lane name is rejected
        let mut json = TMParams::new(2, 4, 8).to_json();
        if let Json::Obj(o) = &mut json {
            o.insert("simd".to_string(), Json::str("avx512"));
        }
        assert!(TMParams::from_json(&json).is_err());
    }

    #[test]
    fn from_json_rejects_missing_and_invalid() {
        assert!(TMParams::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut p = TMParams::new(10, 100, 784);
        p.clauses_per_class = 3; // invalid (odd)
        assert!(TMParams::from_json(&p.to_json()).is_err());
    }
}
