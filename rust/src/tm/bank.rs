//! Per-class clause bank: the TA state machine of §2.
//!
//! Each clause `j` owns one Tsetlin Automaton per literal `k`; the TA's
//! integer state decides the literal's inclusion. States are 8-bit
//! (256-state automata, the standard choice): `state >= 0` means
//! *include*. Increment/decrement saturate; crossing the `-1 / 0`
//! boundary is an include/exclude **flip** — the event the paper's index
//! maintains its inclusion lists on.
//!
//! Two storage layouts hold the same automata ([`TaLayout`]):
//!
//! * **scalar** — clause-major `Vec<i8>`, one byte per TA. The portable
//!   reference form (also the serialized form, see [`crate::tm::io`]).
//! * **sliced** — 8 bitplanes per 64-literal word: bit `p` of TA
//!   `(j, k)` lives at lane `k & 63` of plane word `(j, k / 64, p)`.
//!   Saturating ±1 over 64 automata becomes ~8 words of ripple-carry
//!   bitplane arithmetic, and the sign plane (bit 7, set iff the state
//!   is negative) *is* the exclude bitmask — so include masks, flip
//!   extraction, and clause evaluation all read one word per 64 TAs.
//!
//! Both layouts are driven through the same mask-based update entry
//! point ([`ClauseBank::apply_masks`]) and are **bit-identical**: same
//! states, same [`FlipSink`] event stream (`rust/tests/feedback_equiv.rs`
//! proves it differentially). The scalar layout is the escape hatch for
//! debugging and for tooling that wants `row()` access.
//!
//! Polarity is interleaved: even clause ids vote `+1`, odd vote `-1`
//! (equivalent to the paper's half/half split, but keeps the polarity
//! computation a single AND on the hot path).

use crate::eval::traits::FlipSink;
use crate::util::bitvec::{word_mask, words_for};
use crate::util::simd::{self, SimdLanes};

/// Result of a TA state bump: did the literal's inclusion change?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flip {
    /// No inclusion change.
    None,
    /// The literal just became included (exclude -> include).
    Included,
    /// The literal just became excluded (include -> exclude).
    Excluded,
}

/// TA storage layout of a [`ClauseBank`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TaLayout {
    /// Clause-major `Vec<i8>` — the portable reference layout.
    Scalar,
    /// 8 bitplanes per 64-literal word — word-parallel feedback.
    #[default]
    Sliced,
}

impl TaLayout {
    /// Stable lowercase name used by the CLI and model files.
    pub fn name(&self) -> &'static str {
        match self {
            TaLayout::Scalar => "scalar",
            TaLayout::Sliced => "sliced",
        }
    }
}

impl std::str::FromStr for TaLayout {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "scalar" => Ok(TaLayout::Scalar),
            "sliced" => Ok(TaLayout::Sliced),
            other => Err(format!("unknown TA layout '{other}' (scalar|sliced)")),
        }
    }
}

/// Bitplane count: 8-bit two's-complement automata.
const PLANES: usize = 8;
/// The sign plane (bit 7): set iff the state is negative (= excluded).
const SIGN: usize = PLANES - 1;

// the 4-wide group kernel assumes the bank's plane geometry
const _: () = assert!(PLANES == simd::GROUP_PLANES);

/// Bit-sliced TA states: plane word `p` of word `w` of clause `j` at
/// `planes[(j * words + w) * 8 + p]`, so one clause-word's 8 planes are
/// contiguous — the ripple-carry update touches one cache line.
///
/// Tail lanes (`k >= n_literals` in the last word) permanently hold the
/// initial `-1` encoding (all planes set); every mask entering
/// [`ClauseBank::apply_masks`] is ANDed with [`word_mask`], so they
/// never move and never leak into include masks (`!sign & word_mask`).
#[derive(Clone, Debug)]
struct SlicedStates {
    /// Words per clause: `ceil(n_literals / 64)`.
    words: usize,
    planes: Vec<u64>,
}

impl SlicedStates {
    fn new(clauses: usize, n_literals: usize) -> Self {
        let words = words_for(n_literals);
        SlicedStates {
            words,
            // every TA at -1 (byte 0xFF): all planes all-ones
            planes: vec![!0u64; clauses * words * PLANES],
        }
    }

    #[inline]
    fn base(&self, j: usize, w: usize) -> usize {
        (j * self.words + w) * PLANES
    }

    #[inline]
    fn get(&self, j: usize, k: usize) -> i8 {
        let b = self.base(j, k >> 6);
        let lane = k & 63;
        let mut byte = 0u8;
        for p in 0..PLANES {
            byte |= (((self.planes[b + p] >> lane) & 1) as u8) << p;
        }
        byte as i8
    }

    #[inline]
    fn set(&mut self, j: usize, k: usize, v: i8) {
        let b = self.base(j, k >> 6);
        let bit = 1u64 << (k & 63);
        let byte = v as u8;
        for p in 0..PLANES {
            if (byte >> p) & 1 == 1 {
                self.planes[b + p] |= bit;
            } else {
                self.planes[b + p] &= !bit;
            }
        }
    }

    #[inline]
    fn sign_word(&self, j: usize, w: usize) -> u64 {
        self.planes[self.base(j, w) + SIGN]
    }
}

/// The two layouts behind one bank API.
#[derive(Clone, Debug)]
enum TaStates {
    Scalar(Vec<i8>),
    Sliced(SlicedStates),
}

/// TA states and include-counts for one class's `n` clauses over `2o`
/// literals.
#[derive(Clone, Debug)]
pub struct ClauseBank {
    clauses: usize,
    n_literals: usize,
    states: TaStates,
    /// Included-literal count per clause (the paper's clause "size").
    include_count: Vec<u32>,
    /// Integer clause weights (Weighted TM, Phoulady et al. 2020 — the
    /// compression extension the paper cites as [8]). Plain TMs keep
    /// every weight at 1, making weighted voting a strict generalization.
    weights: Vec<u32>,
    /// Lane width of the sliced-layout `apply_masks` path (bit-exact
    /// either way; see [`crate::util::simd`]).
    simd: SimdLanes,
}

impl ClauseBank {
    /// Fresh scalar-layout bank: every TA starts at `-1`, i.e. *exclude*,
    /// one step from the decision boundary — the standard initialization,
    /// and exactly the state the paper's index construction assumes (all
    /// inclusion lists empty).
    pub fn new(clauses: usize, n_literals: usize) -> Self {
        Self::new_with_layout(clauses, n_literals, TaLayout::Scalar)
    }

    /// Fresh bank in an explicit TA storage layout (scalar SIMD lanes;
    /// see [`ClauseBank::new_with_opts`]).
    pub fn new_with_layout(clauses: usize, n_literals: usize, layout: TaLayout) -> Self {
        Self::new_with_opts(clauses, n_literals, layout, SimdLanes::Scalar)
    }

    /// Fresh bank with explicit TA storage layout *and* feedback lane
    /// width. The lane width is a pure dispatch choice — both settings
    /// produce bit-identical states and flip streams.
    pub fn new_with_opts(
        clauses: usize,
        n_literals: usize,
        layout: TaLayout,
        simd: SimdLanes,
    ) -> Self {
        let states = match layout {
            TaLayout::Scalar => TaStates::Scalar(vec![-1; clauses * n_literals]),
            TaLayout::Sliced => TaStates::Sliced(SlicedStates::new(clauses, n_literals)),
        };
        ClauseBank {
            clauses,
            n_literals,
            states,
            include_count: vec![0; clauses],
            weights: vec![1; clauses],
            simd,
        }
    }

    /// This bank's TA storage layout.
    pub fn layout(&self) -> TaLayout {
        match &self.states {
            TaStates::Scalar(_) => TaLayout::Scalar,
            TaStates::Sliced(_) => TaLayout::Sliced,
        }
    }

    /// Lane width used by the sliced-layout [`ClauseBank::apply_masks`].
    #[inline]
    pub fn simd(&self) -> SimdLanes {
        self.simd
    }

    /// Switch the feedback lane width (a dispatch choice, not state —
    /// no TA bits change).
    pub fn set_simd(&mut self, simd: SimdLanes) {
        self.simd = simd;
    }

    /// Copy the bank into another layout (cold path: model conversion,
    /// differential tests). A no-op copy if the layout already matches.
    pub fn convert_layout(&self, layout: TaLayout) -> ClauseBank {
        let mut out = ClauseBank::new_with_opts(self.clauses, self.n_literals, layout, self.simd);
        for j in 0..self.clauses {
            for k in 0..self.n_literals {
                out.set_state(j, k, self.state(j, k));
            }
        }
        out.weights = self.weights.clone();
        debug_assert_eq!(out.include_count, self.include_count);
        out
    }

    /// Clause weight (1 for plain TMs).
    #[inline]
    pub fn weight(&self, j: usize) -> u32 {
        self.weights[j]
    }

    /// Signed weighted vote of clause `j`: `polarity * weight`.
    #[inline]
    pub fn vote(&self, j: usize) -> i32 {
        Self::polarity(j) * self.weights[j] as i32
    }

    /// Increment clause weight (Type Ia in the weighted TM), returning
    /// the new weight.
    #[inline]
    pub fn weight_up(&mut self, j: usize) -> u32 {
        let w = &mut self.weights[j];
        *w = w.saturating_add(1);
        *w
    }

    /// Decrement clause weight toward the floor of 1 (Type II),
    /// returning the new weight.
    #[inline]
    pub fn weight_down(&mut self, j: usize) -> u32 {
        let w = &mut self.weights[j];
        if *w > 1 {
            *w -= 1;
        }
        *w
    }

    /// Force a weight (model loading / tests).
    pub fn set_weight(&mut self, j: usize, w: u32) {
        assert!(w >= 1, "weights have a floor of 1");
        self.weights[j] = w;
    }

    /// Per-clause vote weights (all 1 when weighting is off).
    pub fn weights(&self) -> &[u32] {
        &self.weights
    }

    #[inline]
    /// Number of clauses in the bank.
    pub fn clauses(&self) -> usize {
        self.clauses
    }

    #[inline]
    /// Number of literals (2 × features) per clause.
    pub fn n_literals(&self) -> usize {
        self.n_literals
    }

    /// Vote weight of clause `j`: +1 for even ids, -1 for odd.
    #[inline]
    pub fn polarity(j: usize) -> i32 {
        1 - 2 * ((j & 1) as i32)
    }

    #[inline]
    /// TA state of clause `j`, literal `k` (any layout; slow path).
    pub fn state(&self, j: usize, k: usize) -> i8 {
        match &self.states {
            TaStates::Scalar(v) => v[j * self.n_literals + k],
            TaStates::Sliced(s) => s.get(j, k),
        }
    }

    /// Does clause `j` include literal `k`?
    #[inline]
    pub fn include(&self, j: usize, k: usize) -> bool {
        match &self.states {
            TaStates::Scalar(v) => v[j * self.n_literals + k] >= 0,
            TaStates::Sliced(s) => (s.sign_word(j, k >> 6) >> (k & 63)) & 1 == 0,
        }
    }

    /// Number of included literals of clause `j`.
    #[inline]
    pub fn count(&self, j: usize) -> u32 {
        self.include_count[j]
    }

    /// Raw state row of clause `j` — **scalar layout only** (the layout
    /// that physically stores rows). Sliced callers use
    /// [`ClauseBank::clause_states`] / [`ClauseBank::include_word`].
    #[inline]
    pub fn row(&self, j: usize) -> &[i8] {
        match &self.states {
            TaStates::Scalar(v) => &v[j * self.n_literals..(j + 1) * self.n_literals],
            TaStates::Sliced(_) => panic!("row() requires the scalar TA layout"),
        }
    }

    /// Clause `j`'s states decoded into a fresh `Vec` (layout-agnostic;
    /// diagnostics and tests).
    pub fn clause_states(&self, j: usize) -> Vec<i8> {
        (0..self.n_literals).map(|k| self.state(j, k)).collect()
    }

    /// Include mask of word `w` of clause `j`: bit `b` set iff literal
    /// `64w + b` is included. For the sliced layout this is one negated
    /// sign-plane word — the "sign plane doubles as the evaluation
    /// bitmask" property; the scalar layout gathers it.
    #[inline]
    pub fn include_word(&self, j: usize, w: usize) -> u64 {
        let mask = word_mask(self.n_literals, w);
        match &self.states {
            TaStates::Scalar(v) => {
                let row = &v[j * self.n_literals..(j + 1) * self.n_literals];
                let start = w * 64;
                let end = (start + 64).min(self.n_literals);
                let mut out = 0u64;
                for (b, &s) in row[start..end].iter().enumerate() {
                    out |= ((s >= 0) as u64) << b;
                }
                out
            }
            TaStates::Sliced(s) => !s.sign_word(j, w) & mask,
        }
    }

    /// Fill `out` (>= `ceil(n_literals/64)` words) with the *exclude*
    /// mask of clause `j` — the complement of [`include_word`] over the
    /// valid lanes. Type II feedback builds its bump-up mask from this.
    pub fn fill_exclude_mask(&self, j: usize, out: &mut [u64]) {
        let words = words_for(self.n_literals);
        debug_assert!(out.len() >= words);
        for (w, slot) in out.iter_mut().enumerate().take(words) {
            *slot = !self.include_word(j, w) & word_mask(self.n_literals, w);
        }
    }

    /// Move the TA of (j, k) one step toward *include*. Saturates.
    #[inline]
    pub fn bump_up(&mut self, j: usize, k: usize) -> Flip {
        match &mut self.states {
            TaStates::Scalar(v) => {
                let s = &mut v[j * self.n_literals + k];
                if *s == i8::MAX {
                    return Flip::None;
                }
                *s += 1;
                if *s == 0 {
                    self.include_count[j] += 1;
                    Flip::Included
                } else {
                    Flip::None
                }
            }
            TaStates::Sliced(s) => {
                let cur = s.get(j, k);
                if cur == i8::MAX {
                    return Flip::None;
                }
                s.set(j, k, cur + 1);
                if cur + 1 == 0 {
                    self.include_count[j] += 1;
                    Flip::Included
                } else {
                    Flip::None
                }
            }
        }
    }

    /// Move the TA of (j, k) one step toward *exclude*. Saturates.
    #[inline]
    pub fn bump_down(&mut self, j: usize, k: usize) -> Flip {
        match &mut self.states {
            TaStates::Scalar(v) => {
                let s = &mut v[j * self.n_literals + k];
                if *s == i8::MIN {
                    return Flip::None;
                }
                *s -= 1;
                if *s == -1 {
                    self.include_count[j] -= 1;
                    Flip::Excluded
                } else {
                    Flip::None
                }
            }
            TaStates::Sliced(s) => {
                let cur = s.get(j, k);
                if cur == i8::MIN {
                    return Flip::None;
                }
                s.set(j, k, cur - 1);
                if cur - 1 == -1 {
                    self.include_count[j] -= 1;
                    Flip::Excluded
                } else {
                    Flip::None
                }
            }
        }
    }

    /// Mask-driven saturating update of clause `j`: +1 on every lane of
    /// `up`, −1 on every lane of `down` (the masks must be disjoint;
    /// lanes past `n_literals` are ignored). Include/exclude flips are
    /// recovered from the sign change and forwarded to `sink` in
    /// ascending-`k` order with post-flip counts — the exact event
    /// stream the per-literal [`bump_up`]/[`bump_down`] loop produces,
    /// so the `FlipSink` → O(1) index-maintenance contract is preserved
    /// bit-exactly in both layouts.
    ///
    /// Sliced layout: per 64-literal word, saturation lanes are masked
    /// out (`+127` / `−128` detected from the planes), a ripple-carry
    /// add and a borrow-ripple subtract run over the 8 plane words, and
    /// flips are `sign_before XOR sign_after`. Scalar layout: the same
    /// masks applied lane-at-a-time (still skipping unselected lanes).
    ///
    /// With [`SimdLanes::Wide`] the sliced ripple runs 4 clause-words
    /// at a time ([`simd::saturating_step_group`] — the bank's plane
    /// layout keeps a 4-word group's 32 plane words contiguous), with
    /// per-lane in-order flip extraction; the tail words fall back to
    /// the per-word body. Updates on zero-mask lanes are algebraically
    /// idempotent, so the group path needn't skip them to stay
    /// bit-exact.
    ///
    /// [`bump_up`]: ClauseBank::bump_up
    /// [`bump_down`]: ClauseBank::bump_down
    pub fn apply_masks(&mut self, j: usize, up: &[u64], down: &[u64], sink: &mut dyn FlipSink) {
        let n = self.n_literals;
        let words = words_for(n);
        debug_assert!(up.len() >= words && down.len() >= words);
        let wj = self.weights[j];
        let lanes = self.simd;
        let counts = &mut self.include_count;
        match &mut self.states {
            TaStates::Scalar(v) => {
                let row = &mut v[j * n..(j + 1) * n];
                for w in 0..words {
                    let mask = word_mask(n, w);
                    let u = up[w] & mask;
                    let d = down[w] & mask;
                    debug_assert_eq!(u & d, 0, "up/down masks must be disjoint");
                    let mut bits = u | d;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        let k = w * 64 + b;
                        let s = &mut row[k];
                        if (u >> b) & 1 == 1 {
                            if *s != i8::MAX {
                                *s += 1;
                                if *s == 0 {
                                    counts[j] += 1;
                                    sink.on_include(j as u32, k as u32, counts[j], wj);
                                }
                            }
                        } else if *s != i8::MIN {
                            *s -= 1;
                            if *s == -1 {
                                counts[j] -= 1;
                                sink.on_exclude(j as u32, k as u32, counts[j], wj);
                            }
                        }
                    }
                }
            }
            TaStates::Sliced(sl) => {
                let mut w = 0usize;
                if lanes == SimdLanes::Wide {
                    while w + simd::GROUP_LANES <= words {
                        let u4: [u64; simd::GROUP_LANES] =
                            std::array::from_fn(|i| up[w + i] & word_mask(n, w + i));
                        let d4: [u64; simd::GROUP_LANES] =
                            std::array::from_fn(|i| down[w + i] & word_mask(n, w + i));
                        debug_assert!(
                            (0..simd::GROUP_LANES).all(|i| u4[i] & d4[i] == 0),
                            "up/down masks must be disjoint"
                        );
                        if u4.iter().chain(d4.iter()).all(|&m| m == 0) {
                            w += simd::GROUP_LANES;
                            continue;
                        }
                        let base = sl.base(j, w);
                        let pl = &mut sl.planes[base..base + simd::GROUP_WORDS];
                        let (before, after) = simd::saturating_step_group(pl, &u4, &d4);
                        for i in 0..simd::GROUP_LANES {
                            let mut flipped = before[i] ^ after[i];
                            while flipped != 0 {
                                let b = flipped.trailing_zeros() as usize;
                                flipped &= flipped - 1;
                                let k = (w + i) * 64 + b;
                                if (before[i] >> b) & 1 == 1 {
                                    counts[j] += 1;
                                    sink.on_include(j as u32, k as u32, counts[j], wj);
                                } else {
                                    counts[j] -= 1;
                                    sink.on_exclude(j as u32, k as u32, counts[j], wj);
                                }
                            }
                        }
                        w += simd::GROUP_LANES;
                    }
                }
                while w < words {
                    let mask = word_mask(n, w);
                    let u = up[w] & mask;
                    let d = down[w] & mask;
                    debug_assert_eq!(u & d, 0, "up/down masks must be disjoint");
                    if (u | d) == 0 {
                        w += 1;
                        continue;
                    }
                    let base = sl.base(j, w);
                    let pl = &mut sl.planes[base..base + PLANES];
                    // saturation lanes: +127 = 0b0111_1111, -128 = 0b1000_0000
                    let low_all = pl[0] & pl[1] & pl[2] & pl[3] & pl[4] & pl[5] & pl[6];
                    let low_none = !(pl[0] | pl[1] | pl[2] | pl[3] | pl[4] | pl[5] | pl[6]);
                    let add = u & !(low_all & !pl[SIGN]);
                    let sub = d & !(low_none & pl[SIGN]);
                    let sign_before = pl[SIGN];
                    // ripple-carry +1 on `add` lanes (no overflow: +127 excluded)
                    let mut carry = add;
                    for p in pl.iter_mut() {
                        let orig = *p;
                        *p = orig ^ carry;
                        carry &= orig;
                    }
                    // borrow-ripple −1 on `sub` lanes (no underflow: −128 excluded)
                    let mut borrow = sub;
                    for p in pl.iter_mut() {
                        let orig = *p;
                        *p = orig ^ borrow;
                        borrow &= !orig;
                    }
                    let mut flipped = sign_before ^ pl[SIGN];
                    while flipped != 0 {
                        let b = flipped.trailing_zeros() as usize;
                        flipped &= flipped - 1;
                        let k = w * 64 + b;
                        if (sign_before >> b) & 1 == 1 {
                            counts[j] += 1;
                            sink.on_include(j as u32, k as u32, counts[j], wj);
                        } else {
                            counts[j] -= 1;
                            sink.on_exclude(j as u32, k as u32, counts[j], wj);
                        }
                    }
                    w += 1;
                }
            }
        }
    }

    /// Force a TA state (model loading / tests). Recomputes the count.
    pub fn set_state(&mut self, j: usize, k: usize, v: i8) {
        let was = self.include(j, k);
        match &mut self.states {
            TaStates::Scalar(s) => s[j * self.n_literals + k] = v,
            TaStates::Sliced(s) => s.set(j, k, v),
        }
        let is = v >= 0;
        match (was, is) {
            (false, true) => self.include_count[j] += 1,
            (true, false) => self.include_count[j] -= 1,
            _ => {}
        }
    }

    /// Iterate the included literal ids of clause `j` (ascending), in
    /// either layout.
    pub fn included_literals(&self, j: usize) -> IncludedIter<'_> {
        let words = words_for(self.n_literals);
        IncludedIter {
            bank: self,
            j,
            words,
            w: 0,
            cur: if words == 0 { 0 } else { self.include_word(j, 0) },
        }
    }

    /// Weighted vote sum over non-empty clauses — the indexed
    /// evaluator's inference baseline (recomputed; the index maintains
    /// it incrementally).
    pub fn vote_alive(&self) -> i32 {
        (0..self.clauses)
            .filter(|&j| self.include_count[j] > 0)
            .map(|j| self.vote(j))
            .sum()
    }

    /// Weighted vote sum over all clauses — the training baseline
    /// (empty clauses output 1 during learning).
    pub fn vote_all(&self) -> i32 {
        (0..self.clauses).map(|j| self.vote(j)).sum()
    }

    /// Mean included-literal count over non-empty clauses (paper §3
    /// Remarks reports ~58 for MNIST, ~116 for IMDb).
    pub fn mean_clause_length(&self) -> f64 {
        let non_empty: Vec<u32> = self
            .include_count
            .iter()
            .copied()
            .filter(|&c| c > 0)
            .collect();
        if non_empty.is_empty() {
            return 0.0;
        }
        non_empty.iter().map(|&c| c as f64).sum::<f64>() / non_empty.len() as f64
    }

    /// All TA states decoded clause-major (serialization, tests). This
    /// is the portable scalar byte form regardless of layout.
    pub fn states(&self) -> Vec<i8> {
        match &self.states {
            TaStates::Scalar(v) => v.clone(),
            TaStates::Sliced(_) => {
                let mut out = Vec::with_capacity(self.clauses * self.n_literals);
                for j in 0..self.clauses {
                    for k in 0..self.n_literals {
                        out.push(self.state(j, k));
                    }
                }
                out
            }
        }
    }

    /// Extract clauses `[start, start + len)` into a fresh bank with
    /// local ids `0..len` — the clause-shard extraction of
    /// [`crate::parallel`]. `start` must be even so local polarity
    /// matches global polarity (ids interleave +/−). The shard inherits
    /// this bank's layout (sliced shards slice whole bitplane ranges —
    /// clause-major plane storage makes the range copy contiguous).
    pub fn clone_range(&self, start: usize, len: usize) -> ClauseBank {
        assert!(start % 2 == 0, "shard start {start} must be even (polarity)");
        assert!(start + len <= self.clauses, "shard out of range");
        let states = match &self.states {
            TaStates::Scalar(v) => TaStates::Scalar(
                v[start * self.n_literals..(start + len) * self.n_literals].to_vec(),
            ),
            TaStates::Sliced(s) => {
                let per = s.words * PLANES;
                TaStates::Sliced(SlicedStates {
                    words: s.words,
                    planes: s.planes[start * per..(start + len) * per].to_vec(),
                })
            }
        };
        ClauseBank {
            clauses: len,
            n_literals: self.n_literals,
            states,
            include_count: self.include_count[start..start + len].to_vec(),
            weights: self.weights[start..start + len].to_vec(),
            simd: self.simd,
        }
    }

    /// Write a shard bank (from [`ClauseBank::clone_range`]) back over
    /// clauses `[start, start + shard.clauses())` — the reassembly step
    /// after a parallel epoch. The layouts must match (shards inherit
    /// the global bank's layout, so they always do).
    pub fn write_range(&mut self, start: usize, shard: &ClauseBank) {
        assert_eq!(shard.n_literals, self.n_literals, "literal width mismatch");
        assert!(start % 2 == 0, "shard start {start} must be even (polarity)");
        assert!(start + shard.clauses <= self.clauses, "shard out of range");
        match (&mut self.states, &shard.states) {
            (TaStates::Scalar(dst), TaStates::Scalar(src)) => {
                let a = start * self.n_literals;
                dst[a..a + shard.clauses * self.n_literals].copy_from_slice(src);
            }
            (TaStates::Sliced(dst), TaStates::Sliced(src)) => {
                debug_assert_eq!(dst.words, src.words);
                let per = dst.words * PLANES;
                dst.planes[start * per..(start + shard.clauses) * per]
                    .copy_from_slice(&src.planes);
            }
            _ => panic!("write_range: TA layout mismatch"),
        }
        self.include_count[start..start + shard.clauses]
            .copy_from_slice(&shard.include_count);
        self.weights[start..start + shard.clauses].copy_from_slice(&shard.weights);
    }

    /// Verify `include_count` against the states (test/debug invariant).
    #[doc(hidden)]
    pub fn check_counts(&self) -> bool {
        let words = words_for(self.n_literals);
        (0..self.clauses).all(|j| {
            let c: u32 = (0..words).map(|w| self.include_word(j, w).count_ones()).sum();
            self.include_count[j] == c
        })
    }
}

/// Iterator over the included literal ids of one clause, walking
/// [`ClauseBank::include_word`] words (one negated sign-plane word per
/// 64 literals in the sliced layout).
pub struct IncludedIter<'a> {
    bank: &'a ClauseBank,
    j: usize,
    words: usize,
    w: usize,
    cur: u64,
}

impl Iterator for IncludedIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.cur != 0 {
                let b = self.cur.trailing_zeros() as usize;
                self.cur &= self.cur - 1;
                return Some(self.w * 64 + b);
            }
            self.w += 1;
            if self.w >= self.words {
                return None;
            }
            self.cur = self.bank.include_word(self.j, self.w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::NoopSink;
    use crate::util::Rng;

    const LAYOUTS: [TaLayout; 2] = [TaLayout::Scalar, TaLayout::Sliced];

    #[test]
    fn layout_parses_and_names() {
        assert_eq!("scalar".parse::<TaLayout>().unwrap(), TaLayout::Scalar);
        assert_eq!("sliced".parse::<TaLayout>().unwrap(), TaLayout::Sliced);
        assert!("simd".parse::<TaLayout>().is_err());
        assert_eq!(TaLayout::Sliced.name(), "sliced");
        assert_eq!(TaLayout::default(), TaLayout::Sliced);
    }

    #[test]
    fn fresh_bank_is_all_exclude() {
        for layout in LAYOUTS {
            let b = ClauseBank::new_with_layout(4, 10, layout);
            assert_eq!(b.layout(), layout);
            for j in 0..4 {
                assert_eq!(b.count(j), 0);
                for k in 0..10 {
                    assert!(!b.include(j, k));
                    assert_eq!(b.state(j, k), -1);
                }
            }
            assert_eq!(b.vote_alive(), 0);
            assert_eq!(b.vote_all(), 0); // interleaved polarity sums to 0
        }
    }

    #[test]
    fn polarity_interleaves() {
        assert_eq!(ClauseBank::polarity(0), 1);
        assert_eq!(ClauseBank::polarity(1), -1);
        assert_eq!(ClauseBank::polarity(2), 1);
    }

    #[test]
    fn bump_up_flips_exactly_at_boundary() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(2, 4, layout);
            assert_eq!(b.bump_up(0, 1), Flip::Included);
            assert_eq!(b.count(0), 1);
            assert!(b.include(0, 1));
            // further bumps: no flip
            assert_eq!(b.bump_up(0, 1), Flip::None);
            assert_eq!(b.count(0), 1);
        }
    }

    #[test]
    fn bump_down_flips_exactly_at_boundary() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(2, 4, layout);
            b.bump_up(0, 1); // -> 0, included
            b.bump_up(0, 1); // -> 1
            assert_eq!(b.bump_down(0, 1), Flip::None); // 1 -> 0, still included
            assert_eq!(b.bump_down(0, 1), Flip::Excluded); // 0 -> -1
            assert_eq!(b.count(0), 0);
            assert!(!b.include(0, 1));
        }
    }

    #[test]
    fn saturation_at_extremes() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(1, 1, layout);
            for _ in 0..300 {
                b.bump_up(0, 0);
            }
            assert_eq!(b.state(0, 0), i8::MAX);
            assert_eq!(b.bump_up(0, 0), Flip::None);
            for _ in 0..300 {
                b.bump_down(0, 0);
            }
            assert_eq!(b.state(0, 0), i8::MIN);
            assert_eq!(b.bump_down(0, 0), Flip::None);
            assert!(b.check_counts());
        }
    }

    #[test]
    fn set_state_maintains_counts() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(2, 4, layout);
            b.set_state(0, 2, 5);
            assert_eq!(b.count(0), 1);
            b.set_state(0, 2, -3);
            assert_eq!(b.count(0), 0);
            b.set_state(0, 2, -3); // no-op transition
            assert_eq!(b.count(0), 0);
            assert!(b.check_counts());
        }
    }

    #[test]
    fn included_literals_iterates_correctly() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(1, 6, layout);
            b.set_state(0, 1, 0);
            b.set_state(0, 4, 3);
            let got: Vec<usize> = b.included_literals(0).collect();
            assert_eq!(got, vec![1, 4]);
        }
    }

    #[test]
    fn included_literals_cross_word_boundaries() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(1, 130, layout);
            for &k in &[0usize, 63, 64, 65, 127, 128, 129] {
                b.set_state(0, k, 1);
            }
            let got: Vec<usize> = b.included_literals(0).collect();
            assert_eq!(got, vec![0, 63, 64, 65, 127, 128, 129]);
            assert!(b.check_counts());
        }
    }

    #[test]
    fn vote_alive_counts_only_nonempty() {
        let mut b = ClauseBank::new(4, 4);
        b.bump_up(0, 0); // clause 0 (+1) non-empty
        b.bump_up(3, 2); // clause 3 (-1) non-empty
        b.bump_up(3, 3);
        assert_eq!(b.vote_alive(), 0); // +1 - 1
        b.bump_up(2, 0); // clause 2 (+1)
        assert_eq!(b.vote_alive(), 1);
    }

    #[test]
    fn clone_range_roundtrips_through_write_range() {
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(6, 4, layout);
            for j in 0..6 {
                for k in 0..4 {
                    b.set_state(j, k, (j * 4 + k) as i8 - 8);
                }
            }
            b.set_weight(2, 7);
            let shard = b.clone_range(2, 2);
            assert_eq!(shard.clauses(), 2);
            assert_eq!(shard.layout(), layout);
            assert_eq!(shard.state(0, 0), b.state(2, 0));
            assert_eq!(shard.weight(0), 7);
            assert_eq!(shard.count(0), b.count(2));
            assert!(shard.check_counts());
            // polarity alignment: local 0 == global 2 (+), local 1 == global 3 (−)
            assert_eq!(ClauseBank::polarity(0), ClauseBank::polarity(2));

            // mutate the shard, write back, only that range changes
            let mut shard = shard;
            shard.set_state(0, 1, 5);
            shard.set_weight(1, 3);
            let before_outside = b.clause_states(0);
            b.write_range(2, &shard);
            assert_eq!(b.state(2, 1), 5);
            assert_eq!(b.weight(3), 3);
            assert_eq!(b.clause_states(0), before_outside);
            assert!(b.check_counts());
        }
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn clone_range_rejects_odd_start() {
        ClauseBank::new(4, 2).clone_range(1, 2);
    }

    #[test]
    #[should_panic(expected = "layout mismatch")]
    fn write_range_rejects_layout_mismatch() {
        let mut a = ClauseBank::new_with_layout(4, 4, TaLayout::Scalar);
        let b = ClauseBank::new_with_layout(2, 4, TaLayout::Sliced);
        a.write_range(0, &b);
    }

    #[test]
    fn mean_clause_length_ignores_empty() {
        let mut b = ClauseBank::new(3, 8);
        for k in 0..4 {
            b.bump_up(0, k);
        }
        for k in 0..2 {
            b.bump_up(1, k);
        }
        // clause 2 empty
        assert!((b.mean_clause_length() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn convert_layout_roundtrips() {
        let mut rng = Rng::new(91);
        let mut b = ClauseBank::new(6, 70); // tail word exercised
        for j in 0..6 {
            for k in 0..70 {
                if rng.bern(0.4) {
                    b.set_state(j, k, (rng.below(255) as i16 - 128) as i8);
                }
            }
        }
        b.set_weight(3, 9);
        let sliced = b.convert_layout(TaLayout::Sliced);
        assert_eq!(sliced.layout(), TaLayout::Sliced);
        assert_eq!(sliced.states(), b.states());
        assert_eq!(sliced.weights(), b.weights());
        assert!(sliced.check_counts());
        let back = sliced.convert_layout(TaLayout::Scalar);
        assert_eq!(back.states(), b.states());
        assert_eq!(back.row(2), &b.states()[2 * 70..3 * 70]);
    }

    #[test]
    fn include_word_matches_per_literal_reads() {
        let mut rng = Rng::new(93);
        for layout in LAYOUTS {
            let mut b = ClauseBank::new_with_layout(3, 130, layout);
            for j in 0..3 {
                for k in 0..130 {
                    if rng.bern(0.3) {
                        b.set_state(j, k, (rng.below(11) as i8) - 5);
                    }
                }
            }
            for j in 0..3 {
                for w in 0..3 {
                    let word = b.include_word(j, w);
                    for bit in 0..64usize {
                        let k = w * 64 + bit;
                        let want = k < 130 && b.include(j, k);
                        assert_eq!((word >> bit) & 1 == 1, want, "j={j} k={k}");
                    }
                }
                let mut excl = vec![0u64; 3];
                b.fill_exclude_mask(j, &mut excl);
                for (w, &e) in excl.iter().enumerate() {
                    assert_eq!(e & b.include_word(j, w), 0);
                    assert_eq!(e | b.include_word(j, w), word_mask(130, w));
                }
            }
        }
    }

    /// Wide-lane equivalence at the bank level: the 4-word group path
    /// must leave identical states, counts, and flip decisions as the
    /// per-word sliced path and the scalar layout (sink-stream
    /// equivalence lives in `rust/tests/simd_equiv.rs`).
    #[test]
    fn apply_masks_wide_lanes_match_scalar_lanes() {
        let mut rng = Rng::new(97);
        // word counts straddling the group width: 1..=5 words incl. tails
        for n_lit in [40usize, 64, 130, 256, 300] {
            let words = words_for(n_lit);
            let mut narrow =
                ClauseBank::new_with_opts(4, n_lit, TaLayout::Sliced, SimdLanes::Scalar);
            let mut wide = ClauseBank::new_with_opts(4, n_lit, TaLayout::Sliced, SimdLanes::Wide);
            let mut scalar =
                ClauseBank::new_with_opts(4, n_lit, TaLayout::Scalar, SimdLanes::Wide);
            for j in 0..4 {
                for k in 0..n_lit {
                    let v = match rng.below(10) {
                        0 => i8::MAX,
                        1 => i8::MIN,
                        _ => (rng.below(9) as i8) - 4,
                    };
                    narrow.set_state(j, k, v);
                    wide.set_state(j, k, v);
                    scalar.set_state(j, k, v);
                }
            }
            for step in 0..300 {
                let j = rng.below(4) as usize;
                let mut up = vec![0u64; words];
                let mut down = vec![0u64; words];
                for w in 0..words {
                    let a = rng.next_u64() & word_mask(n_lit, w);
                    let b = rng.next_u64() & word_mask(n_lit, w);
                    up[w] = a & !b;
                    down[w] = b & !a;
                }
                narrow.apply_masks(j, &up, &down, &mut NoopSink);
                wide.apply_masks(j, &up, &down, &mut NoopSink);
                scalar.apply_masks(j, &up, &down, &mut NoopSink);
                assert_eq!(
                    narrow.clause_states(j),
                    wide.clause_states(j),
                    "n_lit={n_lit} step={step}"
                );
                assert_eq!(wide.clause_states(j), scalar.clause_states(j));
                assert_eq!(narrow.count(j), wide.count(j));
            }
            assert!(narrow.check_counts() && wide.check_counts());
            assert_eq!(narrow.states(), wide.states());
            assert_eq!(wide.states(), scalar.states());
        }
    }

    /// The core layout-equivalence property at the bank level: random
    /// mask storms applied to both layouts leave identical states,
    /// counts, and flip decisions (the full sink-stream equivalence
    /// lives in `rust/tests/feedback_equiv.rs`).
    #[test]
    fn apply_masks_is_layout_invariant_under_random_storms() {
        let mut rng = Rng::new(95);
        for n_lit in [6usize, 64, 70, 200] {
            let words = words_for(n_lit);
            let mut scalar = ClauseBank::new_with_layout(4, n_lit, TaLayout::Scalar);
            let mut sliced = ClauseBank::new_with_layout(4, n_lit, TaLayout::Sliced);
            // mid-training states, including saturation extremes
            for j in 0..4 {
                for k in 0..n_lit {
                    let v = match rng.below(10) {
                        0 => i8::MAX,
                        1 => i8::MIN,
                        _ => (rng.below(9) as i8) - 4,
                    };
                    scalar.set_state(j, k, v);
                    sliced.set_state(j, k, v);
                }
            }
            for step in 0..300 {
                let j = rng.below(4) as usize;
                let mut up = vec![0u64; words];
                let mut down = vec![0u64; words];
                for w in 0..words {
                    let a = rng.next_u64() & word_mask(n_lit, w);
                    let b = rng.next_u64() & word_mask(n_lit, w);
                    up[w] = a & !b;
                    down[w] = b & !a;
                }
                scalar.apply_masks(j, &up, &down, &mut NoopSink);
                sliced.apply_masks(j, &up, &down, &mut NoopSink);
                assert_eq!(
                    scalar.clause_states(j),
                    sliced.clause_states(j),
                    "n_lit={n_lit} step={step}"
                );
                assert_eq!(scalar.count(j), sliced.count(j));
            }
            assert!(scalar.check_counts() && sliced.check_counts());
            assert_eq!(scalar.states(), sliced.states());
        }
    }
}
