//! Per-class clause bank: the TA state machine of §2.
//!
//! Each clause `j` owns one Tsetlin Automaton per literal `k`; the TA's
//! integer state decides the literal's inclusion. States are stored as
//! `i8` (256-state automata, the standard choice): `state >= 0` means
//! *include*. Increment/decrement saturate; crossing the `-1 / 0`
//! boundary is an include/exclude **flip** — the event the paper's index
//! maintains its inclusion lists on.
//!
//! Polarity is interleaved: even clause ids vote `+1`, odd vote `-1`
//! (equivalent to the paper's half/half split, but keeps the polarity
//! computation a single AND on the hot path).

/// Result of a TA state bump: did the literal's inclusion change?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flip {
    /// No inclusion change.
    None,
    /// The literal just became included (exclude -> include).
    Included,
    /// The literal just became excluded (include -> exclude).
    Excluded,
}

/// TA states and include-counts for one class's `n` clauses over `2o`
/// literals.
#[derive(Clone, Debug)]
pub struct ClauseBank {
    clauses: usize,
    n_literals: usize,
    /// Clause-major TA states: `states[j * 2o + k]`; include iff `>= 0`.
    states: Vec<i8>,
    /// Included-literal count per clause (the paper's clause "size").
    include_count: Vec<u32>,
    /// Integer clause weights (Weighted TM, Phoulady et al. 2020 — the
    /// compression extension the paper cites as [8]). Plain TMs keep
    /// every weight at 1, making weighted voting a strict generalization.
    weights: Vec<u32>,
}

impl ClauseBank {
    /// Fresh bank: every TA starts at `-1`, i.e. *exclude*, one step from
    /// the decision boundary — the standard initialization, and exactly
    /// the state the paper's index construction assumes (all inclusion
    /// lists empty).
    pub fn new(clauses: usize, n_literals: usize) -> Self {
        ClauseBank {
            clauses,
            n_literals,
            states: vec![-1; clauses * n_literals],
            include_count: vec![0; clauses],
            weights: vec![1; clauses],
        }
    }

    /// Clause weight (1 for plain TMs).
    #[inline]
    pub fn weight(&self, j: usize) -> u32 {
        self.weights[j]
    }

    /// Signed weighted vote of clause `j`: `polarity * weight`.
    #[inline]
    pub fn vote(&self, j: usize) -> i32 {
        Self::polarity(j) * self.weights[j] as i32
    }

    /// Increment clause weight (Type Ia in the weighted TM), returning
    /// the new weight.
    #[inline]
    pub fn weight_up(&mut self, j: usize) -> u32 {
        let w = &mut self.weights[j];
        *w = w.saturating_add(1);
        *w
    }

    /// Decrement clause weight toward the floor of 1 (Type II),
    /// returning the new weight.
    #[inline]
    pub fn weight_down(&mut self, j: usize) -> u32 {
        let w = &mut self.weights[j];
        if *w > 1 {
            *w -= 1;
        }
        *w
    }

    /// Force a weight (model loading / tests).
    pub fn set_weight(&mut self, j: usize, w: u32) {
        assert!(w >= 1, "weights have a floor of 1");
        self.weights[j] = w;
    }

    pub fn weights(&self) -> &[u32] {
        &self.weights
    }

    #[inline]
    pub fn clauses(&self) -> usize {
        self.clauses
    }

    #[inline]
    pub fn n_literals(&self) -> usize {
        self.n_literals
    }

    /// Vote weight of clause `j`: +1 for even ids, -1 for odd.
    #[inline]
    pub fn polarity(j: usize) -> i32 {
        1 - 2 * ((j & 1) as i32)
    }

    #[inline]
    pub fn state(&self, j: usize, k: usize) -> i8 {
        self.states[j * self.n_literals + k]
    }

    /// Does clause `j` include literal `k`?
    #[inline]
    pub fn include(&self, j: usize, k: usize) -> bool {
        self.states[j * self.n_literals + k] >= 0
    }

    /// Number of included literals of clause `j`.
    #[inline]
    pub fn count(&self, j: usize) -> u32 {
        self.include_count[j]
    }

    /// Raw state row of clause `j` (the naive evaluator scans this).
    #[inline]
    pub fn row(&self, j: usize) -> &[i8] {
        &self.states[j * self.n_literals..(j + 1) * self.n_literals]
    }

    /// Move the TA of (j, k) one step toward *include*. Saturates.
    #[inline]
    pub fn bump_up(&mut self, j: usize, k: usize) -> Flip {
        let s = &mut self.states[j * self.n_literals + k];
        if *s == i8::MAX {
            return Flip::None;
        }
        *s += 1;
        if *s == 0 {
            self.include_count[j] += 1;
            Flip::Included
        } else {
            Flip::None
        }
    }

    /// Move the TA of (j, k) one step toward *exclude*. Saturates.
    #[inline]
    pub fn bump_down(&mut self, j: usize, k: usize) -> Flip {
        let s = &mut self.states[j * self.n_literals + k];
        if *s == i8::MIN {
            return Flip::None;
        }
        *s -= 1;
        if *s == -1 {
            self.include_count[j] -= 1;
            Flip::Excluded
        } else {
            Flip::None
        }
    }

    /// Force a TA state (model loading / tests). Recomputes the count.
    pub fn set_state(&mut self, j: usize, k: usize, v: i8) {
        let idx = j * self.n_literals + k;
        let was = self.states[idx] >= 0;
        self.states[idx] = v;
        let is = v >= 0;
        match (was, is) {
            (false, true) => self.include_count[j] += 1,
            (true, false) => self.include_count[j] -= 1,
            _ => {}
        }
    }

    /// Iterate the included literal ids of clause `j`.
    pub fn included_literals(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        self.row(j)
            .iter()
            .enumerate()
            .filter(|(_, &s)| s >= 0)
            .map(|(k, _)| k)
    }

    /// Weighted vote sum over non-empty clauses — the indexed
    /// evaluator's inference baseline (recomputed; the index maintains
    /// it incrementally).
    pub fn vote_alive(&self) -> i32 {
        (0..self.clauses)
            .filter(|&j| self.include_count[j] > 0)
            .map(|j| self.vote(j))
            .sum()
    }

    /// Weighted vote sum over all clauses — the training baseline
    /// (empty clauses output 1 during learning).
    pub fn vote_all(&self) -> i32 {
        (0..self.clauses).map(|j| self.vote(j)).sum()
    }

    /// Mean included-literal count over non-empty clauses (paper §3
    /// Remarks reports ~58 for MNIST, ~116 for IMDb).
    pub fn mean_clause_length(&self) -> f64 {
        let non_empty: Vec<u32> = self
            .include_count
            .iter()
            .copied()
            .filter(|&c| c > 0)
            .collect();
        if non_empty.is_empty() {
            return 0.0;
        }
        non_empty.iter().map(|&c| c as f64).sum::<f64>() / non_empty.len() as f64
    }

    /// Access raw states (serialization).
    pub fn states(&self) -> &[i8] {
        &self.states
    }

    /// Extract clauses `[start, start + len)` into a fresh bank with
    /// local ids `0..len` — the clause-shard extraction of
    /// [`crate::parallel`]. `start` must be even so local polarity
    /// matches global polarity (ids interleave +/−).
    pub fn clone_range(&self, start: usize, len: usize) -> ClauseBank {
        assert!(start % 2 == 0, "shard start {start} must be even (polarity)");
        assert!(start + len <= self.clauses, "shard out of range");
        ClauseBank {
            clauses: len,
            n_literals: self.n_literals,
            states: self.states[start * self.n_literals..(start + len) * self.n_literals]
                .to_vec(),
            include_count: self.include_count[start..start + len].to_vec(),
            weights: self.weights[start..start + len].to_vec(),
        }
    }

    /// Write a shard bank (from [`ClauseBank::clone_range`]) back over
    /// clauses `[start, start + shard.clauses())` — the reassembly step
    /// after a parallel epoch.
    pub fn write_range(&mut self, start: usize, shard: &ClauseBank) {
        assert_eq!(shard.n_literals, self.n_literals, "literal width mismatch");
        assert!(start % 2 == 0, "shard start {start} must be even (polarity)");
        assert!(start + shard.clauses <= self.clauses, "shard out of range");
        let a = start * self.n_literals;
        let b = a + shard.clauses * self.n_literals;
        self.states[a..b].copy_from_slice(&shard.states);
        self.include_count[start..start + shard.clauses]
            .copy_from_slice(&shard.include_count);
        self.weights[start..start + shard.clauses].copy_from_slice(&shard.weights);
    }

    /// Verify `include_count` against the states (test/debug invariant).
    #[doc(hidden)]
    pub fn check_counts(&self) -> bool {
        (0..self.clauses).all(|j| {
            self.include_count[j] as usize == self.row(j).iter().filter(|&&s| s >= 0).count()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_bank_is_all_exclude() {
        let b = ClauseBank::new(4, 10);
        for j in 0..4 {
            assert_eq!(b.count(j), 0);
            for k in 0..10 {
                assert!(!b.include(j, k));
                assert_eq!(b.state(j, k), -1);
            }
        }
        assert_eq!(b.vote_alive(), 0);
        assert_eq!(b.vote_all(), 0); // interleaved polarity sums to 0
    }

    #[test]
    fn polarity_interleaves() {
        assert_eq!(ClauseBank::polarity(0), 1);
        assert_eq!(ClauseBank::polarity(1), -1);
        assert_eq!(ClauseBank::polarity(2), 1);
    }

    #[test]
    fn bump_up_flips_exactly_at_boundary() {
        let mut b = ClauseBank::new(2, 4);
        assert_eq!(b.bump_up(0, 1), Flip::Included);
        assert_eq!(b.count(0), 1);
        assert!(b.include(0, 1));
        // further bumps: no flip
        assert_eq!(b.bump_up(0, 1), Flip::None);
        assert_eq!(b.count(0), 1);
    }

    #[test]
    fn bump_down_flips_exactly_at_boundary() {
        let mut b = ClauseBank::new(2, 4);
        b.bump_up(0, 1); // -> 0, included
        b.bump_up(0, 1); // -> 1
        assert_eq!(b.bump_down(0, 1), Flip::None); // 1 -> 0, still included
        assert_eq!(b.bump_down(0, 1), Flip::Excluded); // 0 -> -1
        assert_eq!(b.count(0), 0);
        assert!(!b.include(0, 1));
    }

    #[test]
    fn saturation_at_extremes() {
        let mut b = ClauseBank::new(1, 1);
        for _ in 0..300 {
            b.bump_up(0, 0);
        }
        assert_eq!(b.state(0, 0), i8::MAX);
        assert_eq!(b.bump_up(0, 0), Flip::None);
        for _ in 0..300 {
            b.bump_down(0, 0);
        }
        assert_eq!(b.state(0, 0), i8::MIN);
        assert_eq!(b.bump_down(0, 0), Flip::None);
        assert!(b.check_counts());
    }

    #[test]
    fn set_state_maintains_counts() {
        let mut b = ClauseBank::new(2, 4);
        b.set_state(0, 2, 5);
        assert_eq!(b.count(0), 1);
        b.set_state(0, 2, -3);
        assert_eq!(b.count(0), 0);
        b.set_state(0, 2, -3); // no-op transition
        assert_eq!(b.count(0), 0);
        assert!(b.check_counts());
    }

    #[test]
    fn included_literals_iterates_correctly() {
        let mut b = ClauseBank::new(1, 6);
        b.set_state(0, 1, 0);
        b.set_state(0, 4, 3);
        let got: Vec<usize> = b.included_literals(0).collect();
        assert_eq!(got, vec![1, 4]);
    }

    #[test]
    fn vote_alive_counts_only_nonempty() {
        let mut b = ClauseBank::new(4, 4);
        b.bump_up(0, 0); // clause 0 (+1) non-empty
        b.bump_up(3, 2); // clause 3 (-1) non-empty
        b.bump_up(3, 3);
        assert_eq!(b.vote_alive(), 0); // +1 - 1
        b.bump_up(2, 0); // clause 2 (+1)
        assert_eq!(b.vote_alive(), 1);
    }

    #[test]
    fn clone_range_roundtrips_through_write_range() {
        let mut b = ClauseBank::new(6, 4);
        for j in 0..6 {
            for k in 0..4 {
                b.set_state(j, k, (j * 4 + k) as i8 - 8);
            }
        }
        b.set_weight(2, 7);
        let shard = b.clone_range(2, 2);
        assert_eq!(shard.clauses(), 2);
        assert_eq!(shard.state(0, 0), b.state(2, 0));
        assert_eq!(shard.weight(0), 7);
        assert_eq!(shard.count(0), b.count(2));
        assert!(shard.check_counts());
        // polarity alignment: local 0 == global 2 (+), local 1 == global 3 (−)
        assert_eq!(ClauseBank::polarity(0), ClauseBank::polarity(2));

        // mutate the shard, write back, only that range changes
        let mut shard = shard;
        shard.set_state(0, 1, 5);
        shard.set_weight(1, 3);
        let before_outside = b.row(0).to_vec();
        b.write_range(2, &shard);
        assert_eq!(b.state(2, 1), 5);
        assert_eq!(b.weight(3), 3);
        assert_eq!(b.row(0), &before_outside[..]);
        assert!(b.check_counts());
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn clone_range_rejects_odd_start() {
        ClauseBank::new(4, 2).clone_range(1, 2);
    }

    #[test]
    fn mean_clause_length_ignores_empty() {
        let mut b = ClauseBank::new(3, 8);
        for k in 0..4 {
            b.bump_up(0, k);
        }
        for k in 0..2 {
            b.bump_up(1, k);
        }
        // clause 2 empty
        assert!((b.mean_clause_length() - 3.0).abs() < 1e-12);
    }
}
