//! Clause introspection — the TM's interpretability story (§1: clauses
//! have "an interpretable form (e.g., if X satisfies condition A and
//! not condition B then Y = 1)").

use crate::tm::bank::ClauseBank;
use crate::tm::classifier::MultiClassTM;

/// Render clause `j` as a conjunction over named features.
/// Literals `k < o` print as the feature, `k >= o` as its negation.
pub fn clause_string(bank: &ClauseBank, j: usize, names: Option<&[String]>) -> String {
    let o = bank.n_literals() / 2;
    let name = |f: usize| -> String {
        match names {
            Some(ns) => ns[f].clone(),
            None => format!("x{f}"),
        }
    };
    let mut parts: Vec<String> = Vec::new();
    for k in bank.included_literals(j) {
        if k < o {
            parts.push(name(k));
        } else {
            parts.push(format!("¬{}", name(k - o)));
        }
    }
    if parts.is_empty() {
        return "⊤ (empty)".to_string();
    }
    parts.join(" ∧ ")
}

/// One formatted line per clause: id, polarity, weight, body.
pub fn describe_clause(bank: &ClauseBank, j: usize, names: Option<&[String]>) -> String {
    format!(
        "C{}{} (w={}): {}",
        j / 2 + 1,
        if ClauseBank::polarity(j) > 0 { "+" } else { "-" },
        bank.weight(j),
        clause_string(bank, j, names)
    )
}

/// The `top_n` strongest clauses of a class, by weight then by length
/// (longer = more specific); skips empty clauses.
pub fn top_clauses(
    tm: &MultiClassTM,
    class: usize,
    top_n: usize,
    names: Option<&[String]>,
) -> Vec<String> {
    let bank = tm.bank(class);
    let mut ids: Vec<usize> = (0..bank.clauses()).filter(|&j| bank.count(j) > 0).collect();
    ids.sort_by_key(|&j| std::cmp::Reverse((bank.weight(j), bank.count(j))));
    ids.truncate(top_n);
    ids.iter().map(|&j| describe_clause(bank, j, names)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::params::TMParams;

    fn bank_with(incl: &[(usize, usize)]) -> ClauseBank {
        let mut b = ClauseBank::new(4, 8); // o = 4
        for &(j, k) in incl {
            b.set_state(j, k, 0);
        }
        b
    }

    #[test]
    fn renders_positive_and_negated_literals() {
        let b = bank_with(&[(0, 1), (0, 6)]);
        assert_eq!(clause_string(&b, 0, None), "x1 ∧ ¬x2");
    }

    #[test]
    fn renders_named_features() {
        let b = bank_with(&[(1, 0), (1, 4)]);
        let names: Vec<String> = ["good", "bad", "plot", "acting"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(clause_string(&b, 1, Some(&names)), "good ∧ ¬good");
    }

    #[test]
    fn empty_clause_renders_top() {
        let b = bank_with(&[]);
        assert_eq!(clause_string(&b, 0, None), "⊤ (empty)");
    }

    #[test]
    fn describe_includes_polarity_and_weight() {
        let mut b = bank_with(&[(0, 0), (1, 2)]);
        b.set_weight(1, 5);
        assert_eq!(describe_clause(&b, 0, None), "C1+ (w=1): x0");
        assert_eq!(describe_clause(&b, 1, None), "C1- (w=5): x2");
    }

    #[test]
    fn boundary_literals_render_correctly() {
        // first/last positive and first/last negated literal of o = 4
        let b = bank_with(&[(0, 0), (0, 3), (0, 4), (0, 7)]);
        assert_eq!(clause_string(&b, 0, None), "x0 ∧ x3 ∧ ¬x0 ∧ ¬x3");
    }

    #[test]
    fn top_clauses_skips_empty_and_truncates() {
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 4));
        let bank = tm.bank_mut(1);
        bank.set_state(0, 1, 0); // only clause 0 is non-empty
        let top = top_clauses(&tm, 1, 10, None);
        assert_eq!(top.len(), 1, "{top:?}");
        assert!(top[0].contains("x1"), "{top:?}");
        // a machine with no inclusions yields no clauses at all
        assert!(top_clauses(&tm, 0, 10, None).is_empty());
    }

    #[test]
    fn describe_interprets_trained_weighted_machine() {
        // interpretability over a *weighted* bank: the weight shows up
        // and every line renders without panicking
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 4).with_weighted(true));
        let bank = tm.bank_mut(0);
        bank.set_state(0, 0, 1);
        bank.set_state(0, 5, 2);
        bank.set_weight(0, 9);
        bank.set_state(3, 2, 0);
        for j in 0..4 {
            let _ = describe_clause(tm.bank(0), j, None);
        }
        let top = top_clauses(&tm, 0, 4, None);
        assert!(top[0].starts_with("C1+ (w=9)"), "{top:?}");
        assert!(top[0].contains("x0 ∧ ¬x1"), "{top:?}");
    }

    #[test]
    fn top_clauses_orders_by_weight_then_length() {
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 4));
        let bank = tm.bank_mut(0);
        bank.set_state(0, 0, 0); // len 1, w 1
        bank.set_state(1, 0, 0);
        bank.set_state(1, 1, 0); // len 2, w 1
        bank.set_state(2, 0, 0);
        bank.set_weight(2, 3); // len 1, w 3
        let top = top_clauses(&tm, 0, 2, None);
        assert_eq!(top.len(), 2);
        assert!(top[0].contains("w=3"), "{top:?}");
        assert!(top[1].contains("x0 ∧ x1"), "{top:?}");
    }
}
