//! The training loop (§2 Learning): paired target/negative-class
//! updates, clause-update sampling against the voting margin `T`,
//! Type I/II feedback dispatch by polarity.
//!
//! The trainer is generic over the evaluation backend: the *only*
//! behavioural difference between backends is speed (plus the index's
//! maintenance work inside the flip hooks). Given the same seed and data
//! order, all backends produce bit-identical machines — the equivalence
//! tests in `rust/tests/` assert exactly that, which is the paper's
//! implicit correctness claim for the index. The same holds across the
//! TA storage layouts ([`crate::tm::bank::TaLayout`], chosen by
//! `TMParams::ta_layout`): the bit-sliced bank feeds back word-parallel
//! yet stays bit-identical to the scalar bank
//! (`rust/tests/feedback_equiv.rs`).
//!
//! Inference (`predict`/`scores`/`accuracy`/`score_batch_into`) for the
//! **indexed** backend routes through the class-fused batch engine
//! ([`crate::engine::FusedEngine`]): one falsification walk per sample
//! scores every class. Low-density k-hot inputs route instead to the
//! O(nnz) sparse-delta engine ([`crate::engine::SparseEngine`]) — the
//! [`InferMode`] policy auto-picks by measured input density, or can be
//! forced either way. Both engines are lazily (re)built snapshots —
//! training marks them dirty instead of paying double index
//! maintenance, and the next inference call rebuilds once. The
//! naive/bitpacked ablation backends keep their per-class scan so
//! backend comparisons still measure what they claim to. All paths are
//! bit-identical.

use crate::engine::{argmax, BatchScorer, FusedEngine, InferMode, ModelSnapshot, SparseEngine};
use crate::eval::{Backend, Evaluator};
use crate::index::{IndexStats, IndexedEval};
use crate::tm::classifier::MultiClassTM;
use crate::tm::feedback::{
    clause_update_threshold, update_clause_range, FeedbackCtx, FeedbackScratch,
};
use crate::tm::params::TMParams;
use crate::util::rng::Rng;
use crate::util::BitVec;

/// Per-epoch training statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochStats {
    /// Samples seen this epoch.
    pub samples: usize,
    /// Clause-range feedback applications this epoch.
    pub clause_updates: u64,
    /// Include/exclude flips applied through the index hooks.
    pub flips: u64,
    /// Wall-clock time of the epoch (populated by `train_epoch` on both
    /// the sequential and the parallel path).
    pub elapsed: std::time::Duration,
    /// Clause updates per second over the epoch.
    pub updates_per_sec: f64,
}

impl EpochStats {
    /// Derive the throughput fields from a measured epoch duration.
    pub(crate) fn finish(mut self, elapsed: std::time::Duration) -> EpochStats {
        self.elapsed = elapsed;
        let secs = elapsed.as_secs_f64();
        self.updates_per_sec = if secs > 0.0 {
            self.clause_updates as f64 / secs
        } else {
            0.0
        };
        self
    }
}

/// Derive the two training RNG streams for worker `worker` of a
/// clause-sharded training run (see [`crate::parallel`]).
///
/// * stream 0 — the **sample stream**: one draw per sample (the
///   negative-class pick). Every worker derives an *identical* clone,
///   so all shards agree on each sample's negative class without
///   communicating.
/// * stream 1 — the **feedback stream**: per-clause update sampling and
///   Type I literal draws, forked per worker so shards draw
///   independently.
///
/// The sequential [`Trainer`] is exactly worker 0 of this contract,
/// which is what makes a 1-worker [`crate::parallel::ParallelTrainer`]
/// epoch bit-identical to a sequential one.
pub fn train_streams(seed: u64, worker: u64) -> (Rng, Rng) {
    let mut root = Rng::new(seed);
    let mut base = root.fork(0x7261_696e); // "rain" — the training domain
    let sample = base.fork(0x7361_6d70); // "samp": identical for every worker
    let feedback = base.fork(0xfeed_0000_0000_0000 ^ worker);
    (sample, feedback)
}

/// Binds a [`MultiClassTM`] to an evaluation backend and drives
/// learning and prediction.
pub struct Trainer {
    /// The machine being trained (readable between epochs).
    pub tm: MultiClassTM,
    evals: Vec<Box<dyn Evaluator + Send>>,
    backend: Backend,
    /// Per-sample draws (negative-class pick) — stream 0 of
    /// [`train_streams`].
    sample_rng: Rng,
    /// Per-clause feedback draws — stream 1 (worker 0) of
    /// [`train_streams`].
    feedback_rng: Rng,
    ctx: FeedbackCtx,
    out_scratch: BitVec,
    /// Reusable feedback mask buffers (hot path allocates nothing).
    feedback_scratch: FeedbackScratch,
    /// Class-fused inference engine (indexed backend only), built
    /// lazily and invalidated by training steps.
    fused: Option<FusedEngine>,
    fused_dirty: bool,
    /// O(nnz) sparse-delta inference engine (indexed backend only),
    /// built lazily when [`InferMode`] selects it.
    sparse: Option<SparseEngine>,
    sparse_dirty: bool,
    /// Dense/sparse engine selection policy for the indexed backend.
    infer_mode: InferMode,
    /// Worker threads the engine shards large batches across.
    infer_threads: usize,
    /// Reusable per-class score buffer for `predict`.
    class_scratch: Vec<i32>,
    /// Serving snapshots published so far (versions count up from 1).
    publish_seq: u64,
}

impl Trainer {
    /// Trainer over a fresh machine using the given evaluation backend.
    pub fn new(params: TMParams, backend: Backend) -> Self {
        let tm = MultiClassTM::new(params.clone());
        let evals = (0..params.classes)
            .map(|_| backend.make(&params))
            .collect();
        let (sample_rng, feedback_rng) = train_streams(params.seed, 0);
        Trainer {
            out_scratch: BitVec::zeros(params.clauses_per_class),
            feedback_scratch: FeedbackScratch::with_simd(
                params.n_literals(),
                params.simd.resolve(),
            ),
            ctx: FeedbackCtx::new(params.s, params.boost_true_positive, params.weighted),
            evals,
            backend,
            sample_rng,
            feedback_rng,
            tm,
            fused: None,
            fused_dirty: false,
            sparse: None,
            sparse_dirty: false,
            infer_mode: InferMode::Auto,
            infer_threads: 1,
            class_scratch: Vec::new(),
            publish_seq: 0,
        }
    }

    /// Rebuild a trainer around an existing machine (model load,
    /// backend switch). Evaluator state is reconstructed from the banks.
    pub fn from_machine(tm: MultiClassTM, backend: Backend) -> Self {
        let params = tm.params.clone();
        let mut evals: Vec<Box<dyn Evaluator + Send>> = (0..params.classes)
            .map(|_| backend.make(&params))
            .collect();
        for (i, ev) in evals.iter_mut().enumerate() {
            ev.rebuild(tm.bank(i));
        }
        let (sample_rng, feedback_rng) = train_streams(params.seed, 0);
        Trainer {
            out_scratch: BitVec::zeros(params.clauses_per_class),
            feedback_scratch: FeedbackScratch::with_simd(
                params.n_literals(),
                params.simd.resolve(),
            ),
            ctx: FeedbackCtx::new(params.s, params.boost_true_positive, params.weighted),
            evals,
            backend,
            sample_rng,
            feedback_rng,
            tm,
            fused: None,
            fused_dirty: false,
            sparse: None,
            sparse_dirty: false,
            infer_mode: InferMode::Auto,
            infer_threads: 1,
            class_scratch: Vec::new(),
            publish_seq: 0,
        }
    }

    /// The evaluation backend this trainer was built with.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Set the worker-thread count the fused engine shards large
    /// inference batches across (1 = serial; only the indexed backend
    /// uses it).
    pub fn with_infer_threads(mut self, threads: usize) -> Self {
        self.set_infer_threads(threads);
        self
    }

    /// See [`Trainer::with_infer_threads`].
    pub fn set_infer_threads(&mut self, threads: usize) {
        self.infer_threads = threads.max(1);
        if let Some(engine) = &mut self.fused {
            engine.set_threads(self.infer_threads);
        }
        if let Some(engine) = &mut self.sparse {
            engine.set_threads(self.infer_threads);
        }
    }

    /// Worker threads used for batch inference.
    pub fn infer_threads(&self) -> usize {
        self.infer_threads
    }

    /// Dense/sparse engine selection policy for the indexed backend
    /// (builder form).
    pub fn with_infer_mode(mut self, mode: InferMode) -> Self {
        self.set_infer_mode(mode);
        self
    }

    /// See [`Trainer::with_infer_mode`].
    pub fn set_infer_mode(&mut self, mode: InferMode) {
        self.infer_mode = mode;
    }

    /// The engine-selection policy used by inference calls.
    pub fn infer_mode(&self) -> InferMode {
        self.infer_mode
    }

    /// Drop the cached inference engines. Call after mutating `tm`
    /// directly (training through the trainer invalidates them itself).
    pub fn invalidate_engine(&mut self) {
        self.fused_dirty = true;
        self.sparse_dirty = true;
    }

    /// The lazily built class-fused engine (indexed backend): rebuilt
    /// here iff training dirtied it since the last inference call.
    fn ensure_fused(&mut self) -> &mut FusedEngine {
        if self.fused.is_none() {
            self.fused = Some(FusedEngine::from_machine(&self.tm, self.infer_threads));
            self.fused_dirty = false;
        } else if self.fused_dirty {
            self.fused
                .as_mut()
                .expect("fused engine present")
                .rebuild(&self.tm);
            self.fused_dirty = false;
        }
        self.fused.as_mut().expect("fused engine present")
    }

    /// The lazily built sparse-delta engine (indexed backend): rebuilt
    /// here iff training dirtied it since the last sparse inference.
    fn ensure_sparse(&mut self) -> &mut SparseEngine {
        if self.sparse.is_none() {
            self.sparse = Some(SparseEngine::from_machine(&self.tm, self.infer_threads));
            self.sparse_dirty = false;
        } else if self.sparse_dirty {
            self.sparse
                .as_mut()
                .expect("sparse engine present")
                .rebuild(&self.tm);
            self.sparse_dirty = false;
        }
        self.sparse.as_mut().expect("sparse engine present")
    }

    /// Resolve [`InferMode::Auto`] against a batch: sparse iff every
    /// probed sample is complement-structured and the probe's mean
    /// feature density is below
    /// [`crate::engine::SPARSE_DENSITY_THRESHOLD`]. Forced modes pass
    /// through unchanged (see [`crate::engine::resolve_infer_mode`],
    /// shared with the serving snapshot).
    pub fn resolve_infer_mode(&self, batch: &[BitVec]) -> InferMode {
        crate::engine::resolve_infer_mode(self.tm.params.features, self.infer_mode, batch)
    }

    /// Freeze the current machine into an immutable, versioned serving
    /// snapshot ([`ModelSnapshot`]): a clone of the banks plus both
    /// inference engines' read-only indexes, ready for
    /// [`crate::coordinator::Coordinator::swap`]. Versions count up
    /// from 1 per trainer — the train-while-serving loop is
    /// `train_epoch(..); coordinator.swap(model, trainer.publish())`.
    pub fn publish(&mut self) -> std::sync::Arc<ModelSnapshot> {
        self.publish_seq += 1;
        std::sync::Arc::new(ModelSnapshot::with_mode(
            self.tm.clone(),
            self.publish_seq,
            self.infer_mode,
        ))
    }

    /// Reset both training RNG streams to worker 0 of
    /// [`train_streams`]`(seed, 0)`, abandoning the current stream
    /// positions. The online learner uses this to pin an *RNG epoch*
    /// at every durable publish: the live learner and the
    /// crash-restart replay path both reseed to the same epoch
    /// ([`crate::coordinator::online::reseed_seed`]), so replaying the
    /// feedback WAL consumes draw-for-draw the same stream the live
    /// run did and lands on a bit-identical machine.
    pub fn reseed_streams(&mut self, seed: u64) {
        let (sample_rng, feedback_rng) = train_streams(seed, 0);
        self.sample_rng = sample_rng;
        self.feedback_rng = feedback_rng;
    }

    /// Argmax prediction through the per-class evaluators alone — no
    /// fused/sparse engine build, no RNG draws. This is the online
    /// learner's predict-before-apply drift probe: between feedback
    /// updates the inference snapshots are perpetually dirty, so
    /// routing through [`Trainer::predict`] would pay a full engine
    /// rebuild per labeled example; the indexed evaluator scores one
    /// class in O(falsified clauses) instead. Ties break to the
    /// lowest class id, matching [`crate::engine::argmax`].
    pub fn predict_online(&mut self, literals: &BitVec) -> usize {
        let mut best = 0usize;
        let mut best_score = i32::MIN;
        for i in 0..self.tm.classes() {
            let s = self.evals[i].score(self.tm.bank(i), literals);
            if s > best_score {
                best_score = s;
                best = i;
            }
        }
        best
    }

    /// One full update for a labelled sample: Type I/II on the target
    /// class, then on one uniformly-drawn negative class.
    pub fn train_sample(&mut self, literals: &BitVec, label: usize) -> u64 {
        debug_assert!(label < self.tm.classes());
        // the inference snapshots go stale; rebuild lazily at the next
        // inference call instead of paying double maintenance here
        self.fused_dirty = true;
        self.sparse_dirty = true;
        let mut updates = self.update_class(label, literals, true);
        let m = self.tm.classes();
        if m > 1 {
            let mut neg = self.sample_rng.below(m as u32 - 1) as usize;
            if neg >= label {
                neg += 1;
            }
            updates += self.update_class(neg, literals, false);
        }
        updates
    }

    fn update_class(&mut self, class: usize, literals: &BitVec, is_target: bool) -> u64 {
        let t = self.tm.params.threshold as i32;
        let ev = &mut self.evals[class];
        let score = ev.eval_train(self.tm.bank(class), literals, &mut self.out_scratch);
        let p_th = clause_update_threshold(t, score, is_target);
        update_clause_range(
            self.tm.bank_mut(class),
            ev.as_mut(),
            &mut self.feedback_rng,
            &self.ctx,
            &self.out_scratch,
            literals,
            p_th,
            is_target,
            &mut self.feedback_scratch,
        )
    }

    /// One epoch over `(literals, label)` pairs in the given order.
    pub fn train_epoch<'a>(
        &mut self,
        samples: impl Iterator<Item = (&'a BitVec, usize)>,
    ) -> EpochStats {
        let t0 = std::time::Instant::now();
        let mut stats = EpochStats::default();
        for (lits, y) in samples {
            stats.clause_updates += self.train_sample(lits, y);
            stats.samples += 1;
        }
        stats.finish(t0.elapsed())
    }

    /// Rebuild every evaluator's derived state from the banks and drop
    /// the cached fused engine. Call after mutating `tm` from outside
    /// the trainer's own feedback loop — the parallel trainer
    /// ([`crate::parallel`]) uses this when it reassembles shard-trained
    /// banks into the global machine.
    pub fn resync_evaluators(&mut self) {
        for (i, ev) in self.evals.iter_mut().enumerate() {
            ev.rebuild(self.tm.bank(i));
        }
        self.fused_dirty = true;
        self.sparse_dirty = true;
    }

    /// Inference: argmax of per-class scores (eq. 3 / eq. 4). Ties
    /// break to the lowest class id. Indexed backend: one fused walk.
    pub fn predict(&mut self, literals: &BitVec) -> usize {
        let mut buf = std::mem::take(&mut self.class_scratch);
        buf.clear();
        buf.resize(self.tm.classes(), 0);
        self.scores_into(literals, &mut buf);
        let best = argmax(&buf);
        self.class_scratch = buf;
        best
    }

    /// Per-class scores (margin diagnostics; serving uses
    /// [`Trainer::scores_into`] to stay allocation-free).
    pub fn scores(&mut self, literals: &BitVec) -> Vec<i32> {
        let mut out = vec![0i32; self.tm.classes()];
        self.scores_into(literals, &mut out);
        out
    }

    /// Per-class scores into a caller buffer (`out.len() == classes`)
    /// — the allocation-free serving hot path. Indexed backend: one
    /// class-fused falsification walk; other backends: per-class scan.
    pub fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]) {
        assert_eq!(out.len(), self.tm.classes());
        if self.backend == Backend::Indexed {
            match self.resolve_infer_mode(std::slice::from_ref(literals)) {
                InferMode::Sparse => self.ensure_sparse().scores_into(literals, out),
                _ => self.ensure_fused().scores_into(literals, out),
            }
        } else {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = self.evals[i].score(self.tm.bank(i), literals);
            }
        }
    }

    /// Batch scores into the row-major matrix `out[i * classes + c]`.
    /// Indexed backend: fused engine with thread sharding (see
    /// [`Trainer::with_infer_threads`]); other backends: per-class
    /// [`Evaluator::score_batch`] column sweeps.
    pub fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        let m = self.tm.classes();
        assert_eq!(out.len(), batch.len() * m, "output matrix shape mismatch");
        if self.backend == Backend::Indexed {
            match self.resolve_infer_mode(batch) {
                InferMode::Sparse => self.ensure_sparse().score_batch_into(batch, out),
                _ => self.ensure_fused().score_batch_into(batch, out),
            }
        } else {
            // one class at a time over the whole batch: the evaluator's
            // per-clause state stays hot across samples
            let mut col = vec![0i32; batch.len()];
            for (i, ev) in self.evals.iter_mut().enumerate() {
                ev.score_batch(self.tm.bank(i), batch, &mut col);
                for (s, &v) in col.iter().enumerate() {
                    out[s * m + i] = v;
                }
            }
        }
    }

    /// Accuracy over a labelled set.
    pub fn accuracy<'a>(
        &mut self,
        samples: impl Iterator<Item = (&'a BitVec, usize)>,
    ) -> f64 {
        let mut correct = 0usize;
        let mut total = 0usize;
        for (lits, y) in samples {
            if self.predict(lits) == y {
                correct += 1;
            }
            total += 1;
        }
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Index statistics per class (only for the indexed backend).
    pub fn index_stats(&self) -> Option<Vec<IndexStats>> {
        if self.backend != Backend::Indexed {
            return None;
        }
        Some(
            (0..self.tm.classes())
                .map(|i| {
                    let ev = self.evals[i]
                        .as_any()
                        .downcast_ref::<IndexedEval>()
                        .expect("indexed backend holds IndexedEval");
                    IndexStats::collect(ev.index(), self.tm.bank(i))
                })
                .collect(),
        )
    }

    /// Structural invariant check across all classes (tests).
    #[doc(hidden)]
    pub fn check_invariants(&self) -> Result<(), String> {
        for i in 0..self.tm.classes() {
            if !self.tm.bank(i).check_counts() {
                return Err(format!("class {i}: include_count out of sync"));
            }
            if let Some(ev) = self.evals[i].as_any().downcast_ref::<IndexedEval>() {
                ev.index().check_invariants(self.tm.bank(i))?;
            }
        }
        Ok(())
    }
}

/// Serving-facing batch contract: routes to the fused engine for the
/// indexed backend, per-class evaluation otherwise (see the inherent
/// methods of the same names).
impl BatchScorer for Trainer {
    fn classes(&self) -> usize {
        self.tm.classes()
    }

    fn n_literals(&self) -> usize {
        self.tm.params.n_literals()
    }

    fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]) {
        Trainer::scores_into(self, literals, out);
    }

    fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        Trainer::score_batch_into(self, batch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny two-class problem: class 0 = feature 0 set, class 1 = clear.
    fn toy_samples(n: usize, features: usize, seed: u64) -> Vec<(BitVec, usize)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> = (0..features)
                    .map(|k| {
                        if k == 0 {
                            y == 0
                        } else {
                            rng.bern(0.5)
                        }
                    })
                    .collect();
                // literals: [x, ¬x]
                let mut lits = Vec::with_capacity(2 * features);
                lits.extend_from_slice(&bits);
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect()
    }

    fn learns_toy(backend: Backend) {
        let params = TMParams::new(2, 20, 8).with_threshold(10).with_s(3.0);
        let mut tr = Trainer::new(params, backend);
        let train = toy_samples(400, 8, 1);
        for _ in 0..10 {
            tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        }
        let test = toy_samples(200, 8, 2);
        let acc = tr.accuracy(test.iter().map(|(l, y)| (l, *y)));
        assert!(acc > 0.95, "{} accuracy {acc}", backend.name());
        tr.check_invariants().unwrap();
    }

    #[test]
    fn naive_learns_toy_problem() {
        learns_toy(Backend::Naive);
    }

    #[test]
    fn indexed_learns_toy_problem() {
        learns_toy(Backend::Indexed);
    }

    #[test]
    fn bitpacked_learns_toy_problem() {
        learns_toy(Backend::BitPacked);
    }

    #[test]
    fn backends_produce_identical_machines() {
        // The core equivalence theorem: same seed + same data order =>
        // bit-identical TA states regardless of evaluation backend.
        let params = TMParams::new(2, 10, 12).with_threshold(8);
        let train = toy_samples(150, 12, 3);
        let mut machines = vec![];
        for backend in Backend::ALL {
            let mut tr = Trainer::new(params.clone(), backend);
            for _ in 0..3 {
                tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            }
            tr.check_invariants().unwrap();
            machines.push(tr);
        }
        for i in 0..params.classes {
            let s0 = machines[0].tm.bank(i).states();
            for m in &machines[1..] {
                assert_eq!(s0, m.tm.bank(i).states(), "class {i} states diverge");
            }
        }
        // and predictions agree
        let test = toy_samples(50, 12, 4);
        for (lits, _) in &test {
            let p0 = machines[0].predict(lits);
            let s0 = machines[0].scores(lits);
            for m in &mut machines[1..] {
                assert_eq!(s0, m.scores(lits));
                assert_eq!(p0, m.predict(lits));
            }
        }
    }

    #[test]
    fn ta_layouts_produce_identical_machines() {
        // Layout counterpart of the backend-equivalence theorem: the
        // bit-sliced bank trains bit-identically to the scalar one
        // (the deep differential suite is rust/tests/feedback_equiv.rs).
        use crate::tm::bank::TaLayout;
        let base = TMParams::new(2, 10, 12).with_threshold(8);
        let train = toy_samples(150, 12, 3);
        let mut machines = vec![];
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let mut tr =
                Trainer::new(base.clone().with_ta_layout(layout), Backend::Indexed);
            for _ in 0..3 {
                tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            }
            tr.check_invariants().unwrap();
            machines.push(tr);
        }
        for i in 0..base.classes {
            assert_eq!(
                machines[0].tm.bank(i).states(),
                machines[1].tm.bank(i).states(),
                "class {i} states diverge across layouts"
            );
        }
    }

    #[test]
    fn training_is_deterministic_given_seed() {
        let params = TMParams::new(2, 8, 6).with_seed(99);
        let train = toy_samples(100, 6, 5);
        let run = || {
            let mut tr = Trainer::new(params.clone(), Backend::Indexed);
            for _ in 0..2 {
                tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            }
            tr.tm.bank(0).states()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn from_machine_roundtrip_preserves_behaviour() {
        let params = TMParams::new(2, 12, 8);
        let train = toy_samples(200, 8, 6);
        let mut tr = Trainer::new(params, Backend::Indexed);
        for _ in 0..3 {
            tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        }
        let test = toy_samples(60, 8, 7);
        let before: Vec<usize> = test.iter().map(|(l, _)| tr.predict(l)).collect();
        // move the machine to a different backend
        let mut tr2 = Trainer::from_machine(tr.tm.clone(), Backend::Naive);
        let after: Vec<usize> = test.iter().map(|(l, _)| tr2.predict(l)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn fused_engine_tracks_training_across_epochs() {
        // predict/scores interleaved with training: the dirty flag must
        // rebuild the fused snapshot, keeping it identical to the
        // per-class naive path at every step.
        let params = TMParams::new(2, 12, 8).with_threshold(10);
        let mut indexed = Trainer::new(params.clone(), Backend::Indexed);
        let mut naive = Trainer::new(params, Backend::Naive);
        let train = toy_samples(120, 8, 11);
        let probe = toy_samples(30, 8, 12);
        for _ in 0..4 {
            indexed.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            naive.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            for (lits, _) in &probe {
                assert_eq!(indexed.scores(lits), naive.scores(lits));
                assert_eq!(indexed.predict(lits), naive.predict(lits));
            }
        }
    }

    #[test]
    fn score_batch_into_matches_scores_for_all_backends() {
        let params = TMParams::new(2, 10, 8);
        let train = toy_samples(120, 8, 13);
        let probe = toy_samples(25, 8, 14);
        let batch: Vec<BitVec> = probe.iter().map(|(l, _)| l.clone()).collect();
        for backend in Backend::ALL {
            let mut tr = Trainer::new(params.clone(), backend);
            for _ in 0..2 {
                tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
            }
            let mut flat = vec![0i32; batch.len() * 2];
            tr.score_batch_into(&batch, &mut flat);
            for (i, lits) in batch.iter().enumerate() {
                assert_eq!(
                    &flat[i * 2..(i + 1) * 2],
                    tr.scores(lits).as_slice(),
                    "{} sample {i}",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn infer_threads_do_not_change_results() {
        let params = TMParams::new(2, 10, 8);
        let train = toy_samples(100, 8, 15);
        let mut tr = Trainer::new(params, Backend::Indexed);
        for _ in 0..2 {
            tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        }
        let batch: Vec<BitVec> = train.iter().take(64).map(|(l, _)| l.clone()).collect();
        let mut serial = vec![0i32; batch.len() * 2];
        tr.score_batch_into(&batch, &mut serial);
        tr.set_infer_threads(4);
        assert_eq!(tr.infer_threads(), 4);
        let mut sharded = vec![0i32; batch.len() * 2];
        tr.score_batch_into(&batch, &mut sharded);
        assert_eq!(serial, sharded);
    }

    #[test]
    fn reseed_streams_restores_draw_sequences() {
        // train, reseed, retrain == the fresh-trainer stream from the
        // same banks: the contract the WAL replay path depends on
        let params = TMParams::new(2, 8, 6).with_seed(42);
        let train = toy_samples(60, 6, 8);
        let mut a = Trainer::new(params.clone(), Backend::Indexed);
        for (l, y) in &train {
            a.train_sample(l, *y);
        }
        a.reseed_streams(params.seed);
        for (l, y) in &train {
            a.train_sample(l, *y);
        }
        let mut pre = Trainer::new(params.clone(), Backend::Indexed);
        for (l, y) in &train {
            pre.train_sample(l, *y);
        }
        let mut b = Trainer::from_machine(pre.tm.clone(), Backend::Indexed);
        for (l, y) in &train {
            b.train_sample(l, *y);
        }
        for c in 0..2 {
            assert_eq!(a.tm.bank(c).states(), b.tm.bank(c).states());
        }
    }

    #[test]
    fn predict_online_matches_predict() {
        let params = TMParams::new(2, 10, 8);
        let train = toy_samples(100, 8, 9);
        let mut tr = Trainer::new(params, Backend::Indexed);
        for _ in 0..2 {
            tr.train_epoch(train.iter().map(|(l, y)| (l, *y)));
        }
        for (l, _) in &train[..30] {
            assert_eq!(tr.predict_online(l), tr.predict(l));
        }
    }

    #[test]
    fn predict_online_is_training_neutral() {
        // the drift probe must not perturb training state or RNG
        // position — the online differential test leans on this
        let params = TMParams::new(2, 10, 8);
        let train = toy_samples(80, 8, 10);
        let mut probed = Trainer::new(params.clone(), Backend::Indexed);
        let mut control = Trainer::new(params, Backend::Indexed);
        for (l, y) in &train {
            let _ = probed.predict_online(l);
            probed.train_sample(l, *y);
            control.train_sample(l, *y);
        }
        for c in 0..2 {
            assert_eq!(probed.tm.bank(c).states(), control.tm.bank(c).states());
        }
    }

    #[test]
    fn index_stats_only_for_indexed() {
        let params = TMParams::new(2, 4, 4);
        let tr = Trainer::new(params.clone(), Backend::Naive);
        assert!(tr.index_stats().is_none());
        let tr = Trainer::new(params, Backend::Indexed);
        let stats = tr.index_stats().unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].total_inclusions, 0);
    }
}
