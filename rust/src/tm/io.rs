//! Model persistence and densification.
//!
//! * Binary save/load of a trained machine (magic + params JSON + raw TA
//!   state bytes) — keeps the serving coordinator restartable.
//! * [`DenseModel`]: the dense f32 arrays the AOT-compiled XLA
//!   executable consumes (`include`, `count`, `polarity` — see
//!   `python/compile/model.py` for the layout contract).
//!
//! **TA layout note:** the serialized state block is always the
//! portable *scalar* byte form — clause-major `i8` states, one byte per
//! TA — regardless of the in-memory [`crate::tm::bank::TaLayout`]
//! (bit-sliced banks are decoded on save and re-encoded on load). The
//! params JSON carries `ta_layout` so a reload reconstructs the same
//! in-memory representation, but any layout can read any model file:
//! the two layouts are bit-identical state machines.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{ensure, Result};

use crate::tm::classifier::MultiClassTM;
use crate::tm::params::TMParams;
use crate::util::Json;

const MAGIC: &[u8; 8] = b"TMINDEX2"; // v2: + clause weights per class

/// Save a machine to a writer.
pub fn save_to(tm: &MultiClassTM, w: &mut impl Write) -> Result<()> {
    w.write_all(MAGIC)?;
    let params = tm.params.to_json().to_string().into_bytes();
    w.write_all(&(params.len() as u64).to_le_bytes())?;
    w.write_all(&params)?;
    for i in 0..tm.classes() {
        // portable scalar byte form (decoded from bitplanes if sliced);
        // i8 -> u8 reinterpretation is value-preserving for storage
        let bytes: Vec<u8> = tm.bank(i).states().iter().map(|&s| s as u8).collect();
        w.write_all(&bytes)?;
        for &wgt in tm.bank(i).weights() {
            w.write_all(&wgt.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load a machine from a reader.
pub fn load_from(r: &mut impl Read) -> Result<MultiClassTM> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "bad magic: not a TM model file");
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len) as usize;
    ensure!(len < 1 << 20, "params block implausibly large");
    let mut params_buf = vec![0u8; len];
    r.read_exact(&mut params_buf)?;
    let params_text = std::str::from_utf8(&params_buf)?;
    let params =
        TMParams::from_json(&Json::parse(params_text)?).map_err(|e| anyhow::anyhow!(e))?;

    let mut tm = MultiClassTM::new(params.clone());
    let row = params.clauses_per_class * params.n_literals();
    let mut buf = vec![0u8; row];
    let mut wbuf = [0u8; 4];
    for i in 0..params.classes {
        r.read_exact(&mut buf)?;
        let bank = tm.bank_mut(i);
        for j in 0..params.clauses_per_class {
            for k in 0..params.n_literals() {
                bank.set_state(j, k, buf[j * params.n_literals() + k] as i8);
            }
        }
        for j in 0..params.clauses_per_class {
            r.read_exact(&mut wbuf)?;
            let w = u32::from_le_bytes(wbuf);
            ensure!(w >= 1, "clause weight must be >= 1");
            bank.set_weight(j, w);
        }
    }
    Ok(tm)
}

/// Save atomically: write to a `.tmp` sibling, then rename over
/// `path`. A concurrent reader — `tmi serve --watch` re-publishing on
/// model-file change — therefore never observes a torn, half-written
/// model; it sees either the old file or the complete new one.
pub fn save(tm: &MultiClassTM, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        save_to(tm, &mut f)?;
        f.flush()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<MultiClassTM> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    load_from(&mut f)
}

/// Dense f32 export for the XLA serving backend.
///
/// Layout contract (must match `python/compile/model.py`):
/// clauses are ordered class-major (`jt = class * n + j`);
/// `include[k * clauses_total + jt]`, `count[jt]`,
/// `polarity[jt * classes + class] = ±1`.
#[derive(Clone, Debug)]
pub struct DenseModel {
    pub features: usize,
    pub n_literals: usize,
    pub clauses_total: usize,
    pub classes: usize,
    pub include: Vec<f32>,
    pub count: Vec<f32>,
    pub polarity: Vec<f32>,
}

impl DenseModel {
    pub fn from_tm(tm: &MultiClassTM) -> Self {
        let m = tm.classes();
        let n = tm.params.clauses_per_class;
        let n_lit = tm.params.n_literals();
        let total = m * n;
        let mut include = vec![0f32; n_lit * total];
        let mut count = vec![0f32; total];
        let mut polarity = vec![0f32; total * m];
        for i in 0..m {
            let bank = tm.bank(i);
            for j in 0..n {
                let jt = i * n + j;
                count[jt] = bank.count(j) as f32;
                // weighted vote: the XLA polarity matrix carries ±weight
                polarity[jt * m + i] = bank.vote(j) as f32;
                for k in bank.included_literals(j) {
                    include[k * total + jt] = 1.0;
                }
            }
        }
        DenseModel {
            features: tm.params.features,
            n_literals: n_lit,
            clauses_total: total,
            classes: m,
            include,
            count,
            polarity,
        }
    }

    /// Reference scores straight off the dense arrays (test oracle for
    /// the XLA path; mirrors `python/compile/kernels/ref.py`).
    pub fn scores(&self, literals: &[f32]) -> Vec<f32> {
        assert_eq!(literals.len() % self.n_literals, 0);
        let batch = literals.len() / self.n_literals;
        let mut out = vec![0f32; batch * self.classes];
        for b in 0..batch {
            let lits = &literals[b * self.n_literals..(b + 1) * self.n_literals];
            for jt in 0..self.clauses_total {
                if self.count[jt] == 0.0 {
                    continue;
                }
                let mut alive = true;
                for k in 0..self.n_literals {
                    if self.include[k * self.clauses_total + jt] == 1.0 && lits[k] == 0.0 {
                        alive = false;
                        break;
                    }
                }
                if alive {
                    for i in 0..self.classes {
                        out[b * self.classes + i] += self.polarity[jt * self.classes + i];
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Backend;
    use crate::tm::trainer::Trainer;
    use crate::util::{BitVec, Rng};

    fn trained_machine() -> MultiClassTM {
        let params = TMParams::new(3, 8, 10).with_seed(7);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(5);
        let samples: Vec<(BitVec, usize)> = (0..120)
            .map(|_| {
                let y = rng.below(3) as usize;
                let bits: Vec<bool> = (0..10).map(|k| k % 3 == y || rng.bern(0.3)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect();
        for _ in 0..3 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let tm = trained_machine();
        let mut buf = Vec::new();
        save_to(&tm, &mut buf).unwrap();
        let tm2 = load_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tm.params, tm2.params);
        for i in 0..tm.classes() {
            assert_eq!(tm.bank(i).states(), tm2.bank(i).states(), "class {i}");
            assert!(tm2.bank(i).check_counts());
        }
    }

    #[test]
    fn weighted_save_load_score_roundtrip() {
        // a *weighted* multiclass model: weights != 1 must survive the
        // roundtrip and the reloaded machine must score bit-identically
        // on every backend
        let params = TMParams::new(3, 8, 10).with_seed(21).with_weighted(true);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(17);
        let samples: Vec<(BitVec, usize)> = (0..150)
            .map(|_| {
                let y = rng.below(3) as usize;
                let bits: Vec<bool> = (0..10).map(|k| k % 3 == y || rng.bern(0.25)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect();
        for _ in 0..4 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        let tm = tr.tm;
        let grew = (0..3).any(|c| tm.bank(c).weights().iter().any(|&w| w > 1));
        assert!(grew, "weighted training never grew a weight");

        let mut buf = Vec::new();
        save_to(&tm, &mut buf).unwrap();
        let tm2 = load_from(&mut buf.as_slice()).unwrap();
        assert!(tm2.params.weighted);
        for c in 0..3 {
            assert_eq!(tm.bank(c).states(), tm2.bank(c).states(), "class {c}");
            assert_eq!(tm.bank(c).weights(), tm2.bank(c).weights(), "class {c}");
        }
        // scores agree between the original and the reload, across
        // backends (weighted votes flow through every path)
        let mut orig = Trainer::from_machine(tm, Backend::Indexed);
        let mut naive = Trainer::from_machine(tm2.clone(), Backend::Naive);
        let mut indexed = Trainer::from_machine(tm2, Backend::Indexed);
        for (lits, _) in samples.iter().take(40) {
            let want = orig.scores(lits);
            assert_eq!(naive.scores(lits), want);
            assert_eq!(indexed.scores(lits), want);
        }
    }

    #[test]
    fn sliced_and_scalar_models_serialize_identically() {
        // same trained machine in both layouts: the byte streams match
        // exactly (scalar serialized form), and a sliced save reloads
        // into a sliced bank with the same states.
        use crate::tm::bank::TaLayout;
        let params = TMParams::new(3, 8, 10).with_seed(7);
        let train_bytes = |layout: TaLayout| -> Vec<u8> {
            let mut tr =
                Trainer::new(params.clone().with_ta_layout(layout), Backend::Indexed);
            let mut rng = Rng::new(5);
            let samples: Vec<(BitVec, usize)> = (0..120)
                .map(|_| {
                    let y = rng.below(3) as usize;
                    let bits: Vec<bool> =
                        (0..10).map(|k| k % 3 == y || rng.bern(0.3)).collect();
                    let mut lits = bits.clone();
                    lits.extend(bits.iter().map(|b| !b));
                    (BitVec::from_bools(&lits), y)
                })
                .collect();
            for _ in 0..3 {
                tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
            }
            let mut buf = Vec::new();
            save_to(&tr.tm, &mut buf).unwrap();
            buf
        };
        let scalar_bytes = train_bytes(TaLayout::Scalar);
        let sliced_bytes = train_bytes(TaLayout::Sliced);
        // identical except for the params JSON block (ta_layout name):
        // the decoded machines must agree exactly
        let a = load_from(&mut scalar_bytes.as_slice()).unwrap();
        let b = load_from(&mut sliced_bytes.as_slice()).unwrap();
        assert_eq!(a.params.ta_layout, TaLayout::Scalar);
        assert_eq!(b.params.ta_layout, TaLayout::Sliced);
        assert_eq!(b.bank(0).layout(), TaLayout::Sliced);
        for c in 0..3 {
            assert_eq!(a.bank(c).states(), b.bank(c).states(), "class {c}");
            assert!(b.bank(c).check_counts());
        }
    }

    #[test]
    fn save_is_atomic_and_roundtrips_via_path() {
        let tm = trained_machine();
        let path = std::env::temp_dir().join(format!("tmi-io-{}.tm", std::process::id()));
        save(&tm, &path).unwrap();
        // the temp sibling must be gone (renamed into place)
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        assert!(!std::path::PathBuf::from(tmp_name).exists());
        let tm2 = load(&path).unwrap();
        for i in 0..tm.classes() {
            assert_eq!(tm.bank(i).states(), tm2.bank(i).states(), "class {i}");
        }
        // overwrite in place (the --watch republish cycle)
        save(&tm2, &path).unwrap();
        assert!(load(&path).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(load_from(&mut &b"not a model"[..]).is_err());
        let mut buf = Vec::new();
        save_to(&trained_machine(), &mut buf).unwrap();
        buf[0] = b'X';
        assert!(load_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn load_rejects_truncation() {
        let mut buf = Vec::new();
        save_to(&trained_machine(), &mut buf).unwrap();
        buf.truncate(buf.len() - 10);
        assert!(load_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn dense_model_matches_trainer_scores() {
        let tm = trained_machine();
        let dense = DenseModel::from_tm(&tm);
        let mut tr = Trainer::from_machine(tm, Backend::Naive);
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let bits: Vec<bool> = (0..10).map(|_| rng.bern(0.5)).collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            let bv = BitVec::from_bools(&lits);
            let want = tr.scores(&bv);
            let lits_f32: Vec<f32> =
                lits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
            let got = dense.scores(&lits_f32);
            for (i, &w) in want.iter().enumerate() {
                assert_eq!(got[i], w as f32, "class {i}");
            }
        }
    }

    #[test]
    fn dense_shapes() {
        let tm = trained_machine();
        let d = DenseModel::from_tm(&tm);
        assert_eq!(d.clauses_total, 24);
        assert_eq!(d.include.len(), 20 * 24);
        assert_eq!(d.polarity.len(), 24 * 3);
        // each clause votes for exactly its own class
        for jt in 0..24 {
            let nz: Vec<usize> = (0..3)
                .filter(|&i| d.polarity[jt * 3 + i] != 0.0)
                .collect();
            assert_eq!(nz, vec![jt / 8]);
        }
    }
}
