//! Model persistence and densification.
//!
//! * Binary save/load of a trained machine — keeps the serving
//!   coordinator restartable. Format **v3** (`TMINDEX3`): magic +
//!   params JSON + raw TA state bytes + clause weights + a CRC-32
//!   footer over everything before it, so torn or bit-flipped files
//!   are *detected* ([`ModelIoError::Corrupt`]) instead of silently
//!   served. v2 files (`TMINDEX2`, no footer) still load.
//! * [`DenseModel`]: the dense f32 arrays the AOT-compiled XLA
//!   executable consumes (`include`, `count`, `polarity` — see
//!   `python/compile/model.py` for the layout contract).
//!
//! **TA layout note:** the serialized state block is always the
//! portable *scalar* byte form — clause-major `i8` states, one byte per
//! TA — regardless of the in-memory [`crate::tm::bank::TaLayout`]
//! (bit-sliced banks are decoded on save and re-encoded on load). The
//! params JSON carries `ta_layout` so a reload reconstructs the same
//! in-memory representation, but any layout can read any model file:
//! the two layouts are bit-identical state machines.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::Result;

use crate::tm::classifier::MultiClassTM;
use crate::tm::params::TMParams;
use crate::util::{crc32, Crc32, Json};

/// v3: v2 body + CRC-32 footer (4 bytes LE, over magic..end-of-body).
const MAGIC_V3: &[u8; 8] = b"TMINDEX3";
/// v2: + clause weights per class, no checksum footer (legacy load).
const MAGIC_V2: &[u8; 8] = b"TMINDEX2";

/// Typed model-file load failure. Every malformed input maps to one of
/// these — there are no panic paths in [`load_from`], so a serving
/// process can quarantine a bad file and keep answering.
#[derive(Debug)]
pub enum ModelIoError {
    /// The first 8 bytes name neither `TMINDEX3` nor `TMINDEX2`.
    BadMagic,
    /// The stream ended before the declared structure did (torn or
    /// half-written file).
    Truncated,
    /// Structurally complete but invalid: checksum mismatch, malformed
    /// params JSON, or out-of-range field values.
    Corrupt(String),
    /// An underlying I/O failure other than EOF.
    Io(std::io::Error),
}

impl std::fmt::Display for ModelIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ModelIoError::BadMagic => write!(f, "bad magic: not a TM model file"),
            ModelIoError::Truncated => write!(f, "truncated model file"),
            ModelIoError::Corrupt(why) => write!(f, "corrupt model file: {why}"),
            ModelIoError::Io(e) => write!(f, "model io error: {e}"),
        }
    }
}

impl std::error::Error for ModelIoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ModelIoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ModelIoError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            ModelIoError::Truncated
        } else {
            ModelIoError::Io(e)
        }
    }
}

fn corrupt(why: impl Into<String>) -> ModelIoError {
    ModelIoError::Corrupt(why.into())
}

/// Write the format body (params + states + weights) — everything
/// between the magic and the v3 footer. Identical for v2 and v3.
fn write_body(tm: &MultiClassTM, w: &mut impl Write) -> std::io::Result<()> {
    let params = tm.params.to_json().to_string().into_bytes();
    w.write_all(&(params.len() as u64).to_le_bytes())?;
    w.write_all(&params)?;
    for i in 0..tm.classes() {
        // portable scalar byte form (decoded from bitplanes if sliced);
        // i8 -> u8 reinterpretation is value-preserving for storage
        let bytes: Vec<u8> = tm.bank(i).states().iter().map(|&s| s as u8).collect();
        w.write_all(&bytes)?;
        for &wgt in tm.bank(i).weights() {
            w.write_all(&wgt.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Save a machine to a writer in format v3 (checksummed).
pub fn save_to(tm: &MultiClassTM, w: &mut impl Write) -> Result<()> {
    let bytes = serialize(tm);
    w.write_all(&bytes)?;
    Ok(())
}

/// Serialize a machine to its complete v3 byte image (magic + body +
/// CRC-32 footer). The registry stores these bytes verbatim and records
/// [`crate::util::crc32`] of them as the file digest.
pub fn serialize(tm: &MultiClassTM) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC_V3);
    write_body(tm, &mut buf).expect("Vec write is infallible");
    let mut crc = Crc32::new();
    crc.update(&buf);
    buf.extend_from_slice(&crc.finish().to_le_bytes());
    buf
}

/// Content digest of a machine: CRC-32 of its serialized v3 image.
/// Two machines share a digest iff their persisted form is
/// bit-identical — the recovery tests' "scores identically" witness.
pub fn model_digest(tm: &MultiClassTM) -> u32 {
    crc32(&serialize(tm))
}

/// Parse the body header — params length + params JSON — and return
/// `(params, state_offset, expected_body_len)`. All size arithmetic is
/// checked: a corrupt dimension field must fail typed, never overflow
/// or drive a giant allocation.
fn read_header(bytes: &[u8]) -> Result<(TMParams, usize, usize), ModelIoError> {
    let mut r = bytes;
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let len = u64::from_le_bytes(len) as usize;
    if len >= 1 << 20 {
        return Err(corrupt("params block implausibly large"));
    }
    if r.len() < len {
        return Err(ModelIoError::Truncated);
    }
    let params_text =
        std::str::from_utf8(&r[..len]).map_err(|_| corrupt("params block is not UTF-8"))?;
    let params_json =
        Json::parse(params_text).map_err(|e| corrupt(format!("params JSON: {e}")))?;
    let params = TMParams::from_json(&params_json).map_err(corrupt)?;
    let dims = || corrupt("implausible model dimensions");
    let row = params
        .clauses_per_class
        .checked_mul(params.n_literals())
        .ok_or_else(dims)?;
    let per_class = row
        .checked_add(params.clauses_per_class.checked_mul(4).ok_or_else(dims)?)
        .ok_or_else(dims)?;
    let state_offset = 8 + len;
    let expected = params
        .classes
        .checked_mul(per_class)
        .and_then(|n| n.checked_add(state_offset))
        .ok_or_else(dims)?;
    Ok((params, state_offset, expected))
}

/// Parse the format body out of `bytes` (everything after the magic,
/// footer already stripped for v3).
fn read_body(bytes: &[u8]) -> Result<MultiClassTM, ModelIoError> {
    let (params, state_offset, expected) = read_header(bytes)?;
    if bytes.len() < expected {
        return Err(ModelIoError::Truncated);
    }
    let mut r = &bytes[state_offset..];
    let mut tm = MultiClassTM::new(params.clone());
    let row = params.clauses_per_class * params.n_literals();
    let mut buf = vec![0u8; row];
    let mut wbuf = [0u8; 4];
    for i in 0..params.classes {
        r.read_exact(&mut buf)?;
        let bank = tm.bank_mut(i);
        for j in 0..params.clauses_per_class {
            for k in 0..params.n_literals() {
                bank.set_state(j, k, buf[j * params.n_literals() + k] as i8);
            }
        }
        for j in 0..params.clauses_per_class {
            r.read_exact(&mut wbuf)?;
            let w = u32::from_le_bytes(wbuf);
            if w < 1 {
                return Err(corrupt("clause weight must be >= 1"));
            }
            bank.set_weight(j, w);
        }
    }
    Ok(tm)
}

/// Load a machine from a reader. Accepts v3 (footer verified *before*
/// the body is trusted) and v2 (legacy, no footer). Never panics on
/// malformed input — every failure is a typed [`ModelIoError`].
pub fn load_from(r: &mut impl Read) -> Result<MultiClassTM, ModelIoError> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let checksummed = match &magic {
        m if m == MAGIC_V3 => true,
        m if m == MAGIC_V2 => false,
        _ => return Err(ModelIoError::BadMagic),
    };
    let mut rest = Vec::new();
    r.read_to_end(&mut rest)?;
    if !checksummed {
        return read_body(&rest);
    }
    if rest.len() < 4 {
        return Err(ModelIoError::Truncated);
    }
    let body_len = rest.len() - 4;
    let body = &rest[..body_len];
    let stored = u32::from_le_bytes(rest[body_len..].try_into().expect("4-byte footer"));
    let mut crc = Crc32::new();
    crc.update(&magic);
    crc.update(body);
    let computed = crc.finish();
    if computed == stored {
        return read_body(body);
    }
    // The checksum failed. A *torn* file (crashed writer) is a strict
    // prefix of a valid one — diagnose it by probing the header: if the
    // declared structure overruns what's on disk, report Truncated;
    // anything else is in-place corruption.
    match read_header(body) {
        Err(ModelIoError::Truncated) => Err(ModelIoError::Truncated),
        Ok((_, _, expected)) if body.len() < expected => Err(ModelIoError::Truncated),
        _ => Err(corrupt(format!(
            "checksum mismatch (stored {stored:#010x}, computed {computed:#010x})"
        ))),
    }
}

/// Save atomically: write to a `.tmp` sibling, fsync, then rename over
/// `path`. A concurrent reader — `tmi serve --watch` re-publishing on
/// model-file change — therefore never observes a torn, half-written
/// model; it sees either the old file or the complete new one.
pub fn save(tm: &MultiClassTM, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp_name);
    {
        let f = std::fs::File::create(&tmp)?;
        let mut w = std::io::BufWriter::new(f);
        save_to(tm, &mut w)?;
        w.flush()?;
        // fsync before the rename: a crash between rename and writeback
        // must not leave a renamed-but-empty file
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a model file (v2 or v3), verifying the CRC-32 footer first.
pub fn load(path: impl AsRef<Path>) -> Result<MultiClassTM, ModelIoError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    load_from(&mut f)
}

/// Dense f32 export for the XLA serving backend.
///
/// Layout contract (must match `python/compile/model.py`):
/// clauses are ordered class-major (`jt = class * n + j`);
/// `include[k * clauses_total + jt]`, `count[jt]`,
/// `polarity[jt * classes + class] = ±1`.
#[derive(Clone, Debug)]
pub struct DenseModel {
    /// Number of raw boolean features.
    pub features: usize,
    /// Number of literals (2 × features).
    pub n_literals: usize,
    /// Total clauses across every class.
    pub clauses_total: usize,
    /// Number of classes.
    pub classes: usize,
    /// Row-major include mask, `clauses_total × n_literals`, 0.0/1.0.
    pub include: Vec<f32>,
    /// Included-literal count per clause.
    pub count: Vec<f32>,
    /// Vote polarity per clause (+1.0 even local ids, −1.0 odd).
    pub polarity: Vec<f32>,
}

impl DenseModel {
    /// Flatten a machine into the dense array form the XLA path consumes.
    pub fn from_tm(tm: &MultiClassTM) -> Self {
        let m = tm.classes();
        let n = tm.params.clauses_per_class;
        let n_lit = tm.params.n_literals();
        let total = m * n;
        let mut include = vec![0f32; n_lit * total];
        let mut count = vec![0f32; total];
        let mut polarity = vec![0f32; total * m];
        for i in 0..m {
            let bank = tm.bank(i);
            for j in 0..n {
                let jt = i * n + j;
                count[jt] = bank.count(j) as f32;
                // weighted vote: the XLA polarity matrix carries ±weight
                polarity[jt * m + i] = bank.vote(j) as f32;
                for k in bank.included_literals(j) {
                    include[k * total + jt] = 1.0;
                }
            }
        }
        DenseModel {
            features: tm.params.features,
            n_literals: n_lit,
            clauses_total: total,
            classes: m,
            include,
            count,
            polarity,
        }
    }

    /// Reference scores straight off the dense arrays (test oracle for
    /// the XLA path; mirrors `python/compile/kernels/ref.py`).
    pub fn scores(&self, literals: &[f32]) -> Vec<f32> {
        assert_eq!(literals.len() % self.n_literals, 0);
        let batch = literals.len() / self.n_literals;
        let mut out = vec![0f32; batch * self.classes];
        for b in 0..batch {
            let lits = &literals[b * self.n_literals..(b + 1) * self.n_literals];
            for jt in 0..self.clauses_total {
                if self.count[jt] == 0.0 {
                    continue;
                }
                let mut alive = true;
                for k in 0..self.n_literals {
                    if self.include[k * self.clauses_total + jt] == 1.0 && lits[k] == 0.0 {
                        alive = false;
                        break;
                    }
                }
                if alive {
                    for i in 0..self.classes {
                        out[b * self.classes + i] += self.polarity[jt * self.classes + i];
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Backend;
    use crate::tm::trainer::Trainer;
    use crate::util::{BitVec, Rng};

    fn trained_machine() -> MultiClassTM {
        let params = TMParams::new(3, 8, 10).with_seed(7);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(5);
        let samples: Vec<(BitVec, usize)> = (0..120)
            .map(|_| {
                let y = rng.below(3) as usize;
                let bits: Vec<bool> = (0..10).map(|k| k % 3 == y || rng.bern(0.3)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect();
        for _ in 0..3 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    /// Serialize in the legacy v2 framing (no footer) — the back-compat
    /// fixture generator.
    fn serialize_v2(tm: &MultiClassTM) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V2);
        write_body(tm, &mut buf).unwrap();
        buf
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let tm = trained_machine();
        let mut buf = Vec::new();
        save_to(&tm, &mut buf).unwrap();
        assert_eq!(&buf[..8], MAGIC_V3);
        let tm2 = load_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tm.params, tm2.params);
        for i in 0..tm.classes() {
            assert_eq!(tm.bank(i).states(), tm2.bank(i).states(), "class {i}");
            assert!(tm2.bank(i).check_counts());
        }
    }

    #[test]
    fn v2_files_still_load() {
        let tm = trained_machine();
        let v2 = serialize_v2(&tm);
        let tm2 = load_from(&mut v2.as_slice()).unwrap();
        assert_eq!(tm.params, tm2.params);
        for i in 0..tm.classes() {
            assert_eq!(tm.bank(i).states(), tm2.bank(i).states(), "class {i}");
        }
        // a v2 reload re-saves as v3 — the migration path
        let mut buf = Vec::new();
        save_to(&tm2, &mut buf).unwrap();
        assert_eq!(&buf[..8], MAGIC_V3);
    }

    #[test]
    fn model_digest_tracks_content() {
        let tm = trained_machine();
        assert_eq!(model_digest(&tm), model_digest(&tm.clone()));
        let mut other = tm.clone();
        let s = other.bank(0).states()[0];
        other.bank_mut(0).set_state(0, 0, s.wrapping_add(1));
        assert_ne!(model_digest(&tm), model_digest(&other));
    }

    #[test]
    fn weighted_save_load_score_roundtrip() {
        // a *weighted* multiclass model: weights != 1 must survive the
        // roundtrip and the reloaded machine must score bit-identically
        // on every backend
        let params = TMParams::new(3, 8, 10).with_seed(21).with_weighted(true);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(17);
        let samples: Vec<(BitVec, usize)> = (0..150)
            .map(|_| {
                let y = rng.below(3) as usize;
                let bits: Vec<bool> = (0..10).map(|k| k % 3 == y || rng.bern(0.25)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect();
        for _ in 0..4 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        let tm = tr.tm;
        let grew = (0..3).any(|c| tm.bank(c).weights().iter().any(|&w| w > 1));
        assert!(grew, "weighted training never grew a weight");

        let mut buf = Vec::new();
        save_to(&tm, &mut buf).unwrap();
        let tm2 = load_from(&mut buf.as_slice()).unwrap();
        assert!(tm2.params.weighted);
        for c in 0..3 {
            assert_eq!(tm.bank(c).states(), tm2.bank(c).states(), "class {c}");
            assert_eq!(tm.bank(c).weights(), tm2.bank(c).weights(), "class {c}");
        }
        // scores agree between the original and the reload, across
        // backends (weighted votes flow through every path)
        let mut orig = Trainer::from_machine(tm, Backend::Indexed);
        let mut naive = Trainer::from_machine(tm2.clone(), Backend::Naive);
        let mut indexed = Trainer::from_machine(tm2, Backend::Indexed);
        for (lits, _) in samples.iter().take(40) {
            let want = orig.scores(lits);
            assert_eq!(naive.scores(lits), want);
            assert_eq!(indexed.scores(lits), want);
        }
    }

    #[test]
    fn sliced_and_scalar_models_serialize_identically() {
        // same trained machine in both layouts: the byte streams match
        // exactly (scalar serialized form), and a sliced save reloads
        // into a sliced bank with the same states.
        use crate::tm::bank::TaLayout;
        let params = TMParams::new(3, 8, 10).with_seed(7);
        let train_bytes = |layout: TaLayout| -> Vec<u8> {
            let mut tr =
                Trainer::new(params.clone().with_ta_layout(layout), Backend::Indexed);
            let mut rng = Rng::new(5);
            let samples: Vec<(BitVec, usize)> = (0..120)
                .map(|_| {
                    let y = rng.below(3) as usize;
                    let bits: Vec<bool> =
                        (0..10).map(|k| k % 3 == y || rng.bern(0.3)).collect();
                    let mut lits = bits.clone();
                    lits.extend(bits.iter().map(|b| !b));
                    (BitVec::from_bools(&lits), y)
                })
                .collect();
            for _ in 0..3 {
                tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
            }
            let mut buf = Vec::new();
            save_to(&tr.tm, &mut buf).unwrap();
            buf
        };
        let scalar_bytes = train_bytes(TaLayout::Scalar);
        let sliced_bytes = train_bytes(TaLayout::Sliced);
        // identical except for the params JSON block (ta_layout name):
        // the decoded machines must agree exactly
        let a = load_from(&mut scalar_bytes.as_slice()).unwrap();
        let b = load_from(&mut sliced_bytes.as_slice()).unwrap();
        assert_eq!(a.params.ta_layout, TaLayout::Scalar);
        assert_eq!(b.params.ta_layout, TaLayout::Sliced);
        assert_eq!(b.bank(0).layout(), TaLayout::Sliced);
        for c in 0..3 {
            assert_eq!(a.bank(c).states(), b.bank(c).states(), "class {c}");
            assert!(b.bank(c).check_counts());
        }
    }

    #[test]
    fn save_is_atomic_and_roundtrips_via_path() {
        let tm = trained_machine();
        let path = std::env::temp_dir().join(format!("tmi-io-{}.tm", std::process::id()));
        save(&tm, &path).unwrap();
        // the temp sibling must be gone (renamed into place)
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        assert!(!std::path::PathBuf::from(tmp_name).exists());
        let tm2 = load(&path).unwrap();
        for i in 0..tm.classes() {
            assert_eq!(tm.bank(i).states(), tm2.bank(i).states(), "class {i}");
        }
        // overwrite in place (the --watch republish cycle)
        save(&tm2, &path).unwrap();
        assert!(load(&path).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_garbage_with_bad_magic() {
        assert!(matches!(
            load_from(&mut &b"not a model!"[..]),
            Err(ModelIoError::BadMagic)
        ));
        let mut buf = Vec::new();
        save_to(&trained_machine(), &mut buf).unwrap();
        buf[0] = b'X';
        assert!(matches!(
            load_from(&mut buf.as_slice()),
            Err(ModelIoError::BadMagic)
        ));
    }

    #[test]
    fn truncation_reports_typed_error_at_every_length() {
        // every proper prefix of a valid file must fail with Truncated
        // (or BadMagic below 8 bytes) — never panic, never succeed
        let mut buf = Vec::new();
        save_to(&trained_machine(), &mut buf).unwrap();
        let probes: Vec<usize> =
            [0, 1, 7, 8, 9, 15, 16, 40, buf.len() / 2, buf.len() - 5, buf.len() - 1]
                .into_iter()
                .filter(|&n| n < buf.len())
                .collect();
        for n in probes {
            match load_from(&mut &buf[..n]) {
                Err(ModelIoError::Truncated) => {}
                // fewer than 8 bytes cannot even prove the magic
                Err(ModelIoError::BadMagic) if n < 8 => {}
                other => panic!("prefix of {n} bytes: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn bit_flips_report_corrupt_not_panic() {
        let mut buf = Vec::new();
        save_to(&trained_machine(), &mut buf).unwrap();
        let len = buf.len();
        // flips in the state/weight/footer region: body still parses, so
        // the CRC mismatch is reported as such
        for pos in [len / 3, len / 2, len - 6, len - 1] {
            let mut bad = buf.clone();
            bad[pos] ^= 0x04;
            match load_from(&mut bad.as_slice()) {
                Err(ModelIoError::Corrupt(why)) => {
                    assert!(why.contains("checksum"), "offset {pos}: {why}")
                }
                other => panic!("flip at {pos}: expected Corrupt, got {other:?}"),
            }
        }
        // flips in the length field / params JSON: still a typed error,
        // never Ok, never a panic. (A flipped length that overruns the
        // file is indistinguishable from truncation, so Truncated is an
        // acceptable diagnosis here.)
        for pos in [8, 9, 20] {
            let mut bad = buf.clone();
            bad[pos] ^= 0x04;
            match load_from(&mut bad.as_slice()) {
                Err(ModelIoError::Corrupt(_)) | Err(ModelIoError::Truncated) => {}
                other => panic!("flip at {pos}: expected typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn v2_truncation_is_typed_too() {
        // the legacy path has no checksum but still reports Truncated
        let v2 = serialize_v2(&trained_machine());
        assert!(matches!(
            load_from(&mut &v2[..v2.len() - 10]),
            Err(ModelIoError::Truncated)
        ));
    }

    #[test]
    fn dense_model_matches_trainer_scores() {
        let tm = trained_machine();
        let dense = DenseModel::from_tm(&tm);
        let mut tr = Trainer::from_machine(tm, Backend::Naive);
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let bits: Vec<bool> = (0..10).map(|_| rng.bern(0.5)).collect();
            let mut lits = bits.clone();
            lits.extend(bits.iter().map(|b| !b));
            let bv = BitVec::from_bools(&lits);
            let want = tr.scores(&bv);
            let lits_f32: Vec<f32> =
                lits.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
            let got = dense.scores(&lits_f32);
            for (i, &w) in want.iter().enumerate() {
                assert_eq!(got[i], w as f32, "class {i}");
            }
        }
    }

    #[test]
    fn dense_shapes() {
        let tm = trained_machine();
        let d = DenseModel::from_tm(&tm);
        assert_eq!(d.clauses_total, 24);
        assert_eq!(d.include.len(), 20 * 24);
        assert_eq!(d.polarity.len(), 24 * 3);
        // each clause votes for exactly its own class
        for jt in 0..24 {
            let nz: Vec<usize> = (0..3)
                .filter(|&i| d.polarity[jt * 3 + i] != 0.0)
                .collect();
            assert_eq!(nz, vec![jt / 8]);
        }
    }
}
