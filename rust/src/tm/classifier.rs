//! Multi-class Tsetlin Machine: one clause bank per class, argmax vote
//! (eq. 3; with indexing, eq. 4).

use crate::tm::bank::ClauseBank;
use crate::tm::params::TMParams;
use crate::util::simd::SimdMode;

/// The machine state proper: parameters + per-class TA banks. Evaluation
/// strategy is deliberately *not* part of this struct — the paper's whole
/// point is that the same machine can be driven by different evaluators
/// (see [`crate::eval::Backend`]); [`crate::tm::trainer::Trainer`] binds
/// the two together.
#[derive(Clone, Debug)]
pub struct MultiClassTM {
    /// Shared hyperparameters (immutable after construction except [`set_simd`](Self::set_simd)).
    pub params: TMParams,
    banks: Vec<ClauseBank>,
}

impl MultiClassTM {
    /// Fresh machine: one clause bank per class, all TA states at −1.
    pub fn new(params: TMParams) -> Self {
        params.validate().expect("invalid TM parameters");
        let banks = (0..params.classes)
            .map(|_| {
                ClauseBank::new_with_opts(
                    params.clauses_per_class,
                    params.n_literals(),
                    params.ta_layout,
                    params.simd.resolve(),
                )
            })
            .collect();
        MultiClassTM { params, banks }
    }

    /// Switch the machine's SIMD lane selector (CLI `--simd` override
    /// after loading a model): updates `params.simd` and re-points every
    /// bank's feedback lane width. A pure dispatch change — no TA state
    /// moves, and engines built from this machine afterwards (via
    /// [`crate::engine::ModelSnapshot`] or the trainer) pick it up from
    /// `params`.
    pub fn set_simd(&mut self, simd: SimdMode) {
        self.params.simd = simd;
        for bank in &mut self.banks {
            bank.set_simd(simd.resolve());
        }
    }

    #[inline]
    /// The clause bank of `class`.
    pub fn bank(&self, class: usize) -> &ClauseBank {
        &self.banks[class]
    }

    #[inline]
    /// Mutable clause bank of `class`.
    pub fn bank_mut(&mut self, class: usize) -> &mut ClauseBank {
        &mut self.banks[class]
    }

    /// All class banks, in class order.
    pub fn banks(&self) -> &[ClauseBank] {
        &self.banks
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.params.classes
    }

    /// Mean clause length across all classes (paper §3 Remarks metric).
    pub fn mean_clause_length(&self) -> f64 {
        let per: Vec<f64> = self
            .banks
            .iter()
            .map(|b| b.mean_clause_length())
            .filter(|&l| l > 0.0)
            .collect();
        if per.is_empty() {
            0.0
        } else {
            per.iter().sum::<f64>() / per.len() as f64
        }
    }

    /// Total TA memory in bytes (the paper's footprint model: 1 byte/TA).
    pub fn ta_memory_bytes(&self) -> usize {
        self.params.classes * self.params.clauses_per_class * self.params.n_literals()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_shapes() {
        let tm = MultiClassTM::new(TMParams::new(10, 20, 784));
        assert_eq!(tm.classes(), 10);
        assert_eq!(tm.bank(0).clauses(), 20);
        assert_eq!(tm.bank(9).n_literals(), 1568);
        assert_eq!(tm.ta_memory_bytes(), 10 * 20 * 1568);
    }

    #[test]
    fn banks_follow_params_layout() {
        use crate::tm::bank::TaLayout;
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let tm = MultiClassTM::new(TMParams::new(2, 4, 8).with_ta_layout(layout));
            assert_eq!(tm.bank(0).layout(), layout);
            assert_eq!(tm.bank(1).layout(), layout);
        }
    }

    #[test]
    fn banks_follow_params_simd_and_set_simd_repoints() {
        use crate::util::simd::SimdLanes;
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 8).with_simd(SimdMode::Scalar));
        assert_eq!(tm.bank(0).simd(), SimdLanes::Scalar);
        tm.set_simd(SimdMode::Wide);
        assert_eq!(tm.params.simd, SimdMode::Wide);
        assert_eq!(tm.bank(0).simd(), SimdLanes::Wide);
        assert_eq!(tm.bank(1).simd(), SimdLanes::Wide);
    }

    #[test]
    #[should_panic(expected = "invalid TM parameters")]
    fn invalid_params_panic() {
        MultiClassTM::new(TMParams::new(1, 20, 784));
    }

    #[test]
    fn fresh_machine_has_zero_clause_length() {
        let tm = MultiClassTM::new(TMParams::new(2, 4, 8));
        assert_eq!(tm.mean_clause_length(), 0.0);
    }
}
