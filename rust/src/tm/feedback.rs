//! Type I / Type II feedback — the TM learning rules (§2 of the paper,
//! following the reference formulation of Granmo 2018).
//!
//! The learning hot path is **mask-driven**: for each updated clause,
//! the per-literal Bernoulli decisions are drawn once into packed
//! `u64` mask words by geometric skip sampling
//! ([`crate::util::rng::fill_bernoulli_words`] — an expected
//! `O(2o / s)` RNG draws instead of `O(2o)`), combined with the sample's
//! literal words and the clause's exclude mask, and applied through
//! [`ClauseBank::apply_masks`]. The bank's scalar and bit-sliced layouts
//! consume the *same* masks from the *same* RNG stream — the shared RNG
//! contract that makes the two layouts bit-identical (states **and**
//! [`FlipSink`] event stream; `rust/tests/feedback_equiv.rs` proves it).
//!
//! Every include/exclude *flip* is forwarded to the evaluator's
//! [`FlipSink`] in ascending-literal order — that is where the paper's
//! index maintenance happens, and it is the only difference between
//! training with and without indexing.

use std::sync::atomic::Ordering;

use crate::eval::traits::FlipSink;
use crate::obs::probes::{FEEDBACK_CLAUSE_UPDATES, FEEDBACK_FLIPS};
use crate::tm::bank::ClauseBank;
use crate::util::bitvec::words_for;
use crate::util::rng::{fill_bernoulli_words, fill_bernoulli_words_simd, prob_to_threshold, Rng};
use crate::util::simd::{self, SimdLanes};
use crate::util::BitVec;

/// Precomputed Bernoulli thresholds for the specificity `s`.
#[derive(Clone, Copy, Debug)]
pub struct FeedbackCtx {
    /// P = 1/s as a u32 threshold (forget/penalize draw). Also the
    /// failure rate of the memorize draw: the memorize mask is drawn as
    /// the *complement* of a 1/s mask, so both masks cost `O(2o / s)`
    /// skip-sampled draws.
    pub p_forget: u32,
    /// P = 1 - 1/s as a u32 threshold (memorize/reward rate;
    /// diagnostic — the hot path draws its complement, see `p_forget`).
    pub p_memorize: u32,
    /// Reinforce true-positive literals with probability 1.
    pub boost_true_positive: bool,
    /// Weighted TM (paper ref [8]): clause weights move with feedback.
    pub weighted: bool,
}

impl FeedbackCtx {
    /// Build the threshold set for specificity `s`.
    ///
    /// `s` is defined on `[1, ∞)`; values below 1 (or NaN) would invert
    /// the reward/penalty split into nonsense probabilities
    /// (`1/s > 1`, `1 - 1/s < 0`), so they clamp to the `s = 1`
    /// degenerate point: always forget, never memorize without boost.
    /// `TMParams::validate` rejects such configs up front — the clamp
    /// guards direct constructions.
    pub fn new(s: f64, boost_true_positive: bool, weighted: bool) -> Self {
        let s = if s >= 1.0 { s } else { 1.0 }; // also catches NaN
        FeedbackCtx {
            p_forget: prob_to_threshold(1.0 / s),
            p_memorize: prob_to_threshold(1.0 - 1.0 / s),
            boost_true_positive,
            weighted,
        }
    }
}

/// Reusable per-clause mask buffers (`ceil(2o / 64)` words each),
/// owned by the trainer / parallel worker and threaded through
/// [`update_clause_range`], so the feedback hot path allocates nothing.
pub struct FeedbackScratch {
    n_bits: usize,
    /// Bernoulli(1/s) forget mask.
    forget: Vec<u64>,
    /// Bernoulli(1/s) memorize-*failure* mask (complemented at use).
    mem_fail: Vec<u64>,
    /// Lanes bumped toward include this update.
    up: Vec<u64>,
    /// Lanes bumped toward exclude this update.
    down: Vec<u64>,
    /// Lane width for mask fills and combines (bit-exact either way).
    simd: SimdLanes,
}

impl FeedbackScratch {
    /// Scalar-lane scratch (the reference path); the trainers build
    /// theirs via [`FeedbackScratch::with_simd`] from `TMParams::simd`.
    pub fn new(n_literals: usize) -> Self {
        Self::with_simd(n_literals, SimdLanes::Scalar)
    }

    /// Scratch with an explicit lane width for the Bernoulli fills and
    /// mask combines. Both widths draw identical RNG streams and build
    /// identical masks — the width only changes how many words move per
    /// instruction.
    pub fn with_simd(n_literals: usize, simd: SimdLanes) -> Self {
        let words = words_for(n_literals);
        FeedbackScratch {
            n_bits: n_literals,
            forget: vec![0; words],
            mem_fail: vec![0; words],
            up: vec![0; words],
            down: vec![0; words],
            simd,
        }
    }
}

/// Clause-update probability against the voting margin `T` (§2
/// Learning), in the u32-threshold form the hot loop consumes.
///
/// * target class: push the score up — update prob `(T - score) / 2T`
/// * negative class: push the score down — update prob `(T + score) / 2T`
///
/// `score` may be a *stale* vote sum (the clause-sharded asynchronous
/// trainer in [`crate::parallel`] feeds tallies that lag by up to one
/// staleness window); the formula is unchanged, which is exactly the
/// relaxation of arXiv 2009.04861.
#[inline]
pub fn clause_update_threshold(t: i32, score: i32, is_target: bool) -> u32 {
    debug_assert!(t > 0);
    let clamped = score.clamp(-t, t);
    let p = if is_target {
        (t - clamped) as f64 / (2 * t) as f64
    } else {
        (t + clamped) as f64 / (2 * t) as f64
    };
    prob_to_threshold(p)
}

/// The per-clause feedback body shared by the sequential
/// [`crate::tm::trainer::Trainer`] and the clause-sharded parallel
/// workers ([`crate::parallel`]): sample every clause of `bank` against
/// the update threshold, then dispatch Type I (clause polarity agrees
/// with the update direction) or Type II feedback.
///
/// `bank` may be a full class bank or a contiguous shard of one
/// ([`ClauseBank::clone_range`]) — polarity is positional, so shards
/// must start at an even clause id. `outputs` holds the training-mode
/// clause outputs for exactly `bank`'s clauses, computed *before* any
/// feedback of this step. `scratch` is caller-owned (one per trainer /
/// worker) so the hot loop performs zero allocations. Returns the
/// number of clauses updated.
#[allow(clippy::too_many_arguments)]
pub fn update_clause_range(
    bank: &mut ClauseBank,
    sink: &mut dyn FlipSink,
    rng: &mut Rng,
    ctx: &FeedbackCtx,
    outputs: &BitVec,
    literals: &BitVec,
    p_update: u32,
    is_target: bool,
    scratch: &mut FeedbackScratch,
) -> u64 {
    debug_assert_eq!(outputs.len(), bank.clauses());
    let n = bank.clauses();
    let mut updates = 0;
    let mut counting = FlipCounter { inner: sink, flips: 0 };
    for j in 0..n {
        if !rng.bern_threshold(p_update) {
            continue;
        }
        updates += 1;
        let positive = ClauseBank::polarity(j) > 0;
        let clause_out = outputs.get(j);
        if positive == is_target {
            type_i_with_scratch(bank, &mut counting, rng, ctx, j, clause_out, literals, scratch);
        } else {
            type_ii_with_scratch(bank, &mut counting, ctx, j, clause_out, literals, scratch);
        }
    }
    // Process-tier probe flush: one relaxed fetch_add per clause-range
    // update (never per flip) — see `crate::obs::probes`.
    if updates > 0 {
        FEEDBACK_CLAUSE_UPDATES.fetch_add(updates, Ordering::Relaxed);
    }
    if counting.flips > 0 {
        FEEDBACK_FLIPS.fetch_add(counting.flips, Ordering::Relaxed);
    }
    updates
}

/// Counts include/exclude flips on their way to the real sink, so
/// [`update_clause_range`] can flush one aggregate into the
/// process-wide [`FEEDBACK_FLIPS`] counter instead of an atomic per
/// flip.
struct FlipCounter<'a> {
    inner: &'a mut dyn FlipSink,
    flips: u64,
}

impl FlipSink for FlipCounter<'_> {
    #[inline]
    fn on_include(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.flips += 1;
        self.inner.on_include(j, k, new_count, weight);
    }
    #[inline]
    fn on_exclude(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.flips += 1;
        self.inner.on_exclude(j, k, new_count, weight);
    }
    #[inline]
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.inner.on_weight(j, delta, nonempty);
    }
}

/// Type I feedback: combats false negatives — reinforces clauses toward
/// matching the current sample (frequent-pattern capture).
///
/// * clause output 1: true literals are memorized (state toward include,
///   prob 1 with boosting else 1 - 1/s); false literals are gently
///   forgotten (prob 1/s).
/// * clause output 0: every literal is gently forgotten (prob 1/s).
///
/// Convenience wrapper over [`type_i_with_scratch`] (allocates its own
/// mask buffers; the training loop reuses one scratch across clauses).
pub fn type_i(
    bank: &mut ClauseBank,
    sink: &mut dyn FlipSink,
    rng: &mut Rng,
    ctx: &FeedbackCtx,
    j: usize,
    clause_out: bool,
    literals: &BitVec,
) {
    let mut scratch = FeedbackScratch::new(bank.n_literals());
    type_i_with_scratch(bank, sink, rng, ctx, j, clause_out, literals, &mut scratch);
}

/// [`type_i`] with caller-owned mask buffers — the hot-path form.
///
/// RNG contract (identical for both TA layouts): one Bernoulli(1/s)
/// forget mask is always drawn; iff the clause fired and boosting is
/// off, one more Bernoulli(1/s) *memorize-failure* mask follows. Masks
/// are filled by [`fill_bernoulli_words`] — geometric skip sampling
/// (`O(2o / s)` expected draws) for sparse thresholds, exact
/// word-parallel expansion for dense ones — never one draw per literal.
#[allow(clippy::too_many_arguments)]
pub fn type_i_with_scratch(
    bank: &mut ClauseBank,
    sink: &mut dyn FlipSink,
    rng: &mut Rng,
    ctx: &FeedbackCtx,
    j: usize,
    clause_out: bool,
    literals: &BitVec,
    scratch: &mut FeedbackScratch,
) {
    debug_assert_eq!(literals.len(), bank.n_literals());
    debug_assert_eq!(scratch.n_bits, bank.n_literals());
    if clause_out && ctx.weighted {
        // Weighted TM, Type Ia: a clause that fires while its class is
        // reinforced earns vote weight (integer additive variant).
        bank.weight_up(j);
        sink.on_weight(j as u32, 1, bank.count(j) > 0);
    }
    let n = bank.n_literals();
    let lanes = scratch.simd;
    fill_bernoulli_words_simd(rng, ctx.p_forget, &mut scratch.forget, n, lanes);
    let lw = literals.words();
    if clause_out {
        if ctx.boost_true_positive {
            scratch.up.copy_from_slice(lw);
        } else {
            fill_bernoulli_words_simd(rng, ctx.p_forget, &mut scratch.mem_fail, n, lanes);
            if lanes == SimdLanes::Wide {
                simd::and_not_into(&mut scratch.up, lw, &scratch.mem_fail);
            } else {
                for (w, &l) in lw.iter().enumerate() {
                    scratch.up[w] = l & !scratch.mem_fail[w];
                }
            }
        }
        if lanes == SimdLanes::Wide {
            simd::not_and_into(&mut scratch.down, lw, &scratch.forget);
        } else {
            for (w, &l) in lw.iter().enumerate() {
                scratch.down[w] = !l & scratch.forget[w];
            }
        }
    } else {
        scratch.up.fill(0);
        scratch.down.copy_from_slice(&scratch.forget);
    }
    bank.apply_masks(j, &scratch.up, &scratch.down, sink);
}

/// Type II feedback: combats false positives — when a clause fires on a
/// sample of the wrong class, every currently-*excluded* false literal
/// is pushed one step toward inclusion, so the clause learns to be
/// falsified by such samples in the future. Deterministic (no s-draws).
///
/// Convenience wrapper over [`type_ii_with_scratch`].
pub fn type_ii(
    bank: &mut ClauseBank,
    sink: &mut dyn FlipSink,
    ctx: &FeedbackCtx,
    j: usize,
    clause_out: bool,
    literals: &BitVec,
) {
    let mut scratch = FeedbackScratch::new(bank.n_literals());
    type_ii_with_scratch(bank, sink, ctx, j, clause_out, literals, &mut scratch);
}

/// [`type_ii`] with caller-owned mask buffers: the bump-up mask is one
/// word-parallel combine, `exclude(j) & !literals` (the sliced layout's
/// exclude mask *is* its sign plane).
pub fn type_ii_with_scratch(
    bank: &mut ClauseBank,
    sink: &mut dyn FlipSink,
    ctx: &FeedbackCtx,
    j: usize,
    clause_out: bool,
    literals: &BitVec,
    scratch: &mut FeedbackScratch,
) {
    if !clause_out {
        return;
    }
    debug_assert_eq!(literals.len(), bank.n_literals());
    debug_assert_eq!(scratch.n_bits, bank.n_literals());
    // Weighted TM: a clause firing on the wrong class sheds vote weight
    // (floor 1) before learning to be falsified.
    if ctx.weighted {
        let before = bank.weight(j);
        let after = bank.weight_down(j);
        if after < before {
            sink.on_weight(j as u32, -1, bank.count(j) > 0);
        }
    }
    bank.fill_exclude_mask(j, &mut scratch.up);
    if scratch.simd == SimdLanes::Wide {
        simd::and_not_assign(&mut scratch.up, literals.words());
        scratch.down.fill(0);
    } else {
        for (w, &l) in literals.words().iter().enumerate() {
            scratch.up[w] &= !l;
            scratch.down[w] = 0;
        }
    }
    bank.apply_masks(j, &scratch.up, &scratch.down, sink);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::NoopSink;
    use crate::tm::bank::TaLayout;

    fn lits(bits: &[bool]) -> BitVec {
        BitVec::from_bools(bits)
    }

    fn plain_ctx() -> FeedbackCtx {
        FeedbackCtx::new(4.0, true, false)
    }

    #[test]
    fn type_ii_includes_falsifying_literals_only() {
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let mut bank = ClauseBank::new_with_layout(2, 4, layout);
            let mut sink = NoopSink;
            let x = lits(&[true, false, true, false]);
            type_ii(&mut bank, &mut sink, &plain_ctx(), 0, true, &x);
            // false literals 1 and 3, both excluded -> bumped to include
            assert!(bank.include(0, 1));
            assert!(bank.include(0, 3));
            assert!(!bank.include(0, 0));
            assert!(!bank.include(0, 2));
        }
    }

    #[test]
    fn type_ii_noop_when_clause_output_zero() {
        let mut bank = ClauseBank::new(2, 4);
        let mut sink = NoopSink;
        let x = lits(&[false, false, false, false]);
        type_ii(&mut bank, &mut sink, &plain_ctx(), 0, false, &x);
        assert_eq!(bank.count(0), 0);
    }

    #[test]
    fn type_ii_skips_already_included() {
        let mut bank = ClauseBank::new(2, 4);
        bank.set_state(0, 1, 3); // already included, state 3
        let mut sink = NoopSink;
        let x = lits(&[true, false, true, true]);
        type_ii(&mut bank, &mut sink, &plain_ctx(), 0, true, &x);
        assert_eq!(bank.state(0, 1), 3); // untouched
    }

    #[test]
    fn type_i_with_boost_memorizes_true_literals_deterministically() {
        for layout in [TaLayout::Scalar, TaLayout::Sliced] {
            let mut bank = ClauseBank::new_with_layout(2, 4, layout);
            let mut sink = NoopSink;
            let ctx = FeedbackCtx::new(1e12, true, false); // p_forget ~ 0
            let mut rng = Rng::new(1);
            let x = lits(&[true, true, false, false]);
            type_i(&mut bank, &mut sink, &mut rng, &ctx, 0, true, &x);
            assert!(bank.include(0, 0));
            assert!(bank.include(0, 1));
            assert!(!bank.include(0, 2));
            assert!(!bank.include(0, 3));
        }
    }

    #[test]
    fn type_i_clause_zero_forgets_at_rate_one_over_s() {
        // s = 1 -> p_forget = 1: every literal decremented.
        let mut bank = ClauseBank::new(2, 4);
        bank.set_state(0, 0, 0); // included at the boundary
        let mut sink = NoopSink;
        let ctx = FeedbackCtx::new(1.0, true, false);
        let mut rng = Rng::new(2);
        let x = lits(&[true, true, true, true]);
        type_i(&mut bank, &mut sink, &mut rng, &ctx, 0, false, &x);
        assert!(!bank.include(0, 0)); // 0 -> -1: flip to exclude
        assert_eq!(bank.state(0, 1), -2);
    }

    #[test]
    fn type_i_statistical_forget_rate() {
        // With clause_out=0 and s=4, each literal decrements w.p. 1/4.
        let s = 4.0;
        let trials = 20_000usize;
        let mut bank = ClauseBank::new(2, trials);
        let mut sink = NoopSink;
        let ctx = FeedbackCtx::new(s, true, false);
        let mut rng = Rng::new(3);
        let x = BitVec::ones(trials);
        type_i(&mut bank, &mut sink, &mut rng, &ctx, 0, false, &x);
        let dec = (0..trials).filter(|&k| bank.state(0, k) == -2).count();
        let rate = dec as f64 / trials as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn type_i_statistical_memorize_rate_without_boost() {
        // clause_out=1, boost off, s=4: true literals increment w.p.
        // 3/4 (drawn as the complement of a 1/4 failure mask).
        let trials = 20_000usize;
        let mut bank = ClauseBank::new(2, trials);
        let mut sink = NoopSink;
        let ctx = FeedbackCtx::new(4.0, false, false);
        let mut rng = Rng::new(4);
        let x = BitVec::ones(trials);
        type_i(&mut bank, &mut sink, &mut rng, &ctx, 0, true, &x);
        let inc = (0..trials).filter(|&k| bank.state(0, k) == 0).count();
        let rate = inc as f64 / trials as f64;
        assert!((rate - 0.75).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn degenerate_s_clamps_to_one() {
        // s <= 1 (or NaN) clamps to the s = 1 point instead of
        // producing inverted probabilities.
        let want = FeedbackCtx::new(1.0, true, false);
        for bad in [0.25, 0.0, -3.0, f64::NAN] {
            let got = FeedbackCtx::new(bad, true, false);
            assert_eq!(got.p_forget, want.p_forget, "s={bad}");
            assert_eq!(got.p_memorize, want.p_memorize, "s={bad}");
        }
        assert_eq!(want.p_forget, u32::MAX); // always forget
        assert_eq!(want.p_memorize, 0); // never memorize (sans boost)
        // and a huge s approaches the opposite edge
        let wide = FeedbackCtx::new(f64::INFINITY, true, false);
        assert_eq!(wide.p_forget, 0);
        assert_eq!(wide.p_memorize, u32::MAX);
    }

    /// Flip events reaching the sink must mirror bank transitions.
    struct CountingSink {
        inc: Vec<(u32, u32)>,
        exc: Vec<(u32, u32)>,
    }
    impl FlipSink for CountingSink {
        fn on_include(&mut self, j: u32, k: u32, _c: u32, _w: u32) {
            self.inc.push((j, k));
        }
        fn on_exclude(&mut self, j: u32, k: u32, _c: u32, _w: u32) {
            self.exc.push((j, k));
        }
    }

    #[test]
    fn update_threshold_edges_and_clamping() {
        let t = 10;
        // target at -T: certain update; at +T: never
        assert_eq!(clause_update_threshold(t, -10, true), u32::MAX);
        assert_eq!(clause_update_threshold(t, 10, true), 0);
        // negative class mirrors
        assert_eq!(clause_update_threshold(t, 10, false), u32::MAX);
        assert_eq!(clause_update_threshold(t, -10, false), 0);
        // stale sums beyond the margin clamp instead of overflowing
        assert_eq!(clause_update_threshold(t, -1000, true), u32::MAX);
        assert_eq!(clause_update_threshold(t, 1000, true), 0);
        // score 0: p = 1/2 either way
        let half = clause_update_threshold(t, 0, true);
        assert_eq!(half, clause_update_threshold(t, 0, false));
        assert!((half as f64 / 2f64.powi(32) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn update_clause_range_updates_every_clause_at_p_one() {
        let mut bank = ClauseBank::new(4, 4);
        let mut sink = NoopSink;
        let ctx = plain_ctx();
        let mut rng = Rng::new(7);
        let x = lits(&[true, false, true, false]);
        let mut outputs = BitVec::zeros(4);
        outputs.set_all();
        let mut scratch = FeedbackScratch::new(bank.n_literals());
        let n = update_clause_range(
            &mut bank, &mut sink, &mut rng, &ctx, &outputs, &x, u32::MAX, true, &mut scratch,
        );
        assert_eq!(n, 4);
        // Type II hit the negative-polarity clauses (ids 1, 3): false
        // literals 1 and 3 pushed to include
        assert!(bank.include(1, 1) && bank.include(1, 3));
        assert!(bank.include(3, 1) && bank.include(3, 3));
        // and p_update = 0 touches nothing
        let n = update_clause_range(
            &mut bank, &mut sink, &mut rng, &ctx, &outputs, &x, 0, true, &mut scratch,
        );
        assert_eq!(n, 0);
    }

    #[test]
    fn flips_are_forwarded_to_sink() {
        let mut bank = ClauseBank::new(2, 3);
        let mut sink = CountingSink { inc: vec![], exc: vec![] };
        let x = lits(&[false, false, false]);
        type_ii(&mut bank, &mut sink, &plain_ctx(), 1, true, &x);
        assert_eq!(sink.inc, vec![(1, 0), (1, 1), (1, 2)]);
        assert!(sink.exc.is_empty());
        // repeated type_ii: states move deeper into include, no new flips
        type_ii(&mut bank, &mut sink, &plain_ctx(), 1, true, &x);
        assert_eq!(sink.inc.len(), 3);
    }
}
