//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320): the checksum behind the
//! model-file footer (format v3) and the registry's file digests.
//!
//! Table-driven, built at compile time — the offline environment has no
//! crc crates, and the footer check runs once per model load, far off
//! the inference hot path.

/// 256-entry lookup table for the reflected IEEE polynomial.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Streaming CRC-32 accumulator (feed chunks, then [`Crc32::finish`]).
#[derive(Clone, Copy, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh CRC-32 (IEEE) state.
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// Finalize and return the checksum.
    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vectors() {
        // the canonical CRC-32/IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_changes_digest() {
        let mut data = vec![0u8; 512];
        let base = crc32(&data);
        for pos in [0usize, 100, 511] {
            data[pos] ^= 0x10;
            assert_ne!(crc32(&data), base, "flip at {pos} undetected");
            data[pos] ^= 0x10;
        }
    }
}
