//! Minimal timing helpers for the bench harness.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop around the measured region,
/// repeatedly; read the total.
#[derive(Debug)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    /// Stopped stopwatch with zero accumulated time.
    pub fn new() -> Self {
        Stopwatch {
            total: Duration::ZERO,
            started: None,
        }
    }

    #[inline]
    /// Begin (or resume) timing.
    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    #[inline]
    /// Pause timing, accumulating the elapsed span.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Total accumulated time (including a running span).
    pub fn elapsed(&self) -> Duration {
        match self.started {
            Some(t0) => self.total + t0.elapsed(),
            None => self.total,
        }
    }

    /// Total accumulated time in seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Zero the accumulated time and stop.
    pub fn reset(&mut self) {
        self.total = Duration::ZERO;
        self.started = None;
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_start_stop() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let t1 = sw.elapsed();
        assert!(t1 >= Duration::from_millis(4));
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.elapsed() > t1);
    }

    #[test]
    fn reset_zeroes() {
        let mut sw = Stopwatch::new();
        sw.start();
        sw.stop();
        sw.reset();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
