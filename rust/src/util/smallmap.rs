//! Compact open-addressing hash map `u64 -> u32`.
//!
//! Used by the sparse position store (`index::position`) when a dense
//! `clauses x literals` matrix would blow the memory budget (e.g. IMDb
//! with 20k clauses x 40k literals = 3.2 GB dense). Keys are packed
//! `(clause << 32) | literal` pairs. Linear probing, power-of-two
//! capacity, tombstone-free deletion via backward-shift.

const EMPTY: u64 = u64::MAX;

/// Open-addressing `u64 -> u32` map. `u64::MAX` is reserved (never a
/// valid key: clause and literal ids are both `< u32::MAX`).
#[derive(Clone, Debug)]
pub struct U64Map {
    keys: Vec<u64>,
    vals: Vec<u32>,
    len: usize,
    mask: usize,
}

#[inline]
fn hash(key: u64) -> u64 {
    // splitmix64 finalizer — strong enough for packed-id keys.
    let mut z = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl U64Map {
    /// Empty map.
    pub fn new() -> Self {
        Self::with_capacity(16)
    }

    /// Empty map with room for `cap` entries before rehashing.
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.next_power_of_two().max(8);
        U64Map {
            keys: vec![EMPTY; cap],
            vals: vec![0; cap],
            len: 0,
            mask: cap - 1,
        }
    }

    #[inline]
    /// Number of entries.
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    /// True if the map holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert or overwrite `key`.
    pub fn insert(&mut self, key: u64, val: u32) {
        debug_assert_ne!(key, EMPTY);
        if (self.len + 1) * 4 >= self.keys.len() * 3 {
            self.grow();
        }
        let mut i = (hash(key) as usize) & self.mask;
        loop {
            if self.keys[i] == EMPTY {
                self.keys[i] = key;
                self.vals[i] = val;
                self.len += 1;
                return;
            }
            if self.keys[i] == key {
                self.vals[i] = val;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    #[inline]
    /// Look up `key`.
    pub fn get(&self, key: u64) -> Option<u32> {
        let mut i = (hash(key) as usize) & self.mask;
        loop {
            if self.keys[i] == EMPTY {
                return None;
            }
            if self.keys[i] == key {
                return Some(self.vals[i]);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Remove `key`, returning its value. Backward-shift deletion keeps
    /// probe chains intact without tombstones.
    pub fn remove(&mut self, key: u64) -> Option<u32> {
        let mut i = (hash(key) as usize) & self.mask;
        loop {
            if self.keys[i] == EMPTY {
                return None;
            }
            if self.keys[i] == key {
                let val = self.vals[i];
                self.len -= 1;
                // backward-shift: close the hole
                let mut hole = i;
                let mut j = (i + 1) & self.mask;
                while self.keys[j] != EMPTY {
                    let home = (hash(self.keys[j]) as usize) & self.mask;
                    // can keys[j] legally move into `hole`?
                    let dist_home_to_hole = hole.wrapping_sub(home) & self.mask;
                    let dist_home_to_j = j.wrapping_sub(home) & self.mask;
                    if dist_home_to_hole <= dist_home_to_j {
                        self.keys[hole] = self.keys[j];
                        self.vals[hole] = self.vals[j];
                        hole = j;
                    }
                    j = (j + 1) & self.mask;
                }
                self.keys[hole] = EMPTY;
                return Some(val);
            }
            i = (i + 1) & self.mask;
        }
    }

    fn grow(&mut self) {
        let new_cap = self.keys.len() * 2;
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY; new_cap]);
        let old_vals = std::mem::take(&mut self.vals);
        self.vals = vec![0; new_cap];
        self.mask = new_cap - 1;
        self.len = 0;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k != EMPTY {
                self.insert(k, v);
            }
        }
    }
}

impl Default for U64Map {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove() {
        let mut m = U64Map::new();
        m.insert(1, 10);
        m.insert(2, 20);
        assert_eq!(m.get(1), Some(10));
        assert_eq!(m.get(2), Some(20));
        assert_eq!(m.get(3), None);
        assert_eq!(m.remove(1), Some(10));
        assert_eq!(m.get(1), None);
        assert_eq!(m.get(2), Some(20));
        assert_eq!(m.remove(1), None);
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn overwrite_keeps_len() {
        let mut m = U64Map::new();
        m.insert(5, 1);
        m.insert(5, 2);
        assert_eq!(m.len(), 1);
        assert_eq!(m.get(5), Some(2));
    }

    #[test]
    fn grows_past_initial_capacity() {
        let mut m = U64Map::with_capacity(8);
        for i in 0..1000u64 {
            m.insert(i, (i * 3) as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m.get(i), Some((i * 3) as u32), "key {i}");
        }
    }

    #[test]
    fn fuzz_against_std_hashmap() {
        let mut rng = Rng::new(42);
        let mut ours = U64Map::new();
        let mut theirs: HashMap<u64, u32> = HashMap::new();
        for _ in 0..20_000 {
            let key = rng.below(500) as u64 | ((rng.below(50) as u64) << 32);
            match rng.below(3) {
                0 => {
                    let v = rng.next_u32();
                    ours.insert(key, v);
                    theirs.insert(key, v);
                }
                1 => {
                    assert_eq!(ours.remove(key), theirs.remove(&key), "remove {key}");
                }
                _ => {
                    assert_eq!(ours.get(key), theirs.get(&key).copied(), "get {key}");
                }
            }
            assert_eq!(ours.len(), theirs.len());
        }
        for (&k, &v) in &theirs {
            assert_eq!(ours.get(k), Some(v));
        }
    }
}
