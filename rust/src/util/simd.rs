//! Explicit 4-wide `u64` lane primitives for the bit-plane hot paths.
//!
//! The fused falsification walk ([`crate::engine::FusedIndex`]), the
//! sparse-delta toggle loops ([`crate::engine::SparseFusedIndex`]), the
//! bit-sliced TA feedback planes ([`crate::tm::bank::ClauseBank`]) and
//! the Bernoulli mask fills ([`crate::util::rng`]) all reduce to bulk
//! boolean algebra over `u64` words. This module gives those loops an
//! explicit SIMD shape:
//!
//! * [`W4`] — a portable `[u64; 4]` lane pack (one AVX2 register wide)
//!   with the boolean ops the kernels need. On its own it compiles to
//!   whatever the target baseline allows; the dispatched kernels below
//!   recompile the same code under `#[target_feature]` so LLVM emits
//!   real 256-bit ops.
//! * Dispatched kernels — [`or_accumulate`], [`popcount_words`],
//!   [`parity_vote_in_range`], [`and_not_into`], [`not_and_into`],
//!   [`and_not_assign`], [`saturating_step_group`] — each checks the
//!   cached CPU feature level ([`accel`], via
//!   `is_x86_feature_detected!`) once per call and routes to an
//!   AVX2/POPCNT specialization or the portable body. Every
//!   specialization is the *same* kernel recompiled, so results are
//!   bit-identical by construction on every path.
//! * [`SimdMode`] / [`SimdLanes`] — the user-facing selector
//!   (`--simd auto|wide|scalar`, `TMParams::simd`) and its resolved
//!   form. `scalar` forces the pre-SIMD word-at-a-time loops
//!   everywhere; `wide` forces the 4-lane paths (including the fused
//!   index's literal→clause bitmap plane); `auto` picks wide wherever
//!   the memory trade-off is safe.
//!
//! **Bit-exactness contract:** every wide path in the crate must
//! produce identical observable state to its scalar twin — TA states,
//! include counts, [`crate::eval::traits::FlipSink`] event streams,
//! scores, and RNG stream positions. `rust/tests/simd_equiv.rs` proves
//! it differentially; the unit tests here pin the lane primitives in
//! isolation.

use crate::util::bitvec::word_mask;

/// User-facing SIMD lane selector (`--simd`, `TMParams::simd`).
///
/// A *representation/dispatch* choice, not a learning hyper-parameter:
/// all three settings produce bit-identical machines, scores, flip
/// streams and RNG positions. Only throughput (and, for the fused
/// bitmap plane, memory) changes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdMode {
    /// Use the 4-wide lane paths wherever their memory cost is safe:
    /// the fused index builds its literal→clause bitmap plane only
    /// under [`crate::engine::fused::AUTO_PLANE_WORD_CAP`]; every other
    /// wide path has no memory cost and is always on.
    #[default]
    Auto,
    /// Force every 4-wide lane path, including the fused bitmap plane
    /// regardless of size.
    Wide,
    /// Force the scalar word-at-a-time reference loops everywhere.
    Scalar,
}

impl SimdMode {
    /// Canonical CLI/JSON name.
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Wide => "wide",
            SimdMode::Scalar => "scalar",
        }
    }

    /// Resolve to the lane width the kernels dispatch on. `Auto`
    /// resolves wide — the portable [`W4`] path is available on every
    /// arch, so the only auto/wide difference is the fused bitmap
    /// plane's memory gate (which needs the unresolved mode and is
    /// handled at index build time).
    #[inline]
    pub fn resolve(self) -> SimdLanes {
        match self {
            SimdMode::Scalar => SimdLanes::Scalar,
            SimdMode::Auto | SimdMode::Wide => SimdLanes::Wide,
        }
    }
}

impl std::str::FromStr for SimdMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(SimdMode::Auto),
            "wide" => Ok(SimdMode::Wide),
            "scalar" => Ok(SimdMode::Scalar),
            other => Err(format!("unknown simd mode '{other}' (auto|wide|scalar)")),
        }
    }
}

/// Resolved lane width ([`SimdMode::resolve`]): what the hot loops
/// actually branch on.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SimdLanes {
    /// Scalar word-at-a-time reference loops.
    Scalar,
    /// 4-wide `u64` lane kernels (portable, with x86_64 AVX2/POPCNT
    /// specializations behind runtime detection).
    #[default]
    Wide,
}

/// Runtime-detected x86_64 acceleration level for the dispatched
/// kernels (cached after the first query; always [`X86Accel::Portable`]
/// off x86_64).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum X86Accel {
    /// No specialization: the portable kernel bodies run as compiled
    /// for the target baseline.
    Portable,
    /// POPCNT available: population-count kernels recompiled with
    /// hardware popcount.
    Popcnt,
    /// AVX2 (implies POPCNT on every shipping CPU we detect): boolean
    /// bulk kernels recompiled to 256-bit ops.
    Avx2,
}

impl X86Accel {
    /// Diagnostic name (`stats`/bench reports).
    pub fn name(self) -> &'static str {
        match self {
            X86Accel::Portable => "portable",
            X86Accel::Popcnt => "popcnt",
            X86Accel::Avx2 => "avx2",
        }
    }
}

/// Cached CPU feature detection: 0 = unknown, 1 = portable, 2 = popcnt,
/// 3 = avx2.
static ACCEL: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// The detected acceleration level (cached; the first call runs
/// `is_x86_feature_detected!`, later calls are one relaxed load).
#[inline]
pub fn accel() -> X86Accel {
    use std::sync::atomic::Ordering;
    match ACCEL.load(Ordering::Relaxed) {
        1 => X86Accel::Portable,
        2 => X86Accel::Popcnt,
        3 => X86Accel::Avx2,
        _ => {
            let detected = detect();
            let code = match detected {
                X86Accel::Portable => 1,
                X86Accel::Popcnt => 2,
                X86Accel::Avx2 => 3,
            };
            ACCEL.store(code, Ordering::Relaxed);
            detected
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> X86Accel {
    if std::arch::is_x86_feature_detected!("avx2") {
        X86Accel::Avx2
    } else if std::arch::is_x86_feature_detected!("popcnt") {
        X86Accel::Popcnt
    } else {
        X86Accel::Portable
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> X86Accel {
    X86Accel::Portable
}

/// A portable pack of 4 `u64` lanes — one AVX2 register wide. The
/// boolean methods are plain lane-wise ops; under a `#[target_feature]`
/// specialization LLVM lowers them to single 256-bit instructions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct W4(pub [u64; 4]);

impl W4 {
    /// All lanes zero.
    #[inline(always)]
    pub fn zero() -> W4 {
        W4([0; 4])
    }

    /// Load 4 consecutive words from `src` starting at `at`.
    #[inline(always)]
    pub fn load(src: &[u64], at: usize) -> W4 {
        W4([src[at], src[at + 1], src[at + 2], src[at + 3]])
    }

    /// Store the lanes to 4 consecutive words of `dst` starting at `at`.
    #[inline(always)]
    pub fn store(self, dst: &mut [u64], at: usize) {
        dst[at..at + 4].copy_from_slice(&self.0);
    }

    /// Lane-wise AND.
    #[inline(always)]
    pub fn and(self, o: W4) -> W4 {
        W4(std::array::from_fn(|i| self.0[i] & o.0[i]))
    }

    /// Lane-wise OR.
    #[inline(always)]
    pub fn or(self, o: W4) -> W4 {
        W4(std::array::from_fn(|i| self.0[i] | o.0[i]))
    }

    /// Lane-wise XOR.
    #[inline(always)]
    pub fn xor(self, o: W4) -> W4 {
        W4(std::array::from_fn(|i| self.0[i] ^ o.0[i]))
    }

    /// Lane-wise NOT.
    #[inline(always)]
    pub fn not(self) -> W4 {
        W4(std::array::from_fn(|i| !self.0[i]))
    }

    /// Lane-wise `self & !o` (mask clear).
    #[inline(always)]
    pub fn and_not(self, o: W4) -> W4 {
        W4(std::array::from_fn(|i| self.0[i] & !o.0[i]))
    }

    /// Sum of `count_ones` over the 4 lanes.
    #[inline(always)]
    pub fn popcount(self) -> u32 {
        self.0.iter().map(|w| w.count_ones()).sum()
    }
}

// ---------------------------------------------------------------------------
// Dispatched bulk kernels. Each has one portable `*_kernel` body; the
// x86_64 wrappers recompile that exact body under `#[target_feature]`
// so the results are bit-identical on every dispatch path.
// ---------------------------------------------------------------------------

#[inline(always)]
fn or_accumulate_kernel(acc: &mut [u64], src: &[u64]) {
    let n = acc.len().min(src.len());
    let quads = n / 4;
    for q in 0..quads {
        let at = q * 4;
        W4::load(acc, at).or(W4::load(src, at)).store(acc, at);
    }
    for i in quads * 4..n {
        acc[i] |= src[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn or_accumulate_avx2(acc: &mut [u64], src: &[u64]) {
    or_accumulate_kernel(acc, src);
}

/// `acc[i] |= src[i]` over `min(len)` words — the falsified-bitmap
/// accumulation of the fused wide walk (one OR per 64 clauses per
/// false literal).
#[inline]
pub fn or_accumulate(acc: &mut [u64], src: &[u64]) {
    #[cfg(target_arch = "x86_64")]
    if accel() == X86Accel::Avx2 {
        // SAFETY: AVX2 presence checked at runtime by `accel()`.
        return unsafe { or_accumulate_avx2(acc, src) };
    }
    or_accumulate_kernel(acc, src);
}

#[inline(always)]
fn popcount_words_kernel(words: &[u64]) -> u64 {
    let mut total = 0u64;
    let quads = words.len() / 4;
    for q in 0..quads {
        total += W4::load(words, q * 4).popcount() as u64;
    }
    for &w in &words[quads * 4..] {
        total += w.count_ones() as u64;
    }
    total
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn popcount_words_popcnt(words: &[u64]) -> u64 {
    popcount_words_kernel(words)
}

/// Total set bits over a word slice (hardware POPCNT when detected —
/// the x86-64 baseline compiles `count_ones` to a software fallback).
#[inline]
pub fn popcount_words(words: &[u64]) -> u64 {
    #[cfg(target_arch = "x86_64")]
    if accel() != X86Accel::Portable {
        // SAFETY: POPCNT presence checked at runtime by `accel()`
        // (Avx2 implies popcnt in `detect()`'s ordering).
        return unsafe { popcount_words_popcnt(words) };
    }
    popcount_words_kernel(words)
}

/// Mask selecting even bit positions of a word — with interleaved
/// clause polarity (even global id = vote `+1`), the even lanes of a
/// falsified-clause bitmap word are exactly its positive-polarity
/// clauses.
pub const EVEN_LANES: u64 = 0x5555_5555_5555_5555;

#[inline(always)]
fn parity_vote_kernel(words: &[u64], lo: usize, hi: usize) -> i32 {
    // Σ over set bits b in [lo, hi): +1 if b even, -1 if odd
    //   = 2 * popcount(even bits) - popcount(all bits)
    if lo >= hi {
        return 0;
    }
    let first = lo / 64;
    let last = (hi - 1) / 64;
    let mut even = 0i64;
    let mut total = 0i64;
    for (wi, &raw) in words.iter().enumerate().take(last + 1).skip(first) {
        let mut w = raw;
        if wi == first {
            w &= !0u64 << (lo % 64);
        }
        if wi == last {
            w &= word_mask(hi, wi);
        }
        even += (w & EVEN_LANES).count_ones() as i64;
        total += w.count_ones() as i64;
    }
    (2 * even - total) as i32
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "popcnt")]
unsafe fn parity_vote_popcnt(words: &[u64], lo: usize, hi: usize) -> i32 {
    parity_vote_kernel(words, lo, hi)
}

/// Signed polarity-vote sum over bit range `[lo, hi)` of a
/// falsified-clause bitmap: `+1` per set even bit, `-1` per set odd
/// bit. With interleaved polarity and uniform (weight-1) votes this is
/// exactly the vote mass a class loses to falsification — the masked
/// popcount accumulation of the fused wide walk.
#[inline]
pub fn parity_vote_in_range(words: &[u64], lo: usize, hi: usize) -> i32 {
    #[cfg(target_arch = "x86_64")]
    if accel() != X86Accel::Portable {
        // SAFETY: POPCNT presence checked at runtime by `accel()`.
        return unsafe { parity_vote_popcnt(words, lo, hi) };
    }
    parity_vote_kernel(words, lo, hi)
}

#[inline(always)]
fn and_not_into_kernel(dst: &mut [u64], a: &[u64], b: &[u64]) {
    let n = dst.len().min(a.len()).min(b.len());
    let quads = n / 4;
    for q in 0..quads {
        let at = q * 4;
        W4::load(a, at).and_not(W4::load(b, at)).store(dst, at);
    }
    for i in quads * 4..n {
        dst[i] = a[i] & !b[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn and_not_into_avx2(dst: &mut [u64], a: &[u64], b: &[u64]) {
    and_not_into_kernel(dst, a, b);
}

/// `dst[i] = a[i] & !b[i]` — the Type I memorize combine
/// (`up = literals & !mem_fail`).
#[inline]
pub fn and_not_into(dst: &mut [u64], a: &[u64], b: &[u64]) {
    #[cfg(target_arch = "x86_64")]
    if accel() == X86Accel::Avx2 {
        // SAFETY: AVX2 presence checked at runtime by `accel()`.
        return unsafe { and_not_into_avx2(dst, a, b) };
    }
    and_not_into_kernel(dst, a, b);
}

#[inline(always)]
fn not_and_into_kernel(dst: &mut [u64], a: &[u64], b: &[u64]) {
    let n = dst.len().min(a.len()).min(b.len());
    let quads = n / 4;
    for q in 0..quads {
        let at = q * 4;
        W4::load(b, at).and_not(W4::load(a, at)).store(dst, at);
    }
    for i in quads * 4..n {
        dst[i] = !a[i] & b[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn not_and_into_avx2(dst: &mut [u64], a: &[u64], b: &[u64]) {
    not_and_into_kernel(dst, a, b);
}

/// `dst[i] = !a[i] & b[i]` — the Type I forget combine
/// (`down = !literals & forget`).
#[inline]
pub fn not_and_into(dst: &mut [u64], a: &[u64], b: &[u64]) {
    #[cfg(target_arch = "x86_64")]
    if accel() == X86Accel::Avx2 {
        // SAFETY: AVX2 presence checked at runtime by `accel()`.
        return unsafe { not_and_into_avx2(dst, a, b) };
    }
    not_and_into_kernel(dst, a, b);
}

#[inline(always)]
fn and_not_assign_kernel(dst: &mut [u64], a: &[u64]) {
    let n = dst.len().min(a.len());
    let quads = n / 4;
    for q in 0..quads {
        let at = q * 4;
        W4::load(dst, at).and_not(W4::load(a, at)).store(dst, at);
    }
    for i in quads * 4..n {
        dst[i] &= !a[i];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn and_not_assign_avx2(dst: &mut [u64], a: &[u64]) {
    and_not_assign_kernel(dst, a);
}

/// `dst[i] &= !a[i]` — the Type II combine
/// (`up = exclude_mask & !literals`, built in place).
#[inline]
pub fn and_not_assign(dst: &mut [u64], a: &[u64]) {
    #[cfg(target_arch = "x86_64")]
    if accel() == X86Accel::Avx2 {
        // SAFETY: AVX2 presence checked at runtime by `accel()`.
        return unsafe { and_not_assign_avx2(dst, a) };
    }
    and_not_assign_kernel(dst, a);
}

/// Plane words per 64-literal clause-word in the bit-sliced TA layout
/// (8-bit two's-complement automata — must equal
/// `crate::tm::bank::PLANES`).
pub const GROUP_PLANES: usize = 8;
/// Clause-words processed per [`saturating_step_group`] call.
pub const GROUP_LANES: usize = 4;
/// Plane words consumed by one [`saturating_step_group`] call
/// (`GROUP_LANES * GROUP_PLANES`).
pub const GROUP_WORDS: usize = GROUP_LANES * GROUP_PLANES;

#[inline(always)]
fn saturating_step_group_kernel(
    pl: &mut [u64],
    up: &[u64; GROUP_LANES],
    down: &[u64; GROUP_LANES],
) -> ([u64; GROUP_LANES], [u64; GROUP_LANES]) {
    debug_assert!(pl.len() >= GROUP_WORDS);
    // Transpose-load: plane p of lane (clause-word) i lives at
    // pl[i * GROUP_PLANES + p] — the bank's contiguous per-word layout.
    let mut planes: [W4; GROUP_PLANES] = std::array::from_fn(|p| {
        W4(std::array::from_fn(|i| pl[i * GROUP_PLANES + p]))
    });
    let sign = planes[GROUP_PLANES - 1];
    // saturation lanes: +127 = 0b0111_1111, -128 = 0b1000_0000
    let low_all = planes[0]
        .and(planes[1])
        .and(planes[2])
        .and(planes[3])
        .and(planes[4])
        .and(planes[5])
        .and(planes[6]);
    let low_none = planes[0]
        .or(planes[1])
        .or(planes[2])
        .or(planes[3])
        .or(planes[4])
        .or(planes[5])
        .or(planes[6])
        .not();
    let add = W4(*up).and_not(low_all.and_not(sign));
    let sub = W4(*down).and_not(low_none.and(sign));
    let sign_before = sign;
    // ripple-carry +1 on `add` lanes (no overflow: +127 excluded)
    let mut carry = add;
    for p in planes.iter_mut() {
        let orig = *p;
        *p = orig.xor(carry);
        carry = carry.and(orig);
    }
    // borrow-ripple −1 on `sub` lanes (no underflow: −128 excluded)
    let mut borrow = sub;
    for p in planes.iter_mut() {
        let orig = *p;
        *p = orig.xor(borrow);
        borrow = borrow.and(orig.not());
    }
    for (p, w4) in planes.iter().enumerate() {
        for (i, &w) in w4.0.iter().enumerate() {
            pl[i * GROUP_PLANES + p] = w;
        }
    }
    (sign_before.0, planes[GROUP_PLANES - 1].0)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn saturating_step_group_avx2(
    pl: &mut [u64],
    up: &[u64; GROUP_LANES],
    down: &[u64; GROUP_LANES],
) -> ([u64; GROUP_LANES], [u64; GROUP_LANES]) {
    saturating_step_group_kernel(pl, up, down)
}

/// Saturating ±1 over 4 bit-sliced clause-words at once — the 4-wide
/// form of the ripple-carry/borrow body of
/// [`crate::tm::bank::ClauseBank::apply_masks`].
///
/// `pl` holds the [`GROUP_WORDS`] contiguous plane words of 4
/// consecutive clause-words (the bank's `(j * words + w) * 8` layout);
/// `up`/`down` are the per-lane bump masks (already tail-masked and
/// disjoint). Lanes at `+127` ignore `up`; lanes at `−128` ignore
/// `down` — identical saturation algebra to the scalar word body.
/// Returns the per-lane `(sign_before, sign_after)` words; flips are
/// `sign_before ^ sign_after` with direction read from `sign_before`.
#[inline]
pub fn saturating_step_group(
    pl: &mut [u64],
    up: &[u64; GROUP_LANES],
    down: &[u64; GROUP_LANES],
) -> ([u64; GROUP_LANES], [u64; GROUP_LANES]) {
    #[cfg(target_arch = "x86_64")]
    if accel() == X86Accel::Avx2 {
        // SAFETY: AVX2 presence checked at runtime by `accel()`.
        return unsafe { saturating_step_group_avx2(pl, up, down) };
    }
    saturating_step_group_kernel(pl, up, down)
}

/// Portable (never-specialized) twins of the dispatched kernels, used
/// by the dispatch-fallback tests to prove specializations are
/// bit-identical to the portable bodies.
#[doc(hidden)]
pub mod portable {
    /// Portable [`super::or_accumulate`].
    pub fn or_accumulate(acc: &mut [u64], src: &[u64]) {
        super::or_accumulate_kernel(acc, src);
    }
    /// Portable [`super::popcount_words`].
    pub fn popcount_words(words: &[u64]) -> u64 {
        super::popcount_words_kernel(words)
    }
    /// Portable [`super::parity_vote_in_range`].
    pub fn parity_vote_in_range(words: &[u64], lo: usize, hi: usize) -> i32 {
        super::parity_vote_kernel(words, lo, hi)
    }
    /// Portable [`super::saturating_step_group`].
    pub fn saturating_step_group(
        pl: &mut [u64],
        up: &[u64; super::GROUP_LANES],
        down: &[u64; super::GROUP_LANES],
    ) -> ([u64; super::GROUP_LANES], [u64; super::GROUP_LANES]) {
        super::saturating_step_group_kernel(pl, up, down)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mode_parse_name_roundtrip_and_defaults() {
        for mode in [SimdMode::Auto, SimdMode::Wide, SimdMode::Scalar] {
            assert_eq!(mode.name().parse::<SimdMode>().unwrap(), mode);
        }
        assert!("avx512".parse::<SimdMode>().is_err());
        assert_eq!(SimdMode::default(), SimdMode::Auto);
        assert_eq!(SimdMode::Auto.resolve(), SimdLanes::Wide);
        assert_eq!(SimdMode::Wide.resolve(), SimdLanes::Wide);
        assert_eq!(SimdMode::Scalar.resolve(), SimdLanes::Scalar);
    }

    #[test]
    fn accel_detection_is_cached_and_stable() {
        let a = accel();
        let b = accel();
        assert_eq!(a, b);
        assert!(!a.name().is_empty());
    }

    #[test]
    fn w4_boolean_ops_match_scalar() {
        let mut rng = Rng::new(0x51);
        for _ in 0..200 {
            let a: [u64; 4] = std::array::from_fn(|_| rng.next_u64());
            let b: [u64; 4] = std::array::from_fn(|_| rng.next_u64());
            let (wa, wb) = (W4(a), W4(b));
            for i in 0..4 {
                assert_eq!(wa.and(wb).0[i], a[i] & b[i]);
                assert_eq!(wa.or(wb).0[i], a[i] | b[i]);
                assert_eq!(wa.xor(wb).0[i], a[i] ^ b[i]);
                assert_eq!(wa.not().0[i], !a[i]);
                assert_eq!(wa.and_not(wb).0[i], a[i] & !b[i]);
            }
            let want: u32 = a.iter().map(|w| w.count_ones()).sum();
            assert_eq!(wa.popcount(), want);
        }
    }

    #[test]
    fn bulk_combines_match_scalar_loops_at_odd_lengths() {
        let mut rng = Rng::new(0x52);
        for len in [0usize, 1, 3, 4, 5, 7, 8, 13, 64, 65] {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let mut dst = vec![0u64; len];
            and_not_into(&mut dst, &a, &b);
            assert!(dst.iter().zip(&a).zip(&b).all(|((&d, &x), &y)| d == x & !y));
            not_and_into(&mut dst, &a, &b);
            assert!(dst.iter().zip(&a).zip(&b).all(|((&d, &x), &y)| d == !x & y));
            let mut acc = b.clone();
            or_accumulate(&mut acc, &a);
            assert!(acc.iter().zip(&a).zip(&b).all(|((&d, &x), &y)| d == x | y));
            let mut dst = a.clone();
            and_not_assign(&mut dst, &b);
            assert!(dst.iter().zip(&a).zip(&b).all(|((&d, &x), &y)| d == x & !y));
        }
    }

    #[test]
    fn popcount_accumulation_matches_count_ones() {
        let mut rng = Rng::new(0x53);
        for len in [0usize, 1, 3, 4, 9, 31, 64, 100] {
            let words: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let want: u64 = words.iter().map(|w| w.count_ones() as u64).sum();
            assert_eq!(popcount_words(&words), want);
            assert_eq!(portable::popcount_words(&words), want);
        }
    }

    #[test]
    fn parity_vote_matches_per_bit_reference() {
        let mut rng = Rng::new(0x54);
        let words: Vec<u64> = (0..10).map(|_| rng.next_u64()).collect();
        let reference = |lo: usize, hi: usize| -> i32 {
            (lo..hi)
                .filter(|&b| (words[b / 64] >> (b % 64)) & 1 == 1)
                .map(|b| if b % 2 == 0 { 1 } else { -1 })
                .sum()
        };
        for &(lo, hi) in &[
            (0usize, 640usize),
            (0, 1),
            (0, 0),
            (63, 65),
            (100, 100),
            (7, 300),
            (128, 256),
            (599, 640),
            (64, 65),
        ] {
            assert_eq!(parity_vote_in_range(&words, lo, hi), reference(lo, hi), "[{lo},{hi})");
            assert_eq!(
                portable::parity_vote_in_range(&words, lo, hi),
                reference(lo, hi),
                "portable [{lo},{hi})"
            );
        }
    }

    /// Reference i8 semantics of one saturating step over 4 clause-words.
    fn reference_step(
        states: &mut [i8; 256],
        up: &[u64; 4],
        down: &[u64; 4],
    ) -> ([u64; 4], [u64; 4]) {
        let before: [u64; 4] = std::array::from_fn(|i| {
            (0..64).fold(0u64, |acc, b| acc | (((states[i * 64 + b] < 0) as u64) << b))
        });
        for i in 0..4 {
            for b in 0..64 {
                let s = &mut states[i * 64 + b];
                if (up[i] >> b) & 1 == 1 && *s != i8::MAX {
                    *s += 1;
                } else if (down[i] >> b) & 1 == 1 && *s != i8::MIN {
                    *s -= 1;
                }
            }
        }
        let after: [u64; 4] = std::array::from_fn(|i| {
            (0..64).fold(0u64, |acc, b| acc | (((states[i * 64 + b] < 0) as u64) << b))
        });
        (before, after)
    }

    fn pack_planes(states: &[i8; 256]) -> Vec<u64> {
        let mut pl = vec![0u64; GROUP_WORDS];
        for (k, &s) in states.iter().enumerate() {
            let (lane, bit) = (k / 64, k % 64);
            for p in 0..GROUP_PLANES {
                if ((s as u8) >> p) & 1 == 1 {
                    pl[lane * GROUP_PLANES + p] |= 1u64 << bit;
                }
            }
        }
        pl
    }

    fn unpack_planes(pl: &[u64]) -> [i8; 256] {
        let mut states = [0i8; 256];
        for (k, slot) in states.iter_mut().enumerate() {
            let (lane, bit) = (k / 64, k % 64);
            let mut byte = 0u8;
            for p in 0..GROUP_PLANES {
                byte |= (((pl[lane * GROUP_PLANES + p] >> bit) & 1) as u8) << p;
            }
            *slot = byte as i8;
        }
        states
    }

    #[test]
    fn saturating_step_group_matches_i8_reference_with_rails() {
        let mut rng = Rng::new(0x55);
        for trial in 0..200 {
            // seed states with both saturation rails well represented
            let mut ref_states = [0i8; 256];
            for s in ref_states.iter_mut() {
                *s = match rng.below(8) {
                    0 => i8::MAX,
                    1 => i8::MIN,
                    2 => -1,
                    3 => 0,
                    _ => (rng.below(41) as i8) - 20,
                };
            }
            let mut pl = pack_planes(&ref_states);
            let up: [u64; 4] = std::array::from_fn(|_| rng.next_u64());
            // disjoint by construction
            let down: [u64; 4] = std::array::from_fn(|i| rng.next_u64() & !up[i]);
            let (want_before, want_after) = reference_step(&mut ref_states, &up, &down);
            let (got_before, got_after) = saturating_step_group(&mut pl, &up, &down);
            assert_eq!(got_before, want_before, "trial {trial}: sign_before");
            assert_eq!(got_after, want_after, "trial {trial}: sign_after");
            assert_eq!(unpack_planes(&pl), ref_states, "trial {trial}: states");
        }
    }

    #[test]
    fn saturation_rails_are_pinned() {
        // every lane at +127 bumped up stays +127; at -128 bumped down
        // stays -128; and each rail still moves the *other* direction
        let mut states = [0i8; 256];
        states[0] = i8::MAX;
        states[1] = i8::MIN;
        let mut pl = pack_planes(&states);
        let up = [0b11u64, 0, 0, 0];
        let down = [0u64; 4];
        let (before, after) = saturating_step_group(&mut pl, &up, &down);
        let got = unpack_planes(&pl);
        assert_eq!(got[0], i8::MAX, "+127 must saturate");
        assert_eq!(got[1], i8::MIN + 1, "-128 must still increment");
        // lane 1 crossed no sign boundary; no flips on lane 0 either
        assert_eq!(before[0] ^ after[0], 0);
        let mut pl = pack_planes(&states);
        let down = [0b11u64, 0, 0, 0];
        let up = [0u64; 4];
        let (before, after) = saturating_step_group(&mut pl, &up, &down);
        let got = unpack_planes(&pl);
        assert_eq!(got[0], i8::MAX - 1, "+127 must still decrement");
        assert_eq!(got[1], i8::MIN, "-128 must saturate");
        assert_eq!(before[0] ^ after[0], 0, "no sign change: 127 -> 126");
    }

    #[test]
    fn dispatched_kernels_match_portable_twins() {
        // whatever accel() detected, the dispatched entry points must be
        // bit-identical to the never-specialized portable bodies — the
        // forced-scalar/dispatch-fallback guarantee
        let mut rng = Rng::new(0x56);
        for _ in 0..50 {
            let a: Vec<u64> = (0..37).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..37).map(|_| rng.next_u64()).collect();
            let mut x = a.clone();
            let mut y = a.clone();
            or_accumulate(&mut x, &b);
            portable::or_accumulate(&mut y, &b);
            assert_eq!(x, y);
            assert_eq!(popcount_words(&a), portable::popcount_words(&a));
            assert_eq!(
                parity_vote_in_range(&a, 5, 2000),
                portable::parity_vote_in_range(&a, 5, 2000)
            );
            let mut pl_a: Vec<u64> = (0..GROUP_WORDS).map(|_| rng.next_u64()).collect();
            let mut pl_b = pl_a.clone();
            let up: [u64; 4] = std::array::from_fn(|_| rng.next_u64());
            let down: [u64; 4] = std::array::from_fn(|i| rng.next_u64() & !up[i]);
            let ra = saturating_step_group(&mut pl_a, &up, &down);
            let rb = portable::saturating_step_group(&mut pl_b, &up, &down);
            assert_eq!(ra, rb);
            assert_eq!(pl_a, pl_b);
        }
    }
}
