//! Minimal JSON parser/emitter (the build environment is offline; no
//! serde). Covers the full JSON grammar minus exotic number forms —
//! sufficient for `artifacts/manifest.json`, model params blocks, and
//! the bench harness's machine-readable reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic emission.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (stored as `f64`).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object with sorted keys (deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document, reporting position on error.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// Borrow the string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value as `usize`, if integral and in range.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Borrow the elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Look up `key`, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    // -- builders ----------------------------------------------------------

    /// Build an object from key/value pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a number.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the parse failure.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs unsupported (not needed for
                            // our manifests); map lone surrogates to U+FFFD
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""line\nbreak \"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak \"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_through_display() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"nested":{"k":null},"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
        assert_eq!(emitted, src); // BTreeMap => canonical order
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("missing"), None);
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let man = r#"{
          "format": "hlo-text",
          "variants": [
            {"name": "tm_b32", "file": "tm_b32.hlo.txt", "batch": 32,
             "features": 784, "clauses": 1280, "classes": 10,
             "fused": true, "sha256": "abc"}
          ]
        }"#;
        let v = Json::parse(man).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let variants = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants[0].get("batch").unwrap().as_usize(), Some(32));
        assert_eq!(variants[0].get("fused").unwrap().as_bool(), Some(true));
    }
}
