//! Packed bit vectors.
//!
//! Two hot uses: (1) sample literal vectors — inference iterates the
//! *zero* bits (false literals, the paper's falsification walk) and the
//! bit-parallel baseline ANDs whole words; (2) per-clause output/alive
//! bitmaps during training.

/// Number of `u64` words covering `bits` bits.
#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(64)
}

/// Mask of the valid lanes of word `w` in a `bits`-bit vector: all-ones
/// for full words, the low tail for a final partial word, zero for
/// words past the end. Word-granular consumers (the bit-sliced TA bank,
/// feedback masks) use this to keep tail lanes inert.
#[inline]
pub fn word_mask(bits: usize, w: usize) -> u64 {
    let start = w * 64;
    if start + 64 <= bits {
        !0u64
    } else if start >= bits {
        0
    } else {
        (1u64 << (bits - start)) - 1
    }
}

/// Fixed-length packed bit vector over `u64` words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All-zero vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVec {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// All-one vector of `len` bits (trailing bits of the last word are 0).
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec {
            words: vec![!0u64; len.div_ceil(64)],
            len,
        };
        v.mask_tail();
        v
    }

    /// Build from a bool slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i);
            }
        }
        v
    }

    #[inline]
    fn mask_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    #[inline]
    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    /// True if the vector has zero bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    /// Read bit `i`.
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    /// Set bit `i` to 1.
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    /// Clear bit `i` to 0.
    pub fn clear(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    #[inline]
    /// Write bit `i`.
    pub fn assign(&mut self, i: usize, v: bool) {
        if v {
            self.set(i)
        } else {
            self.clear(i)
        }
    }

    /// Zero every bit without reallocating.
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Set every bit without reallocating (tail stays masked).
    pub fn set_all(&mut self) {
        self.words.fill(!0u64);
        self.mask_tail();
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Is this an exact `[x, ¬x]` literal vector — bit `o + k` the
    /// complement of bit `k` for every `k < o = len/2`? Word-parallel
    /// (O(len/64)), so the sparse inference path can *prove* the
    /// structure it relies on instead of assuming it; odd-length
    /// vectors are never complement-structured.
    pub fn halves_complement(&self) -> bool {
        if self.len % 2 != 0 {
            return false;
        }
        let o = self.len / 2;
        let base = o / 64;
        let shift = o % 64;
        for i in 0..o.div_ceil(64) {
            // bits [64i, 64i+64) of the upper (negated) half
            let lo = self.words[base + i] >> shift;
            let hi = if shift == 0 {
                0
            } else {
                self.words.get(base + i + 1).copied().unwrap_or(0) << (64 - shift)
            };
            let upper = lo | hi;
            let bits = (o - 64 * i).min(64);
            let mask = if bits == 64 { !0u64 } else { (1u64 << bits) - 1 };
            if (self.words[i] ^ upper) & mask != mask {
                return false;
            }
        }
        true
    }

    /// Count set bits among the first `n` bits (`n <= len`). Used by the
    /// sparse inference path to measure feature density from the
    /// positive half of a `[x, ¬x]` literal vector.
    pub fn count_ones_prefix(&self, n: usize) -> usize {
        debug_assert!(n <= self.len);
        let full = n / 64;
        let mut total: usize = self.words[..full]
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum();
        let tail = n % 64;
        if tail != 0 {
            total += (self.words[full] & ((1u64 << tail) - 1)).count_ones() as usize;
        }
        total
    }

    /// Raw words — the bit-parallel evaluator works directly on these.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Iterate indices of set bits (ascending).
    pub fn iter_ones(&self) -> OnesIter<'_> {
        OnesIter::new(&self.words, self.len, false)
    }

    /// Iterate indices of zero bits (ascending) — the falsification walk.
    pub fn iter_zeros(&self) -> OnesIter<'_> {
        OnesIter::new(&self.words, self.len, true)
    }
}

/// Iterator over set-bit indices; with `complement` it yields zero-bit
/// indices instead (tail padding past `len` is never yielded).
pub struct OnesIter<'a> {
    words: &'a [u64],
    len: usize,
    complement: bool,
    word_idx: usize,
    cur: u64,
}

impl<'a> OnesIter<'a> {
    fn new(words: &'a [u64], len: usize, complement: bool) -> Self {
        let first = words.first().copied().unwrap_or(0);
        OnesIter {
            words,
            len,
            complement,
            word_idx: 0,
            cur: if complement { !first } else { first },
        }
    }
}

impl Iterator for OnesIter<'_> {
    type Item = usize;

    #[inline]
    fn next(&mut self) -> Option<usize> {
        loop {
            if self.cur != 0 {
                let bit = self.cur.trailing_zeros() as usize;
                self.cur &= self.cur - 1;
                let idx = self.word_idx * 64 + bit;
                if idx < self.len {
                    return Some(idx);
                }
                // tail padding; anything further in this word is also
                // past `len`, and it's the last word.
                return None;
            }
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            let w = self.words[self.word_idx];
            self.cur = if self.complement { !w } else { w };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_helpers_cover_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(word_mask(128, 0), !0u64);
        assert_eq!(word_mask(128, 1), !0u64);
        assert_eq!(word_mask(70, 1), (1u64 << 6) - 1);
        assert_eq!(word_mask(70, 2), 0);
        assert_eq!(word_mask(0, 0), 0);
    }

    #[test]
    fn set_get_clear_roundtrip() {
        let mut v = BitVec::zeros(130);
        assert_eq!(v.len(), 130);
        v.set(0);
        v.set(63);
        v.set(64);
        v.set(129);
        assert!(v.get(0) && v.get(63) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(65));
        v.clear(64);
        assert!(!v.get(64));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn ones_constructor_masks_tail() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
    }

    #[test]
    fn iter_ones_matches_naive() {
        let mut v = BitVec::zeros(200);
        let idxs = [0usize, 1, 63, 64, 65, 127, 128, 199];
        for &i in &idxs {
            v.set(i);
        }
        let got: Vec<usize> = v.iter_ones().collect();
        assert_eq!(got, idxs.to_vec());
    }

    #[test]
    fn iter_zeros_matches_naive() {
        let mut v = BitVec::ones(131);
        v.clear(5);
        v.clear(64);
        v.clear(130);
        let got: Vec<usize> = v.iter_zeros().collect();
        assert_eq!(got, vec![5, 64, 130]);
    }

    #[test]
    fn iter_zeros_excludes_tail_padding() {
        // 65 bits: word 1 has 63 padding bits that must NOT be yielded.
        let v = BitVec::ones(65);
        assert_eq!(v.iter_zeros().count(), 0);
        let z = BitVec::zeros(65);
        assert_eq!(z.iter_zeros().count(), 65);
    }

    #[test]
    fn from_bools_roundtrip() {
        let bits: Vec<bool> = (0..99).map(|i| i % 3 == 0).collect();
        let v = BitVec::from_bools(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(v.get(i), b, "bit {i}");
        }
    }

    #[test]
    fn halves_complement_matches_naive() {
        // lengths straddling word boundaries, including odd halves
        for o in [0usize, 1, 3, 31, 32, 33, 63, 64, 65, 100, 128, 130] {
            let mut v = BitVec::zeros(2 * o);
            for k in 0..o {
                if k % 3 == 0 {
                    v.set(k);
                } else {
                    v.set(o + k);
                }
            }
            assert!(v.halves_complement(), "o = {o}");
            if o > 0 {
                // break one pair both ways: both set, then both clear
                let k = o / 2;
                let mut both = v.clone();
                both.set(k);
                both.set(o + k);
                assert!(!both.halves_complement(), "both set, o = {o}");
                let mut neither = v.clone();
                neither.clear(k);
                neither.clear(o + k);
                assert!(!neither.halves_complement(), "both clear, o = {o}");
            }
        }
        // count_ones == o is NOT sufficient: {x0, ¬x0 set; x9, ¬x9 clear}
        let mut v = BitVec::zeros(20);
        v.set(0);
        v.set(10);
        for k in 1..9 {
            v.set(10 + k);
        }
        assert_eq!(v.count_ones(), 10);
        assert!(!v.halves_complement());
        // odd length is never complement-structured
        assert!(!BitVec::zeros(7).halves_complement());
    }

    #[test]
    fn count_ones_prefix_matches_naive() {
        let mut v = BitVec::zeros(200);
        for i in [0usize, 1, 5, 63, 64, 100, 127, 128, 190, 199] {
            v.set(i);
        }
        for n in [0usize, 1, 2, 63, 64, 65, 128, 150, 200] {
            let naive = (0..n).filter(|&i| v.get(i)).count();
            assert_eq!(v.count_ones_prefix(n), naive, "prefix {n}");
        }
        assert_eq!(v.count_ones_prefix(v.len()), v.count_ones());
    }

    #[test]
    fn clear_all_resets() {
        let mut v = BitVec::ones(100);
        v.clear_all();
        assert_eq!(v.count_ones(), 0);
        assert_eq!(v.len(), 100);
    }

    #[test]
    fn assign_both_directions() {
        let mut v = BitVec::zeros(10);
        v.assign(3, true);
        assert!(v.get(3));
        v.assign(3, false);
        assert!(!v.get(3));
    }

    #[test]
    fn empty_vec() {
        let v = BitVec::zeros(0);
        assert!(v.is_empty());
        assert_eq!(v.iter_ones().count(), 0);
        assert_eq!(v.iter_zeros().count(), 0);
    }
}
