//! Zero-dependency utilities for the hot path.
//!
//! Everything the inner training/inference loops touch lives here:
//! a deterministic splitmix/xoshiro RNG, packed bit vectors, 4-wide
//! `u64` SIMD lane kernels with runtime x86_64 dispatch ([`simd`]), a
//! compact open-addressing map (used by the sparse position store),
//! and a monotonic timer.

pub mod bitvec;
pub mod crc32;
pub mod json;
pub mod rng;
pub mod simd;
pub mod smallmap;
pub mod timer;

pub use bitvec::BitVec;
pub use crc32::{crc32, Crc32};
pub use json::Json;
pub use rng::Rng;
pub use simd::{SimdLanes, SimdMode};
pub use smallmap::U64Map;
pub use timer::Stopwatch;
