//! Deterministic, allocation-free RNG for the TM hot loops.
//!
//! Tsetlin Machine Type I feedback draws one Bernoulli sample *per
//! literal per updated clause*, so the generator must be a handful of
//! instructions. We use xoshiro256**, seeded via splitmix64 — the
//! standard, well-tested combination. Determinism matters doubly here:
//! the speedup experiments run the *same* training trajectory with and
//! without indexing, so both runs must see identical random streams.

use crate::util::bitvec::{word_mask, words_for};
use crate::util::simd::{SimdLanes, W4};

/// xoshiro256** generator (public-domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-class / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    /// Next 32 uniformly random bits (high half of [`next_u64`](Self::next_u64)).
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (no modulo bias
    /// worth caring about at TM scales; bound is at most a few million).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        (((self.next_u32() as u64) * (bound as u64)) >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p) for a probability precomputed as a u32 threshold
    /// (`p * 2^32`); one u32 draw and one compare — the hot-path form.
    #[inline]
    pub fn bern_threshold(&mut self, threshold: u32) -> bool {
        self.next_u32() < threshold
    }

    /// Bernoulli(p) from a float probability (cold paths only).
    #[inline]
    pub fn bern(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Number of *failures* before the next success of a
    /// Bernoulli(`threshold` / 2^32) trial stream, in a single draw —
    /// geometric skip sampling via inversion of the geometric CDF.
    ///
    /// Walking a length-`n` Bernoulli stream costs an expected
    /// `n * p + 1` draws instead of `n`: the win that makes per-literal
    /// feedback masks cheap for small `1/s` (the TM's forget rate), and
    /// equally useful to `parallel/` workers drawing sparse update
    /// masks. Deterministic given the RNG state.
    ///
    /// Edge contract (mirrors [`prob_to_threshold`]):
    /// * `threshold == 0` (p = 0): no success ever — returns `u64::MAX`
    ///   as an "infinite gap" sentinel **without consuming a draw**.
    /// * `threshold == u32::MAX` (p = 1): every trial succeeds —
    ///   returns 0 without consuming a draw.
    #[inline]
    pub fn geometric_skip(&mut self, threshold: u32) -> u64 {
        if threshold == 0 {
            return u64::MAX;
        }
        if threshold == u32::MAX {
            return 0;
        }
        let p = threshold as f64 * (1.0 / 4294967296.0);
        // U in (0, 1]: gap = floor(ln U / ln(1-p)); U > 1-p <=> gap 0,
        // which happens with probability exactly p.
        let u = 1.0 - self.unit_f64();
        let g = u.ln() / (1.0 - p).ln();
        if g >= u64::MAX as f64 {
            u64::MAX
        } else {
            g as u64
        }
    }
}

/// Fill the first `n_bits` of `out` with an i.i.d. Bernoulli(p) mask,
/// p = `threshold` / 2^32. Bits past `n_bits` are cleared, so the words
/// can be consumed by word-parallel feedback without tail masking.
///
/// Two exact strategies, picked by expected cost (both produce
/// independent Bernoulli(p) bits; the RNG stream shape is a
/// deterministic function of `(threshold, n_bits)`, which is all the
/// scalar/sliced layout-equivalence contract needs):
///
/// * **geometric skip sampling** ([`Rng::geometric_skip`]) for sparse
///   masks: one draw per *set* bit (plus one terminating draw) —
///   expected `n_bits * p` draws, the `O(2o / s)` regime of TM forget
///   masks at large `s`.
/// * **binary-expansion sampling** for dense masks: per output word,
///   combine one uniform word per significant bit of the threshold's
///   dyadic expansion (`m <- r | m` for a 1-bit, `r & m` for a 0-bit,
///   deepest bit first), giving 64 exact Bernoulli(p) lanes in
///   `32 - trailing_zeros(threshold)` cheap draws — e.g. 2 draws per
///   word for the `s = 4` forget rate, independent of density.
pub fn fill_bernoulli_words(rng: &mut Rng, threshold: u32, out: &mut [u64], n_bits: usize) {
    debug_assert!(out.len() * 64 >= n_bits, "mask buffer too small");
    out.fill(0);
    if n_bits == 0 || threshold == 0 {
        return;
    }
    let words = words_for(n_bits);
    let tail_mask = word_mask(n_bits, words - 1);
    if threshold == u32::MAX {
        // p = 1 (the prob_to_threshold(1.0) encoding): draw-free
        out[..words].fill(!0u64);
        out[words - 1] &= tail_mask;
        return;
    }
    // cost model: a skip draw (ln + divide) ~6x a next_u64 draw
    let expansion_bits = 32 - threshold.trailing_zeros();
    let p = threshold as f64 * (1.0 / 4294967296.0);
    let skip_draws = n_bits as f64 * p;
    if skip_draws * 6.0 < (words as u32 * expansion_bits) as f64 {
        let mut pos = rng.geometric_skip(threshold);
        while pos < n_bits as u64 {
            out[(pos >> 6) as usize] |= 1u64 << (pos & 63);
            let gap = rng.geometric_skip(threshold);
            pos = pos.saturating_add(1).saturating_add(gap);
        }
    } else {
        // P(bit) = 0.b1 b2 .. bK in binary (b1 = threshold bit 31):
        // fold from the deepest bit outward — OR folds in a 1-bit's
        // probability half, AND halves for a 0-bit.
        for slot in out[..words].iter_mut() {
            let mut m = 0u64;
            for i in threshold.trailing_zeros()..32 {
                let r = rng.next_u64();
                m = if (threshold >> i) & 1 == 1 { r | m } else { r & m };
            }
            *slot = m;
        }
        out[words - 1] &= tail_mask;
    }
}

/// [`fill_bernoulli_words`] with an explicit lane width: the
/// [`SimdLanes::Wide`] dense path folds 4 output words at a time with
/// [`crate::util::simd::W4`] lane ops while drawing uniform words in
/// the *same word-major order* as the scalar fold, so the produced mask
/// **and** the RNG stream position are bit-identical to the scalar
/// path for every `(threshold, n_bits)`. The sparse geometric-skip
/// path and all edge cases are inherently serial and delegate
/// unchanged.
pub fn fill_bernoulli_words_simd(
    rng: &mut Rng,
    threshold: u32,
    out: &mut [u64],
    n_bits: usize,
    lanes: SimdLanes,
) {
    debug_assert!(out.len() * 64 >= n_bits, "mask buffer too small");
    if lanes == SimdLanes::Scalar || n_bits == 0 || threshold == 0 || threshold == u32::MAX {
        return fill_bernoulli_words(rng, threshold, out, n_bits);
    }
    let words = words_for(n_bits);
    // same cost model as the scalar fill — identical strategy choice
    // keeps the draw streams aligned
    let expansion_bits = 32 - threshold.trailing_zeros();
    let p = threshold as f64 * (1.0 / 4294967296.0);
    let skip_draws = n_bits as f64 * p;
    if skip_draws * 6.0 < (words as u32 * expansion_bits) as f64 {
        return fill_bernoulli_words(rng, threshold, out, n_bits);
    }
    out.fill(0);
    let tail_mask = word_mask(n_bits, words - 1);
    let bits = expansion_bits as usize;
    let tz = threshold.trailing_zeros();
    // Uniform draws for a 4-word group, in scalar order: all `bits`
    // draws of word w, then of word w+1, ... — lane-major here.
    let mut draws = [0u64; 4 * 32];
    let mut w = 0usize;
    while w + 4 <= words {
        for d in draws[..4 * bits].iter_mut() {
            *d = rng.next_u64();
        }
        let mut m = W4::zero();
        for (i, _) in (tz..32).enumerate() {
            let r = W4([
                draws[i],
                draws[bits + i],
                draws[2 * bits + i],
                draws[3 * bits + i],
            ]);
            m = if (threshold >> (tz + i as u32)) & 1 == 1 {
                r.or(m)
            } else {
                r.and(m)
            };
        }
        m.store(out, w);
        w += 4;
    }
    for slot in out[w..words].iter_mut() {
        let mut m = 0u64;
        for i in tz..32 {
            let r = rng.next_u64();
            m = if (threshold >> i) & 1 == 1 { r | m } else { r & m };
        }
        *slot = m;
    }
    out[words - 1] &= tail_mask;
}

/// Convert a probability to the u32 threshold used by `bern_threshold`.
#[inline]
pub fn prob_to_threshold(p: f64) -> u32 {
    if p >= 1.0 {
        u32::MAX
    } else if p <= 0.0 {
        0
    } else {
        (p * 4294967296.0) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unit_f64_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn bern_threshold_matches_probability() {
        let mut r = Rng::new(5);
        let th = prob_to_threshold(0.25);
        let hits = (0..100_000).filter(|_| r.bern_threshold(th)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.25).abs() < 0.01, "p={p}");
    }

    #[test]
    fn prob_to_threshold_edges() {
        assert_eq!(prob_to_threshold(0.0), 0);
        assert_eq!(prob_to_threshold(-1.0), 0);
        assert_eq!(prob_to_threshold(1.0), u32::MAX);
        assert_eq!(prob_to_threshold(2.0), u32::MAX);
        // p=0 never fires, p=1 always fires
        let mut r = Rng::new(11);
        assert!(!(0..1000).any(|_| r.bern_threshold(0)));
        assert!((0..1000).all(|_| r.bern_threshold(u32::MAX) || true));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn geometric_skip_edge_probabilities() {
        let mut r = Rng::new(31);
        // p = 0: infinite gap sentinel, and no stream consumption
        let before = r.clone();
        assert_eq!(r.geometric_skip(prob_to_threshold(0.0)), u64::MAX);
        assert_eq!(r.next_u64(), before.clone().next_u64());
        // p = 1: zero gap, also draw-free
        let before = r.clone();
        assert_eq!(r.geometric_skip(prob_to_threshold(1.0)), 0);
        assert_eq!(r.next_u64(), before.clone().next_u64());
    }

    #[test]
    fn geometric_skip_matches_bernoulli_rate() {
        // Mean gap of Geometric(p) is (1-p)/p: walking by gaps must
        // reproduce the Bernoulli success rate.
        let mut r = Rng::new(33);
        for p in [0.5, 0.25, 0.05] {
            let th = prob_to_threshold(p);
            let trials: u64 = 200_000;
            let mut pos = r.geometric_skip(th);
            let mut hits = 0u64;
            while pos < trials {
                hits += 1;
                pos += 1 + r.geometric_skip(th);
            }
            let rate = hits as f64 / trials as f64;
            assert!((rate - p).abs() < 0.01, "p={p} rate={rate}");
        }
    }

    #[test]
    fn geometric_skip_tiny_p_tail() {
        // p = 1e-6: gaps are ~Exp(p)-sized; the mean over many draws
        // must sit near 1/p - 1 and never collapse to 0 or blow past
        // the f64 -> u64 clamp.
        let mut r = Rng::new(35);
        let th = prob_to_threshold(1e-6);
        let n = 2000;
        let mut sum = 0f64;
        for _ in 0..n {
            let g = r.geometric_skip(th);
            assert!(g < u64::MAX, "tiny p must still yield finite gaps");
            sum += g as f64;
        }
        let mean = sum / n as f64;
        let want = 1e6;
        assert!(mean > want * 0.9 && mean < want * 1.1, "mean={mean}");
    }

    #[test]
    fn fill_bernoulli_words_density_and_edges() {
        let mut r = Rng::new(37);
        let n_bits = 10_000;
        let mut words = vec![0u64; n_bits.div_ceil(64)];
        // p = 1 sets every bit below n_bits and nothing past it
        fill_bernoulli_words(&mut r, prob_to_threshold(1.0), &mut words, n_bits);
        let ones: u32 = words.iter().map(|w| w.count_ones()).sum();
        assert_eq!(ones as usize, n_bits);
        // p = 0 clears a dirty buffer
        fill_bernoulli_words(&mut r, 0, &mut words, n_bits);
        assert!(words.iter().all(|&w| w == 0));
        // both strategies land on the requested density: p = 0.25 is
        // dyadic (binary-expansion path, 2 draws/word), p = 0.01 is
        // sparse (geometric skip path), p = 0.3 is a non-dyadic dense
        // threshold (expansion path, all 32 bits significant)
        for p in [0.25, 0.01, 0.3] {
            let mut hits = 0usize;
            for _ in 0..50 {
                fill_bernoulli_words(&mut r, prob_to_threshold(p), &mut words, n_bits);
                hits += words.iter().map(|w| w.count_ones() as usize).sum::<usize>();
            }
            let rate = hits as f64 / (50.0 * n_bits as f64);
            assert!((rate - p).abs() < 0.012, "p={p} rate={rate}");
        }
        // a short tail word stays clean on every path
        for p in [1.0, 0.5, 0.01] {
            let mut short = vec![!0u64; 2];
            fill_bernoulli_words(&mut r, prob_to_threshold(p), &mut short, 70);
            assert_eq!(short[1] & !((1u64 << 6) - 1), 0, "p={p} tail dirty");
        }
        let mut short = vec![0u64; 2];
        fill_bernoulli_words(&mut r, prob_to_threshold(1.0), &mut short, 70);
        assert_eq!(short[0], !0u64);
        assert_eq!(short[1], (1u64 << 6) - 1);
    }

    #[test]
    fn fill_bernoulli_words_simd_is_bit_and_stream_exact() {
        // wide fill must match the scalar fill bit-for-bit AND leave
        // the RNG at the same stream position, across both strategies,
        // edge thresholds, and non-multiple-of-4 word counts
        for n_bits in [0usize, 1, 63, 64, 70, 255, 256, 300, 1000, 4096] {
            for p in [0.0, 1.0, 0.25, 0.3, 0.5, 0.01, 1e-4] {
                let th = prob_to_threshold(p);
                let words = n_bits.div_ceil(64).max(1);
                let mut scalar_rng = Rng::new(0x1234_5678 ^ n_bits as u64);
                let mut wide_rng = scalar_rng.clone();
                let mut scalar_out = vec![!0u64; words];
                let mut wide_out = vec![0xAAu64; words];
                fill_bernoulli_words(&mut scalar_rng, th, &mut scalar_out, n_bits);
                fill_bernoulli_words_simd(&mut wide_rng, th, &mut wide_out, n_bits, SimdLanes::Wide);
                assert_eq!(scalar_out, wide_out, "p={p} n_bits={n_bits}: mask");
                assert_eq!(
                    scalar_rng.next_u64(),
                    wide_rng.next_u64(),
                    "p={p} n_bits={n_bits}: stream position"
                );
                // forced-scalar lanes are the scalar function verbatim
                let mut forced_rng = Rng::new(0x1234_5678 ^ n_bits as u64);
                let mut forced_out = vec![0u64; words];
                fill_bernoulli_words_simd(&mut forced_rng, th, &mut forced_out, n_bits, SimdLanes::Scalar);
                assert_eq!(scalar_out, forced_out, "p={p} n_bits={n_bits}: forced scalar");
            }
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
