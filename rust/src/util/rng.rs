//! Deterministic, allocation-free RNG for the TM hot loops.
//!
//! Tsetlin Machine Type I feedback draws one Bernoulli sample *per
//! literal per updated clause*, so the generator must be a handful of
//! instructions. We use xoshiro256**, seeded via splitmix64 — the
//! standard, well-tested combination. Determinism matters doubly here:
//! the speedup experiments run the *same* training trajectory with and
//! without indexing, so both runs must see identical random streams.

/// xoshiro256** generator (public-domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-class / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (no modulo bias
    /// worth caring about at TM scales; bound is at most a few million).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        (((self.next_u32() as u64) * (bound as u64)) >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p) for a probability precomputed as a u32 threshold
    /// (`p * 2^32`); one u32 draw and one compare — the hot-path form.
    #[inline]
    pub fn bern_threshold(&mut self, threshold: u32) -> bool {
        self.next_u32() < threshold
    }

    /// Bernoulli(p) from a float probability (cold paths only).
    #[inline]
    pub fn bern(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Convert a probability to the u32 threshold used by `bern_threshold`.
#[inline]
pub fn prob_to_threshold(p: f64) -> u32 {
    if p >= 1.0 {
        u32::MAX
    } else if p <= 0.0 {
        0
    } else {
        (p * 4294967296.0) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn unit_f64_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn bern_threshold_matches_probability() {
        let mut r = Rng::new(5);
        let th = prob_to_threshold(0.25);
        let hits = (0..100_000).filter(|_| r.bern_threshold(th)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.25).abs() < 0.01, "p={p}");
    }

    #[test]
    fn prob_to_threshold_edges() {
        assert_eq!(prob_to_threshold(0.0), 0);
        assert_eq!(prob_to_threshold(-1.0), 0);
        assert_eq!(prob_to_threshold(1.0), u32::MAX);
        assert_eq!(prob_to_threshold(2.0), u32::MAX);
        // p=0 never fires, p=1 always fires
        let mut r = Rng::new(11);
        assert!(!(0..1000).any(|_| r.bern_threshold(0)));
        assert!((0..1000).all(|_| r.bern_threshold(u32::MAX) || true));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
