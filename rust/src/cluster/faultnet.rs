//! Test-only TCP chaos proxy: sits between a client and one upstream
//! and injects network faults on command — added latency, byte
//! corruption, mid-stream truncation, immediate connection reset, and
//! full blackhole (accept, then forward nothing). Drives the
//! `tests/cluster_faults.rs` scenarios: a corrupted replication stream
//! must be quarantined by the CRC check, a blackholed node must
//! degrade to `err unavailable` instead of hanging, a partitioned
//! control plane must leave nodes serving their last-known assignment.
//!
//! Hidden from docs like [`crate::coordinator::server::fault`]; this
//! is harness machinery, not an operator surface.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// What the proxy does to connections accepted while the plan is
/// installed. Mutating faults (`corrupt_at`, `truncate_after`) apply
/// to the client→upstream byte stream, which is where a replication
/// push travels; `delay` applies to both directions.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPlan {
    /// Sleep this long before forwarding each chunk.
    pub delay: Duration,
    /// Stop forwarding client→upstream bytes at this offset, then
    /// close both ends — a torn transfer.
    pub truncate_after: Option<u64>,
    /// XOR one byte at this absolute client→upstream offset — a CRC
    /// failure at the receiver without changing the stream length.
    pub corrupt_at: Option<u64>,
    /// Close accepted connections immediately, forwarding nothing.
    pub reset: bool,
    /// Accept and hold connections open without ever forwarding — the
    /// client only escapes via its own timeout.
    pub blackhole: bool,
}

/// A one-upstream chaos proxy. The plan is sampled per accepted
/// connection, so flipping it affects new connections only.
pub struct ChaosProxy {
    addr: String,
    plan: Arc<Mutex<FaultPlan>>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind an ephemeral local port and start proxying to `upstream`.
    pub fn spawn(upstream: impl Into<String>) -> std::io::Result<ChaosProxy> {
        let upstream = upstream.into();
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let plan = Arc::new(Mutex::new(FaultPlan::default()));
        let stop = Arc::new(AtomicBool::new(false));
        let plan_loop = Arc::clone(&plan);
        let stop_loop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("tmi-chaos".to_string())
            .spawn(move || accept_loop(listener, &upstream, &plan_loop, &stop_loop))
            .expect("spawning chaos proxy thread");
        Ok(ChaosProxy {
            addr,
            plan,
            stop,
            accept: Some(accept),
        })
    }

    /// The proxy's listen address — point the client here.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Install a fault plan for subsequently accepted connections.
    pub fn set(&self, plan: FaultPlan) {
        *self.plan.lock().unwrap_or_else(PoisonError::into_inner) = plan;
    }

    /// Back to transparent forwarding.
    pub fn heal(&self) {
        self.set(FaultPlan::default());
    }

    /// Stop accepting and release the accept thread. Live connection
    /// pumps notice the flag within their read-timeout tick.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    upstream: &str,
    plan: &Mutex<FaultPlan>,
    stop: &Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((client, _)) => {
                let plan = *plan.lock().unwrap_or_else(PoisonError::into_inner);
                let upstream = upstream.to_string();
                let stop = Arc::clone(stop);
                std::thread::spawn(move || handle_conn(client, &upstream, plan, &stop));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
}

fn handle_conn(client: TcpStream, upstream: &str, plan: FaultPlan, stop: &Arc<AtomicBool>) {
    if plan.reset {
        return; // drop closes the socket without a reply
    }
    if plan.blackhole {
        // hold the socket open, forward nothing; the client's own
        // deadline is its only way out
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(10));
        }
        return;
    }
    let Ok(up) = TcpStream::connect(upstream) else {
        return;
    };
    let (Ok(c2), Ok(u2)) = (client.try_clone(), up.try_clone()) else {
        return;
    };
    let stop_b = Arc::clone(stop);
    let back = std::thread::spawn(move || pump(u2, c2, plan, false, &stop_b));
    pump(client, up, plan, true, stop);
    let _ = back.join();
}

/// Forward `r` into `w`, applying the plan. `mutate` is true on the
/// client→upstream direction, where corruption/truncation apply.
fn pump(mut r: TcpStream, mut w: TcpStream, plan: FaultPlan, mutate: bool, stop: &AtomicBool) {
    let _ = r.set_read_timeout(Some(Duration::from_millis(50)));
    let mut buf = [0u8; 4096];
    let mut offset: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        match r.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                if plan.delay > Duration::ZERO {
                    std::thread::sleep(plan.delay);
                }
                let chunk = &mut buf[..n];
                if mutate {
                    if let Some(at) = plan.corrupt_at {
                        if at >= offset && at < offset + n as u64 {
                            chunk[(at - offset) as usize] ^= 0xA5;
                        }
                    }
                    if let Some(cut) = plan.truncate_after {
                        if offset + n as u64 >= cut {
                            let keep = cut.saturating_sub(offset) as usize;
                            let _ = w.write_all(&chunk[..keep]);
                            break;
                        }
                    }
                }
                offset += n as u64;
                if w.write_all(chunk).is_err() {
                    break;
                }
            }
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => break,
        }
    }
    // unblock the peer pump: a half-open proxy would hide the fault
    let _ = r.shutdown(Shutdown::Both);
    let _ = w.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    /// A tiny line-echo upstream for proxy tests.
    fn echo_upstream() -> (String, Arc<AtomicBool>, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().unwrap().to_string();
        listener.set_nonblocking(true).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let stop_l = Arc::clone(&stop);
        let t = std::thread::spawn(move || {
            while !stop_l.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let mut reader = BufReader::new(stream.try_clone().unwrap());
                        let mut stream = stream;
                        let mut line = String::new();
                        while reader.read_line(&mut line).map(|n| n > 0).unwrap_or(false) {
                            if stream.write_all(line.as_bytes()).is_err() {
                                break;
                            }
                            line.clear();
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        (addr, stop, t)
    }

    fn roundtrip(addr: &str, line: &str, timeout: Duration) -> std::io::Result<String> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.write_all(line.as_bytes())?;
        let mut reader = BufReader::new(stream);
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        Ok(reply)
    }

    #[test]
    fn transparent_then_corrupting_then_healed() {
        let (addr, stop, t) = echo_upstream();
        let proxy = ChaosProxy::spawn(addr).expect("proxy");
        let echoed = roundtrip(proxy.addr(), "hello\n", Duration::from_secs(2)).expect("echo");
        assert_eq!(echoed, "hello\n");

        proxy.set(FaultPlan {
            corrupt_at: Some(1),
            ..FaultPlan::default()
        });
        let corrupted = roundtrip(proxy.addr(), "hello\n", Duration::from_secs(2)).expect("echo");
        assert_ne!(corrupted, "hello\n", "corruption plan forwarded bytes unchanged");

        proxy.heal();
        let healed = roundtrip(proxy.addr(), "hello\n", Duration::from_secs(2)).expect("echo");
        assert_eq!(healed, "hello\n");

        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        let _ = t.join();
    }

    #[test]
    fn blackhole_never_replies_and_reset_drops() {
        let (addr, stop, t) = echo_upstream();
        let proxy = ChaosProxy::spawn(addr).expect("proxy");
        proxy.set(FaultPlan {
            blackhole: true,
            ..FaultPlan::default()
        });
        let r = roundtrip(proxy.addr(), "hello\n", Duration::from_millis(200));
        assert!(
            r.is_err() || r.as_deref() == Ok(""),
            "blackholed request produced a reply: {r:?}"
        );

        proxy.set(FaultPlan {
            reset: true,
            ..FaultPlan::default()
        });
        let r = roundtrip(proxy.addr(), "hello\n", Duration::from_secs(2));
        assert!(
            r.is_err() || r.as_deref() == Ok(""),
            "reset connection produced a reply: {r:?}"
        );

        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        let _ = t.join();
    }

    #[test]
    fn truncation_cuts_the_stream_at_the_offset() {
        let (addr, stop, t) = echo_upstream();
        let proxy = ChaosProxy::spawn(addr).expect("proxy");
        proxy.set(FaultPlan {
            truncate_after: Some(3),
            ..FaultPlan::default()
        });
        // upstream only ever sees "hel" (no newline) — the echo never
        // fires, and the proxy closes both ends
        let r = roundtrip(proxy.addr(), "hello\n", Duration::from_secs(2));
        assert!(
            r.is_err() || r.as_deref() == Ok(""),
            "truncated stream still produced a full reply: {r:?}"
        );
        proxy.shutdown();
        stop.store(true, Ordering::SeqCst);
        let _ = t.join();
    }
}
